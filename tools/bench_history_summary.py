"""Print and validate the append-only perf trajectory (BENCH_history.jsonl).

    PYTHONPATH=src python tools/bench_history_summary.py \
        [BENCH_history.jsonl] [--validate] [--last N]

Each ``benchmarks.bench_bcd_eval`` run appends one JSON line; this tool
renders the trajectory as a table (one row per run: commit, backend
candidates/sec, suffix-vs-batched deep/mean) so a perf drift is visible
without diffing JSON blobs, and ``--validate`` checks every line against
the history schema — the contract ``SuffixCostModel.calibrated`` consumes.

Schema per line (current): ``utc`` (ISO-8601 Z), ``git`` (short hash or
null), ``config`` (dict with the operating point), ``cands_per_s``
(backend -> number), ``per_site_depth`` (depth -> row with site /
prefix_fraction / mode / speedup_suffix_vs_batched), plus top-level
``speedup_*`` numbers.  Lines written by older tool versions lack
``per_site_depth`` (and used the ambiguous ``speedup_suffix_vs_batched``
key): they are accepted as *legacy* — valid history, just invisible to
calibration — so ``--validate`` never forces a rewrite of the append-only
log.  Malformed JSON or wrong-typed fields fail validation (exit 1).
"""
from __future__ import annotations

import argparse
import json
import sys


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_entry(entry) -> list:
    """Schema violations for one parsed history entry ([] = valid).
    Legacy entries (no per_site_depth) validate against the legacy shape."""
    errs = []
    if not isinstance(entry, dict):
        return ["entry is not a JSON object"]
    utc = entry.get("utc")
    if not isinstance(utc, str) or not utc.endswith("Z"):
        errs.append(f"utc: expected ISO-8601 Z string, got {utc!r}")
    if not isinstance(entry.get("config"), dict):
        errs.append("config: expected object")
    cps = entry.get("cands_per_s")
    if not isinstance(cps, dict) or not cps or \
            not all(_is_num(v) for v in cps.values()):
        errs.append("cands_per_s: expected non-empty {backend: number}")
    for k, v in entry.items():
        if k.startswith("speedup_") and not _is_num(v):
            errs.append(f"{k}: expected number, got {v!r}")
    psd = entry.get("per_site_depth")
    if psd is None:
        return errs            # legacy line: pre-calibration tool version
    if not isinstance(psd, dict):
        return errs + ["per_site_depth: expected object"]
    for depth, row in psd.items():
        if not isinstance(row, dict):
            errs.append(f"per_site_depth[{depth}]: expected object")
            continue
        if not isinstance(row.get("site"), str):
            errs.append(f"per_site_depth[{depth}].site: expected string")
        for field in ("prefix_fraction", "speedup_suffix_vs_batched"):
            if not _is_num(row.get(field)):
                errs.append(f"per_site_depth[{depth}].{field}: "
                            f"expected number, got {row.get(field)!r}")
        if row.get("mode") not in ("suffix", "fallback"):
            errs.append(f"per_site_depth[{depth}].mode: expected "
                        f"'suffix'|'fallback', got {row.get('mode')!r}")
    return errs


def load_history(path):
    """Parse the jsonl; returns (entries, errors) where errors are
    ``(lineno, message)`` for lines that are not valid JSON objects."""
    entries, errors = [], []
    try:
        fh = open(path)
    except OSError as e:
        return [], [(0, f"cannot read {path}: {e}")]
    with fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                entries.append((lineno, json.loads(line)))
            except json.JSONDecodeError as e:
                errors.append((lineno, f"not valid JSON: {e}"))
    return entries, errors


def _fmt_speedup(entry, key):
    # current key first; one legacy spelling for deep (pre-rename lines)
    v = entry.get(key)
    if v is None and key == "speedup_suffix_vs_batched_deep":
        v = entry.get("speedup_suffix_vs_batched")
    return f"{v:.2f}" if _is_num(v) else "-"


def trajectory_lines(entries) -> list:
    """One table row per history entry (oldest first)."""
    header = (f"{'utc':20} {'git':8} {'seq':>7} {'batched':>8} "
              f"{'suffix':>8} {'deep':>6} {'mean':>6} {'aggr':>6}")
    lines = [header, "-" * len(header)]
    for _, e in entries:
        cps = e.get("cands_per_s") or {}

        def rate(name):
            v = cps.get(name)
            return f"{v:.0f}" if _is_num(v) else "-"

        lines.append(
            f"{str(e.get('utc') or '-'):20} {str(e.get('git') or '-'):8} "
            f"{rate('sequential'):>7} {rate('batched'):>8} "
            f"{rate('suffix'):>8} "
            f"{_fmt_speedup(e, 'speedup_suffix_vs_batched_deep'):>6} "
            f"{_fmt_speedup(e, 'speedup_suffix_vs_batched_mean'):>6} "
            f"{_fmt_speedup(e, 'speedup_suffix_vs_batched_aggregate'):>6}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("history", nargs="?", default="BENCH_history.jsonl")
    ap.add_argument("--validate", action="store_true",
                    help="exit 1 on any schema violation (legacy lines "
                         "without per_site_depth still pass)")
    ap.add_argument("--last", type=int, default=None, metavar="N",
                    help="only show the most recent N entries")
    args = ap.parse_args(argv)

    entries, errors = load_history(args.history)
    if not entries and not errors:
        print(f"{args.history}: empty history")
        return 0

    n_legacy = 0
    for lineno, entry in entries:
        errs = validate_entry(entry)
        if isinstance(entry, dict) and entry.get("per_site_depth") is None:
            n_legacy += 1
        for msg in errs:
            errors.append((lineno, msg))

    shown = entries if args.last is None else entries[-args.last:]
    for line in trajectory_lines(shown):
        print(line)
    print(f"{len(entries)} run(s) in {args.history}"
          + (f" ({n_legacy} legacy, pre-calibration format)"
             if n_legacy else ""))

    if errors:
        for lineno, msg in errors:
            print(f"INVALID line {lineno}: {msg}")
        if args.validate:
            print(f"FAIL: {len(errors)} schema violation(s)")
            return 1
    elif args.validate:
        print("history schema: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
