"""Check in-repo relative links in every tracked Markdown file.

    python tools/check_docs_links.py [root]

Walks the repo for ``*.md`` (skipping VCS/cache/run-output directories),
extracts inline Markdown links/images ``[text](target)``, and verifies that
every non-external target resolves to an existing file or directory:

- ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
- pure in-page anchors (``#section``) are skipped;
- ``path#anchor`` targets are checked for the *file* part;
- absolute paths (``/...``) are rejected outright — they break the moment
  the repo is cloned anywhere else.

Exit code 0 when every link resolves, 1 with one ``BROKEN`` line per bad
link otherwise — the CI ``docs`` job runs this so a renamed or deleted doc
cannot leave dangling references behind.
"""
from __future__ import annotations

import os
import re
import sys

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".ruff_cache",
             "node_modules", ".venv", "venv", "runs"}

# inline links and images: [text](target "title") — non-greedy, one line;
# fenced code blocks are stripped before matching.
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)>\s]+)>?(?:\s+\"[^\"]*\")?\s*\)")
_FENCE_RE = re.compile(r"^(```|~~~)")


def iter_md_files(root: str):
    """Yield every ``.md`` path under ``root``, skipping SKIP_DIRS."""
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in sorted(dirnames) if d not in SKIP_DIRS]
        for fn in sorted(filenames):
            if fn.endswith(".md"):
                yield os.path.join(dirpath, fn)


def iter_links(md_path: str):
    """Yield ``(line_number, target)`` for each inline link/image, with
    fenced code blocks excluded (they hold example syntax, not links)."""
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if _FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK_RE.finditer(line):
                yield i, m.group(1)


def check_file(md_path: str):
    """Check one file; returns ``(problems, n_links)`` where problems is a
    list of ``(line, target, reason)`` tuples (single parse per file)."""
    problems = []
    n_links = 0
    base = os.path.dirname(md_path)
    for line_no, target in iter_links(md_path):
        n_links += 1
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            continue                      # in-page anchor
        path = target.split("#", 1)[0]
        if not path:
            continue
        if path.startswith("/"):
            problems.append((line_no, target,
                             "absolute path (breaks outside this clone)"))
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            problems.append((line_no, target, f"missing: {resolved}"))
    return problems, n_links


def main(argv=None) -> int:
    """CLI entry; returns 0 iff every in-repo link resolves."""
    argv = sys.argv[1:] if argv is None else argv
    root = os.path.abspath(argv[0] if argv else ".")
    n_files = n_links = 0
    broken = []
    for md in iter_md_files(root):
        n_files += 1
        rel = os.path.relpath(md, root)
        problems, count = check_file(md)
        n_links += count
        for line_no, target, reason in problems:
            broken.append(f"BROKEN {rel}:{line_no}: ({target}) — {reason}")
    for b in broken:
        print(b)
    print(f"checked {n_links} links in {n_files} markdown files: "
          f"{len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
