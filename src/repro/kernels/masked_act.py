"""Pallas TPU kernel: fused masked activation (Network Linearization).

The inner op of the paper — ``y = m·act(x) + (1−m)·g(x)`` — is elementwise but
sits on the critical path of every linearized forward pass (BCD evaluates it
RT times per outer step over the whole train subsample).  On TPU we tile
(block_rows × block_cols) tiles of the flattened (rows, channels) activation
into VMEM, broadcast the per-channel mask tile across rows inside the kernel,
and fuse the replacement branch (identity or degree-2 polynomial) so the mask
select never materializes in HBM.

Lane alignment: block_cols is a multiple of 128 (VPU lane width); block_rows a
multiple of 8 (f32 sublane).  Grid is (rows/block_rows, cols/block_cols).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT_2_OVER_PI = 0.7978845608028654


def _act_tile(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "gelu":
        c = jnp.asarray(_SQRT_2_OVER_PI, x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if kind == "silu":
        return x * (1.0 / (1.0 + jnp.exp(-x)))
    if kind == "sqrelu":
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def _masked_act_kernel(x_ref, m_ref, o_ref, *, kind: str):
    x = x_ref[...]
    m = m_ref[...].astype(x.dtype)  # (1, block_cols) -> broadcast over rows
    y = _act_tile(x, kind)
    o_ref[...] = m * y + (1.0 - m) * x


def _masked_act_poly_kernel(x_ref, m_ref, p_ref, o_ref, *, kind: str):
    x = x_ref[...]
    m = m_ref[...].astype(x.dtype)
    p = p_ref[...].astype(x.dtype)  # (3, block_cols)
    y = _act_tile(x, kind)
    lin = p[0:1, :] * x * x + p[1:2, :] * x + p[2:3, :]
    o_ref[...] = m * y + (1.0 - m) * lin


def masked_act_2d(
    x: jax.Array,
    mask: jax.Array,
    poly: jax.Array | None = None,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked activation over a 2D (rows, channels) array.

    mask: (channels,) 0/1.  poly: optional (3, channels) a,b,c coefficients for
    the replacement g(x)=a·x²+b·x+c (AutoReP mode); identity when None.
    Rows/cols need not divide the block sizes — we clamp blocks to the array.
    """
    rows, cols = x.shape
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    # Pad to block multiples (cheap; elementwise kernel).
    pr = (-rows) % br
    pc = (-cols) % bc
    xp = jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x
    mp = jnp.pad(mask, ((0, pc),)) if pc else mask
    mp = mp.reshape(1, -1)
    grid = (xp.shape[0] // br, xp.shape[1] // bc)

    x_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    m_spec = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))

    if poly is None:
        fn = pl.pallas_call(
            functools.partial(_masked_act_kernel, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp)
    else:
        pp = jnp.pad(poly, ((0, 0), (0, pc))) if pc else poly
        p_spec = pl.BlockSpec((3, bc), lambda i, j: (0, j))
        fn = pl.pallas_call(
            functools.partial(_masked_act_poly_kernel, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec, p_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp, pp)
    if pr or pc:
        out = out[:rows, :cols]
    return out


# ------------------------------------------------------------ batched masks
#
# BCD's batched candidate engine evaluates a *stack* of N mask candidates in
# one call: x is (N, rows, cols) (the same activations replicated or
# per-candidate), mask is (N, cols) — one mask row per candidate.  We add a
# leading candidate grid dimension with block size 1: each (b, i, j) program
# owns one (block_rows × block_cols) tile of candidate b, and the mask tile
# (1, 1, block_cols) broadcasts over rows exactly like the 2D kernel.  Poly
# coefficients are per-site, not per-candidate, so they are shared across b.


def _masked_act_kernel_b(x_ref, m_ref, o_ref, *, kind: str):
    x = x_ref[...]                       # (1, br, bc)
    m = m_ref[...].astype(x.dtype)       # (1, 1, bc)
    y = _act_tile(x, kind)
    o_ref[...] = m * y + (1.0 - m) * x


def _masked_act_poly_kernel_b(x_ref, m_ref, p_ref, o_ref, *, kind: str):
    x = x_ref[...]                       # (1, br, bc)
    m = m_ref[...].astype(x.dtype)       # (1, 1, bc)
    p = p_ref[...].astype(x.dtype)       # (1, 3, bc) — candidate-shared
    y = _act_tile(x, kind)
    lin = p[:, 0:1, :] * x * x + p[:, 1:2, :] * x + p[:, 2:3, :]
    o_ref[...] = m * y + (1.0 - m) * lin


def masked_act_2d_batched(
    x: jax.Array,
    mask: jax.Array,
    poly: jax.Array | None = None,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked activation over N stacked candidates.

    x: (N, rows, cols); mask: (N, cols) — candidate b uses mask row b.
    poly: optional (3, cols), shared across candidates (AutoReP replacement
    coefficients belong to the site, not the candidate).
    """
    n, rows, cols = x.shape
    assert mask.shape == (n, cols), (mask.shape, x.shape)
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    pr = (-rows) % br
    pc = (-cols) % bc
    xp = jnp.pad(x, ((0, 0), (0, pr), (0, pc))) if (pr or pc) else x
    mp = jnp.pad(mask, ((0, 0), (0, pc))) if pc else mask
    mp = mp.reshape(n, 1, -1)
    grid = (n, xp.shape[1] // br, xp.shape[2] // bc)

    x_spec = pl.BlockSpec((1, br, bc), lambda b, i, j: (b, i, j))
    m_spec = pl.BlockSpec((1, 1, bc), lambda b, i, j: (b, 0, j))
    out_spec = pl.BlockSpec((1, br, bc), lambda b, i, j: (b, i, j))

    if poly is None:
        fn = pl.pallas_call(
            functools.partial(_masked_act_kernel_b, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp)
    else:
        pp = jnp.pad(poly, ((0, 0), (0, pc))) if pc else poly
        pp = pp.reshape(1, 3, -1)
        p_spec = pl.BlockSpec((1, 3, bc), lambda b, i, j: (0, 0, j))
        fn = pl.pallas_call(
            functools.partial(_masked_act_poly_kernel_b, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec, p_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp, pp)
    if pr or pc:
        out = out[:, :rows, :cols]
    return out


# ------------------------------------------------------ fused suffix kernels
#
# The suffix engine's hot shape: a masked-activation gate whose output feeds
# straight into a matmul (LM FFN down-projection) or a 3x3 conv (ResNet block
# body).  Unfused, the gate kernel writes the gated tensor to HBM and the
# matmul/conv reads it right back — for shallow cuts that round-trip is most
# of the suffix's byte traffic.  These kernels keep the gated tile in VMEM
# and feed the MXU directly (jnp.dot with a float32 accumulator, per the TPU
# guide).  Replacement is identity-only (poly2 sites keep the unfused pair)
# and weights are candidate-shared.
#
# VMEM footprint: the matmul kernel holds (block_rows, K) + (K, N) per
# program; the conv kernel holds one sample's (H, W, Cin) site plus the
# (Ho*Wo, 9*Cin) patch matrix and (9*Cin, Cout) weights — sized for
# CIFAR-scale stages (≤32×32×512 f32 ≈ 2 MB), not ImageNet stems.


def _masked_act_matmul_kernel(x_ref, m_ref, w_ref, o_ref, *, kind: str):
    x = x_ref[...]                       # (br, K)
    m = m_ref[...].astype(x.dtype)       # (1, K) -> broadcast over rows
    g = m * _act_tile(x, kind) + (1.0 - m) * x
    o_ref[...] = jnp.dot(g, w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _masked_act_matmul_mul_kernel(x_ref, m_ref, u_ref, w_ref, o_ref,
                                  *, kind: str):
    x = x_ref[...]
    m = m_ref[...].astype(x.dtype)
    g = (m * _act_tile(x, kind) + (1.0 - m) * x) * u_ref[...]
    o_ref[...] = jnp.dot(g, w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def masked_act_matmul_2d(
    x: jax.Array,
    mask: jax.Array,
    w: jax.Array,
    mul: jax.Array | None = None,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused ``(m·act(x) + (1−m)·x) [· mul] @ w`` over a 2D (rows, K) array.

    mask: (K,) 0/1; w: (K, N) candidate-shared weights; mul: optional
    (rows, K) second operand (gated-FFN up branch, multiplied after the
    gate, before the matmul).  The gated tensor never leaves VMEM.
    """
    rows, k = x.shape
    assert mask.shape == (k,), (mask.shape, x.shape)
    assert w.shape[0] == k, (w.shape, x.shape)
    n_out = w.shape[1]
    br = min(block_rows, rows)
    pr = (-rows) % br
    xp = jnp.pad(x, ((0, pr), (0, 0))) if pr else x
    grid = (xp.shape[0] // br,)
    x_spec = pl.BlockSpec((br, k), lambda i: (i, 0))
    m_spec = pl.BlockSpec((1, k), lambda i: (0, 0))
    w_spec = pl.BlockSpec((k, n_out), lambda i: (0, 0))
    out_spec = pl.BlockSpec((br, n_out), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((xp.shape[0], n_out), x.dtype)
    if mul is None:
        fn = pl.pallas_call(
            functools.partial(_masked_act_matmul_kernel, kind=kind),
            grid=grid, in_specs=[x_spec, m_spec, w_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret)
        out = fn(xp, mask.reshape(1, -1), w)
    else:
        up = jnp.pad(mul, ((0, pr), (0, 0))) if pr else mul
        fn = pl.pallas_call(
            functools.partial(_masked_act_matmul_mul_kernel, kind=kind),
            grid=grid, in_specs=[x_spec, m_spec, x_spec, w_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret)
        out = fn(xp, mask.reshape(1, -1), up, w)
    return out[:rows] if pr else out


def _masked_act_matmul_kernel_b(x_ref, m_ref, w_ref, o_ref, *, kind: str):
    x = x_ref[0]                         # (br, K) of one candidate
    m = m_ref[0].astype(x.dtype)         # (1, K) — candidate's mask row
    g = m * _act_tile(x, kind) + (1.0 - m) * x
    o_ref[0] = jnp.dot(g, w_ref[...],
                       preferred_element_type=jnp.float32
                       ).astype(o_ref.dtype)


def _masked_act_matmul_mul_kernel_b(x_ref, m_ref, u_ref, w_ref, o_ref,
                                    *, kind: str):
    x = x_ref[0]
    m = m_ref[0].astype(x.dtype)
    g = (m * _act_tile(x, kind) + (1.0 - m) * x) * u_ref[0]
    o_ref[0] = jnp.dot(g, w_ref[...],
                       preferred_element_type=jnp.float32
                       ).astype(o_ref.dtype)


def masked_act_matmul_2d_batched(
    x: jax.Array,
    mask: jax.Array,
    w: jax.Array,
    mul: jax.Array | None = None,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Stacked-candidate :func:`masked_act_matmul_2d`.

    x: (N, rows, K); mask: (N, K) — one mask row per candidate; w: (K, N_out)
    shared; mul: optional (N, rows, K).
    """
    n, rows, k = x.shape
    assert mask.shape == (n, k), (mask.shape, x.shape)
    n_out = w.shape[1]
    br = min(block_rows, rows)
    pr = (-rows) % br
    xp = jnp.pad(x, ((0, 0), (0, pr), (0, 0))) if pr else x
    grid = (n, xp.shape[1] // br)
    x_spec = pl.BlockSpec((1, br, k), lambda b, i: (b, i, 0))
    m_spec = pl.BlockSpec((1, 1, k), lambda b, i: (b, 0, 0))
    w_spec = pl.BlockSpec((k, n_out), lambda b, i: (0, 0))
    out_spec = pl.BlockSpec((1, br, n_out), lambda b, i: (b, i, 0))
    out_shape = jax.ShapeDtypeStruct((n, xp.shape[1], n_out), x.dtype)
    if mul is None:
        fn = pl.pallas_call(
            functools.partial(_masked_act_matmul_kernel_b, kind=kind),
            grid=grid, in_specs=[x_spec, m_spec, w_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret)
        out = fn(xp, mask.reshape(n, 1, k), w)
    else:
        up = jnp.pad(mul, ((0, 0), (0, pr), (0, 0))) if pr else mul
        fn = pl.pallas_call(
            functools.partial(_masked_act_matmul_mul_kernel_b, kind=kind),
            grid=grid, in_specs=[x_spec, m_spec, x_spec, w_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret)
        out = fn(xp, mask.reshape(n, 1, k), up, w)
    return out[:, :rows] if pr else out


def _same_pads(size: int, stride: int):
    """XLA SAME-padding geometry for a 3-tap window: (out, lo, hi)."""
    out = -(-size // stride)
    total = max((out - 1) * stride + 3 - size, 0)
    lo = total // 2
    return out, lo, total - lo


def _conv3x3_tile(g, w_flat, *, stride: int, out_dtype):
    """im2col 3x3 conv of one gated sample g: (H, W, Cin) -> (Ho, Wo, Cout).

    Static-slice decomposition: 9 strided taps concatenated to a
    (Ho*Wo, 9*Cin) patch matrix, one MXU matmul against the (9*Cin, Cout)
    flattened weights.  Tap-major (ky, kx, cin) column order matches
    ``w.reshape(9*Cin, Cout)`` of HWIO weights.
    """
    h, wd, cin = g.shape
    ho, plo_h, phi_h = _same_pads(h, stride)
    wo, plo_w, phi_w = _same_pads(wd, stride)
    xp = jnp.pad(g, ((plo_h, phi_h), (plo_w, phi_w), (0, 0)))
    cols = []
    for ky in range(3):
        for kx in range(3):
            sl = jax.lax.slice(
                xp, (ky, kx, 0),
                (ky + (ho - 1) * stride + 1, kx + (wo - 1) * stride + 1, cin),
                (stride, stride, 1))
            cols.append(sl.reshape(ho * wo, cin))
    patches = jnp.concatenate(cols, axis=1)
    out = jnp.dot(patches, w_flat, preferred_element_type=jnp.float32)
    return out.astype(out_dtype).reshape(ho, wo, -1)


def _masked_act_conv3x3_kernel(x_ref, m_ref, w_ref, o_ref, *, kind: str,
                               stride: int):
    x = x_ref[0]                          # (H, W, Cin) — one sample
    m = m_ref[...].astype(x.dtype)        # (H, W, Cin) — full site mask
    g = m * _act_tile(x, kind) + (1.0 - m) * x
    o_ref[0] = _conv3x3_tile(g, w_ref[...], stride=stride,
                             out_dtype=o_ref.dtype)


def _masked_act_conv3x3_kernel_b(x_ref, m_ref, w_ref, o_ref, *, kind: str,
                                 stride: int):
    x = x_ref[0, 0]                       # (H, W, Cin) of (cand, sample)
    m = m_ref[0].astype(x.dtype)          # (H, W, Cin) — candidate's mask
    g = m * _act_tile(x, kind) + (1.0 - m) * x
    o_ref[0, 0] = _conv3x3_tile(g, w_ref[...], stride=stride,
                                out_dtype=o_ref.dtype)


def masked_act_conv3x3(
    x: jax.Array,
    mask: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    kind: str = "relu",
    interpret: bool = False,
) -> jax.Array:
    """Fused gate + SAME 3x3 conv: x (B, H, W, Cin), mask (H, W, Cin) — the
    paper's full per-pixel site mask, shared over the batch — w HWIO
    (3, 3, Cin, Cout).  Grid is one program per sample."""
    b, h, wd, cin = x.shape
    assert mask.shape == (h, wd, cin), (mask.shape, x.shape)
    assert w.shape[:3] == (3, 3, cin), (w.shape, x.shape)
    cout = w.shape[3]
    ho, _, _ = _same_pads(h, stride)
    wo, _, _ = _same_pads(wd, stride)
    fn = pl.pallas_call(
        functools.partial(_masked_act_conv3x3_kernel, kind=kind,
                          stride=stride),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, wd, cin), lambda i: (i, 0, 0, 0)),
                  pl.BlockSpec((h, wd, cin), lambda i: (0, 0, 0)),
                  pl.BlockSpec((9 * cin, cout), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, ho, wo, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, cout), x.dtype),
        interpret=interpret)
    return fn(x, mask, w.reshape(9 * cin, cout))


def masked_act_conv3x3_batched(
    x: jax.Array,
    mask: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    kind: str = "relu",
    interpret: bool = False,
) -> jax.Array:
    """Stacked-candidate :func:`masked_act_conv3x3`: x (N, B, H, W, Cin),
    mask (N, H, W, Cin) — one full site mask per candidate; w shared."""
    n, b, h, wd, cin = x.shape
    assert mask.shape == (n, h, wd, cin), (mask.shape, x.shape)
    cout = w.shape[3]
    ho, _, _ = _same_pads(h, stride)
    wo, _, _ = _same_pads(wd, stride)
    fn = pl.pallas_call(
        functools.partial(_masked_act_conv3x3_kernel_b, kind=kind,
                          stride=stride),
        grid=(n, b),
        in_specs=[pl.BlockSpec((1, 1, h, wd, cin),
                               lambda c, i: (c, i, 0, 0, 0)),
                  pl.BlockSpec((1, h, wd, cin), lambda c, i: (c, 0, 0, 0)),
                  pl.BlockSpec((9 * cin, cout), lambda c, i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1, ho, wo, cout),
                               lambda c, i: (c, i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b, ho, wo, cout), x.dtype),
        interpret=interpret)
    return fn(x, mask, w.reshape(9 * cin, cout))
