"""Pallas TPU kernel: fused masked activation (Network Linearization).

The inner op of the paper — ``y = m·act(x) + (1−m)·g(x)`` — is elementwise but
sits on the critical path of every linearized forward pass (BCD evaluates it
RT times per outer step over the whole train subsample).  On TPU we tile
(block_rows × block_cols) tiles of the flattened (rows, channels) activation
into VMEM, broadcast the per-channel mask tile across rows inside the kernel,
and fuse the replacement branch (identity or degree-2 polynomial) so the mask
select never materializes in HBM.

Lane alignment: block_cols is a multiple of 128 (VPU lane width); block_rows a
multiple of 8 (f32 sublane).  Grid is (rows/block_rows, cols/block_cols).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT_2_OVER_PI = 0.7978845608028654


def _act_tile(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "gelu":
        c = jnp.asarray(_SQRT_2_OVER_PI, x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if kind == "silu":
        return x * (1.0 / (1.0 + jnp.exp(-x)))
    if kind == "sqrelu":
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def _masked_act_kernel(x_ref, m_ref, o_ref, *, kind: str):
    x = x_ref[...]
    m = m_ref[...].astype(x.dtype)  # (1, block_cols) -> broadcast over rows
    y = _act_tile(x, kind)
    o_ref[...] = m * y + (1.0 - m) * x


def _masked_act_poly_kernel(x_ref, m_ref, p_ref, o_ref, *, kind: str):
    x = x_ref[...]
    m = m_ref[...].astype(x.dtype)
    p = p_ref[...].astype(x.dtype)  # (3, block_cols)
    y = _act_tile(x, kind)
    lin = p[0:1, :] * x * x + p[1:2, :] * x + p[2:3, :]
    o_ref[...] = m * y + (1.0 - m) * lin


def masked_act_2d(
    x: jax.Array,
    mask: jax.Array,
    poly: jax.Array | None = None,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked activation over a 2D (rows, channels) array.

    mask: (channels,) 0/1.  poly: optional (3, channels) a,b,c coefficients for
    the replacement g(x)=a·x²+b·x+c (AutoReP mode); identity when None.
    Rows/cols need not divide the block sizes — we clamp blocks to the array.
    """
    rows, cols = x.shape
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    # Pad to block multiples (cheap; elementwise kernel).
    pr = (-rows) % br
    pc = (-cols) % bc
    xp = jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x
    mp = jnp.pad(mask, ((0, pc),)) if pc else mask
    mp = mp.reshape(1, -1)
    grid = (xp.shape[0] // br, xp.shape[1] // bc)

    x_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    m_spec = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))

    if poly is None:
        fn = pl.pallas_call(
            functools.partial(_masked_act_kernel, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp)
    else:
        pp = jnp.pad(poly, ((0, 0), (0, pc))) if pc else poly
        p_spec = pl.BlockSpec((3, bc), lambda i, j: (0, j))
        fn = pl.pallas_call(
            functools.partial(_masked_act_poly_kernel, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec, p_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp, pp)
    if pr or pc:
        out = out[:rows, :cols]
    return out


# ------------------------------------------------------------ batched masks
#
# BCD's batched candidate engine evaluates a *stack* of N mask candidates in
# one call: x is (N, rows, cols) (the same activations replicated or
# per-candidate), mask is (N, cols) — one mask row per candidate.  We add a
# leading candidate grid dimension with block size 1: each (b, i, j) program
# owns one (block_rows × block_cols) tile of candidate b, and the mask tile
# (1, 1, block_cols) broadcasts over rows exactly like the 2D kernel.  Poly
# coefficients are per-site, not per-candidate, so they are shared across b.


def _masked_act_kernel_b(x_ref, m_ref, o_ref, *, kind: str):
    x = x_ref[...]                       # (1, br, bc)
    m = m_ref[...].astype(x.dtype)       # (1, 1, bc)
    y = _act_tile(x, kind)
    o_ref[...] = m * y + (1.0 - m) * x


def _masked_act_poly_kernel_b(x_ref, m_ref, p_ref, o_ref, *, kind: str):
    x = x_ref[...]                       # (1, br, bc)
    m = m_ref[...].astype(x.dtype)       # (1, 1, bc)
    p = p_ref[...].astype(x.dtype)       # (1, 3, bc) — candidate-shared
    y = _act_tile(x, kind)
    lin = p[:, 0:1, :] * x * x + p[:, 1:2, :] * x + p[:, 2:3, :]
    o_ref[...] = m * y + (1.0 - m) * lin


def masked_act_2d_batched(
    x: jax.Array,
    mask: jax.Array,
    poly: jax.Array | None = None,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked activation over N stacked candidates.

    x: (N, rows, cols); mask: (N, cols) — candidate b uses mask row b.
    poly: optional (3, cols), shared across candidates (AutoReP replacement
    coefficients belong to the site, not the candidate).
    """
    n, rows, cols = x.shape
    assert mask.shape == (n, cols), (mask.shape, x.shape)
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    pr = (-rows) % br
    pc = (-cols) % bc
    xp = jnp.pad(x, ((0, 0), (0, pr), (0, pc))) if (pr or pc) else x
    mp = jnp.pad(mask, ((0, 0), (0, pc))) if pc else mask
    mp = mp.reshape(n, 1, -1)
    grid = (n, xp.shape[1] // br, xp.shape[2] // bc)

    x_spec = pl.BlockSpec((1, br, bc), lambda b, i, j: (b, i, j))
    m_spec = pl.BlockSpec((1, 1, bc), lambda b, i, j: (b, 0, j))
    out_spec = pl.BlockSpec((1, br, bc), lambda b, i, j: (b, i, j))

    if poly is None:
        fn = pl.pallas_call(
            functools.partial(_masked_act_kernel_b, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp)
    else:
        pp = jnp.pad(poly, ((0, 0), (0, pc))) if pc else poly
        pp = pp.reshape(1, 3, -1)
        p_spec = pl.BlockSpec((1, 3, bc), lambda b, i, j: (0, 0, j))
        fn = pl.pallas_call(
            functools.partial(_masked_act_poly_kernel_b, kind=kind),
            grid=grid,
            in_specs=[x_spec, m_spec, p_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            interpret=interpret,
        )
        out = fn(xp, mp, pp)
    if pr or pc:
        out = out[:, :rows, :cols]
    return out
