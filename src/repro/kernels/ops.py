"""Public jit'd entry points for the kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled; elsewhere
(this CPU container) callers get the pure-jnp oracle unless they explicitly ask
for ``interpret=True`` (kernel-correctness tests do).  Model code calls these
wrappers only — it never touches pallas directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .masked_act import (masked_act_2d, masked_act_2d_batched,
                         masked_act_conv3x3 as _fused_conv3x3,
                         masked_act_conv3x3_batched as _fused_conv3x3_b,
                         masked_act_matmul_2d, masked_act_matmul_2d_batched)
from .rwkv6_scan import rwkv6_scan as _rwkv6_pallas


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def fused_dispatch_enabled() -> bool:
    """Whether the fused suffix megakernels run natively here (models gate
    their fused-route branches on this; interpret-mode tests bypass it)."""
    return _use_pallas()


def masked_act(x, mask, *, kind: str = "relu", poly=None,
               force_pallas: bool = False, interpret: bool = False):
    """y = mask·act(x) + (1−mask)·g(x) over (..., C) with per-channel mask.

    Accepts any leading shape; flattens to (rows, C) for the kernel.
    """
    if not (force_pallas or _use_pallas()):
        return ref.masked_act_ref(x, mask, kind=kind, poly=poly)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = masked_act_2d(x2, mask, poly, kind=kind,
                        interpret=interpret or not _use_pallas())
    return out.reshape(shape)


def masked_act_sited(x, mask, *, kind: str = "relu", poly=None, **kw):
    """Masked activation where the mask covers the full *site* shape.

    For CNNs the paper's mask is per (H, W, C) location shared over batch:
    x: (B, *site), mask: (*site).  Flattens site dims into the channel axis.
    """
    rows = int(x.size // mask.size)
    x2 = x.reshape(rows, mask.size)
    p2 = None if poly is None else poly.reshape(3, mask.size)
    out = masked_act(x2, mask.reshape(-1), kind=kind, poly=p2, **kw)
    return out.reshape(x.shape)


def masked_act_batched(x, masks, *, kind: str = "relu", poly=None,
                       force_pallas: bool = False, interpret: bool = False):
    """Stacked-candidate masked activation (BCD's batched trial engine).

    x: (N, ..., C) — leading axis is the candidate axis; masks: (N, C), one
    per-channel mask row per candidate.  poly: optional (3, C), shared across
    candidates.  Flattens the middle dims to rows for the batched kernel.
    """
    n = masks.shape[0]
    assert x.shape[0] == n, (x.shape, masks.shape)
    if not (force_pallas or _use_pallas()):
        m = masks.reshape((n,) + (1,) * (x.ndim - 2) + (masks.shape[-1],))
        return ref.masked_act_ref(x, m, kind=kind, poly=poly)
    shape = x.shape
    x3 = x.reshape(n, -1, shape[-1])
    out = masked_act_2d_batched(x3, masks, poly, kind=kind,
                                interpret=interpret or not _use_pallas())
    return out.reshape(shape)


def masked_act_sited_batched(x, masks, *, kind: str = "relu", poly=None,
                             **kw):
    """Batched :func:`masked_act_sited`: stacked site masks.

    x: (N, B, *site) activations per candidate; masks: (N, *site) — flattens
    site dims into the channel axis, candidates stay the leading axis.
    """
    n = masks.shape[0]
    site_size = int(masks.size // n)
    x3 = x.reshape(n, -1, site_size)
    p2 = None if poly is None else poly.reshape(3, site_size)
    out = masked_act_batched(x3, masks.reshape(n, site_size), kind=kind,
                             poly=p2, **kw)
    return out.reshape(x.shape)


# --------------------------------------------------- candidate-vmap routing
#
# The BCD candidate engines (core.engine) evaluate a chunk of masks as
# jit(vmap(eval_fn)): inside the model forward every mask site then carries a
# hidden candidate batch dim.  Plain vmap of masked_act_sited would batch the
# per-candidate pallas_call's grid; the wrappers below attach a
# jax.custom_batching.custom_vmap rule that instead lowers the whole batched
# site to the stacked kernel (masked_act_2d_batched) — one pallas_call owning
# the (N, rows, cols) tiling, with the mask row broadcast per candidate
# inside VMEM.  custom_vmap does not support differentiation, so this entry
# is opt-in (core.linearize.stacked_kernel_route): training forwards keep the
# plain kernel.
#
# Suffix entry (the prefix-reuse engine, core.engine.SuffixEvaluator): the
# vmapped *suffix* forward receives the cached prefix activation with
# in_axes=None, so at the cut segment's first mask site the rule sees a
# batched mask over an UNBATCHED x.  _to_batched broadcasts x across the
# candidate axis before handing the site to the stacked kernel — the one
# extra layout the split forward needs (tests/test_kernels.py pins it).


@functools.lru_cache(maxsize=None)
def _routed_sited(kind: str, interpret: bool, has_poly: bool):
    from jax import custom_batching

    def _to_batched(axis_size, xb, mb, pb, x, mask, poly):
        if pb:
            raise NotImplementedError(
                "poly coefficients are per-site, not per-candidate; a "
                "batched poly axis has no stacked-kernel layout")
        if not xb:        # mask-independent activations (e.g. the first site)
            x = jnp.broadcast_to(x[None], (axis_size,) + x.shape)
        if not mb:
            mask = jnp.broadcast_to(mask[None], (axis_size,) + mask.shape)
        out = masked_act_sited_batched(x, mask, kind=kind, poly=poly,
                                       force_pallas=True, interpret=interpret)
        return out, True

    if has_poly:
        @custom_batching.custom_vmap
        def f(x, mask, poly):
            return masked_act_sited(x, mask, kind=kind, poly=poly,
                                    force_pallas=True, interpret=interpret)

        @f.def_vmap
        def _rule(axis_size, in_batched, x, mask, poly):
            return _to_batched(axis_size, in_batched[0], in_batched[1],
                               in_batched[2], x, mask, poly)
    else:
        @custom_batching.custom_vmap
        def f(x, mask):
            return masked_act_sited(x, mask, kind=kind,
                                    force_pallas=True, interpret=interpret)

        @f.def_vmap
        def _rule(axis_size, in_batched, x, mask):
            return _to_batched(axis_size, in_batched[0], in_batched[1],
                               False, x, mask, None)
    return f


def masked_act_sited_routed(x, mask, *, kind: str = "relu", poly=None,
                            interpret: bool = False):
    """:func:`masked_act_sited` with a custom-vmap rule: under a candidate
    axis vmap (the batched/sharded/pipelined BCD engines) the site lowers to
    the stacked Pallas kernel instead of a vmapped per-candidate grid.

    TPU-path only (callers dispatch; the kernel always runs, with
    ``interpret=True`` for off-TPU tests).  Not differentiable — route
    training forwards through :func:`masked_act_sited`.
    """
    f = _routed_sited(kind, bool(interpret), poly is not None)
    return f(x, mask) if poly is None else f(x, mask, poly)


MASKED_ACT_FUSED_KINDS = ("relu", "gelu", "silu", "sqrelu")


def masked_act_matmul(x, mask, w, mul=None, *, kind: str = "relu",
                      force_pallas: bool = False, interpret: bool = False):
    """Fused ``gate(x) [· mul] @ w`` — the suffix megakernel for a masked
    activation feeding a matmul (LM FFN down-projection).

    x: (..., K); mask: (K,); w: (K, N) candidate-shared; mul: optional
    (..., K).  Off-TPU (without force) this is the unfused oracle — the
    exact primitives the plain forward traces, so CPU dispatch is bitwise
    inert.
    """
    if not (force_pallas or _use_pallas()):
        return ref.masked_act_matmul_ref(x, mask, w, mul, kind=kind)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    u2 = None if mul is None else mul.reshape(-1, shape[-1])
    out = masked_act_matmul_2d(x2, mask, w, u2, kind=kind,
                               interpret=interpret or not _use_pallas())
    return out.reshape(shape[:-1] + (w.shape[-1],))


def masked_act_matmul_batched(x, masks, w, mul=None, *, kind: str = "relu",
                              force_pallas: bool = False,
                              interpret: bool = False):
    """Stacked-candidate :func:`masked_act_matmul`: x (N, ..., K), masks
    (N, K) — one mask row per candidate — w shared, mul optional
    (N, ..., K)."""
    n = masks.shape[0]
    assert x.shape[0] == n, (x.shape, masks.shape)
    if not (force_pallas or _use_pallas()):
        m = masks.reshape((n,) + (1,) * (x.ndim - 2) + (masks.shape[-1],))
        g = ref.masked_act_ref(x, m, kind=kind)
        if mul is not None:
            g = g * mul
        return g @ w
    shape = x.shape
    x3 = x.reshape(n, -1, shape[-1])
    u3 = None if mul is None else mul.reshape(n, -1, shape[-1])
    out = masked_act_matmul_2d_batched(
        x3, masks, w, u3, kind=kind, interpret=interpret or not _use_pallas())
    return out.reshape(shape[:-1] + (w.shape[-1],))


def masked_act_conv3x3(x, mask, w, *, stride: int = 1, kind: str = "relu",
                       force_pallas: bool = False, interpret: bool = False):
    """Fused ``conv3x3(gate(x))`` — the suffix megakernel for a CNN's
    masked ReLU feeding a SAME 3x3 conv.

    x: (B, H, W, Cin); mask: (H, W, Cin) full per-pixel site mask; w HWIO.
    Off-TPU (without force) this is the unfused oracle (gate +
    lax.conv)."""
    if not (force_pallas or _use_pallas()):
        return ref.masked_act_conv3x3_ref(x, mask, w, stride=stride,
                                          kind=kind)
    return _fused_conv3x3(x, mask, w, stride=stride, kind=kind,
                          interpret=interpret or not _use_pallas())


def masked_act_conv3x3_batched(x, masks, w, *, stride: int = 1,
                               kind: str = "relu",
                               force_pallas: bool = False,
                               interpret: bool = False):
    """Stacked-candidate :func:`masked_act_conv3x3`: x (N, B, H, W, Cin),
    masks (N, H, W, Cin), w shared."""
    n = masks.shape[0]
    assert x.shape[0] == n, (x.shape, masks.shape)
    if not (force_pallas or _use_pallas()):
        m = masks[:, None].astype(x.dtype)
        g = m * ref._act(x, kind) + (1.0 - m) * x
        conv = functools.partial(
            jax.lax.conv_general_dilated, rhs=w,
            window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.vmap(lambda g_i: conv(g_i))(g)
    return _fused_conv3x3_b(x, masks, w, stride=stride, kind=kind,
                            interpret=interpret or not _use_pallas())


# Routed (custom_vmap) fused entries: same contract as
# masked_act_sited_routed — under the suffix engine's candidate vmap the
# whole fused site lowers to the stacked kernel, broadcasting an unbatched
# x/mul (the cached prefix at the cut site) across the candidate axis.
# Weights are always candidate-shared (ctx rides with in_axes=None).


def _bcast_cand(axis_size, batched, v):
    return v if batched else jnp.broadcast_to(v[None],
                                              (axis_size,) + v.shape)


@functools.lru_cache(maxsize=None)
def _routed_matmul(kind: str, interpret: bool, has_mul: bool):
    from jax import custom_batching

    if has_mul:
        @custom_batching.custom_vmap
        def f(x, mask, w, mul):
            return masked_act_matmul(x, mask, w, mul, kind=kind,
                                     force_pallas=True, interpret=interpret)

        @f.def_vmap
        def _rule(axis_size, in_batched, x, mask, w, mul):
            xb, mb, wb, ub = in_batched
            if wb:
                raise NotImplementedError(
                    "fused-matmul weights are candidate-shared; a batched "
                    "weight axis has no stacked-kernel layout")
            x = _bcast_cand(axis_size, xb, x)
            mask = _bcast_cand(axis_size, mb, mask)
            mul = _bcast_cand(axis_size, ub, mul)
            out = masked_act_matmul_batched(x, mask, w, mul, kind=kind,
                                            force_pallas=True,
                                            interpret=interpret)
            return out, True
    else:
        @custom_batching.custom_vmap
        def f(x, mask, w):
            return masked_act_matmul(x, mask, w, kind=kind,
                                     force_pallas=True, interpret=interpret)

        @f.def_vmap
        def _rule(axis_size, in_batched, x, mask, w):
            xb, mb, wb = in_batched
            if wb:
                raise NotImplementedError(
                    "fused-matmul weights are candidate-shared; a batched "
                    "weight axis has no stacked-kernel layout")
            x = _bcast_cand(axis_size, xb, x)
            mask = _bcast_cand(axis_size, mb, mask)
            out = masked_act_matmul_batched(x, mask, w, kind=kind,
                                            force_pallas=True,
                                            interpret=interpret)
            return out, True
    return f


def masked_act_matmul_routed(x, mask, w, mul=None, *, kind: str = "relu",
                             interpret: bool = False):
    """:func:`masked_act_matmul` with a custom-vmap rule lowering a
    candidate-axis vmap to the stacked fused kernel.  Not differentiable —
    suffix-engine tracing only (``linearize.fused_suffix_route``)."""
    f = _routed_matmul(kind, bool(interpret), mul is not None)
    return f(x, mask, w) if mul is None else f(x, mask, w, mul)


@functools.lru_cache(maxsize=None)
def _routed_conv3x3(kind: str, stride: int, interpret: bool):
    from jax import custom_batching

    @custom_batching.custom_vmap
    def f(x, mask, w):
        return masked_act_conv3x3(x, mask, w, stride=stride, kind=kind,
                                  force_pallas=True, interpret=interpret)

    @f.def_vmap
    def _rule(axis_size, in_batched, x, mask, w):
        xb, mb, wb = in_batched
        if wb:
            raise NotImplementedError(
                "fused-conv weights are candidate-shared; a batched weight "
                "axis has no stacked-kernel layout")
        x = _bcast_cand(axis_size, xb, x)
        mask = _bcast_cand(axis_size, mb, mask)
        out = masked_act_conv3x3_batched(x, mask, w, stride=stride,
                                         kind=kind, force_pallas=True,
                                         interpret=interpret)
        return out, True
    return f


def masked_act_conv3x3_routed(x, mask, w, *, stride: int = 1,
                              kind: str = "relu", interpret: bool = False):
    """:func:`masked_act_conv3x3` with a custom-vmap rule lowering a
    candidate-axis vmap to the stacked fused kernel.  Not differentiable —
    suffix-engine tracing only (``linearize.fused_suffix_route``)."""
    return _routed_conv3x3(kind, int(stride), bool(interpret))(x, mask, w)


def rwkv6(r, k, v, w, u, state, *, chunk: int = 32,
          force_pallas: bool = False, interpret: bool = False):
    """Chunked rwkv6 scan over (BH, T, K/V); falls back to a lax.scan oracle."""
    if force_pallas or _use_pallas():
        return _rwkv6_pallas(r, k, v, w, u, state, chunk=chunk,
                             interpret=interpret or not _use_pallas())
    return _rwkv6_scan_jnp(r, k, v, w, u, state)


@jax.jit
def _rwkv6_scan_jnp(r, k, v, w, u, state):
    """Vectorized (over BH) chunk-free oracle using lax.scan on tokens."""
    def head(r, k, v, w, u, s0):
        def step(S, inp):
            rt, kt, vt, wt = inp
            y = rt @ S + (rt * (u * kt)).sum() * vt
            S = wt[:, None] * S + kt[:, None] * vt[None, :]
            return S, y
        S, ys = jax.lax.scan(step, s0, (r, k, v, w))
        return ys, S
    return jax.vmap(head)(r, k, v, w, u, state)
