"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "gelu":
        # tanh approximation — matches the kernel exactly.
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if kind == "silu":
        return x * (1.0 / (1.0 + jnp.exp(-x)))
    if kind == "sqrelu":  # rwkv6 channel-mix: relu(x)^2
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def masked_act_ref(x, mask, kind: str = "relu", poly=None):
    """y = mask * act(x) + (1-mask) * g(x).

    x:    (..., C) activations
    mask: (C,) float 0/1 — per-channel keep mask (broadcast over leading dims)
    poly: None -> g(x) = x (identity / Network Linearization)
          (3, C) -> g(x) = a*x^2 + b*x + c   (AutoReP-style replacement)
    """
    act = _act(x, kind)
    if poly is None:
        lin = x
    else:
        a, b, c = poly[0], poly[1], poly[2]
        lin = a * x * x + b * x + c
    m = mask.astype(x.dtype)
    return m * act + (1.0 - m) * lin


def masked_act_matmul_ref(x, mask, w, mul=None, *, kind: str = "relu"):
    """Oracle for the fused gate→matmul suffix kernel: the unfused pair
    ``masked_act_ref(x, mask) [· mul] @ w`` (identity replacement only —
    poly2 sites never take the fused route).

    x: (..., K); mask: (K,); w: (K, N); mul: optional (..., K) gated-FFN up
    branch, multiplied after the gate, before the matmul.
    """
    g = masked_act_ref(x, mask, kind=kind)
    if mul is not None:
        g = g * mul
    return g @ w


def masked_act_conv3x3_ref(x, mask, w, *, stride: int = 1,
                           kind: str = "relu"):
    """Oracle for the fused gate→3x3-conv suffix kernel: the unfused pair —
    full-site gate then ``lax.conv_general_dilated`` (SAME, NHWC/HWIO),
    exactly the primitives the CNN's unfused forward traces.

    x: (B, H, W, Cin); mask: (H, W, Cin) per-pixel site mask (batch-shared);
    w: (3, 3, Cin, Cout).
    """
    m = mask.astype(x.dtype)
    g = m * _act(x, kind) + (1.0 - m) * x
    return jax.lax.conv_general_dilated(
        g, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def rwkv6_chunk_ref(r, k, v, w, u, state):
    """One chunk of the RWKV-6 linear-attention recurrence (oracle).

    Shapes (single head):
      r, k, w : (T, K)     v: (T, V)     u: (K,)    state: (K, V)
    Recurrence per token t:
      y_t   = (u ⊙ k_t) (r_t · ·) v_t  + r_t @ S_t
      S_t+1 = diag(w_t) S_t + k_t^T v_t
    Returns (y: (T, V), new_state).
    """
    T = r.shape[0]
    ys = []
    S = state
    for t in range(T):
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]
        cur = (rt * (u * kt)).sum()[None] * vt  # bonus for current token
        y = rt @ S + cur
        S = wt[:, None] * S + kt[:, None] * vt[None, :]
        ys.append(y)
    return jnp.stack(ys), S
