"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp


def _act(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "gelu":
        # tanh approximation — matches the kernel exactly.
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if kind == "silu":
        return x * (1.0 / (1.0 + jnp.exp(-x)))
    if kind == "sqrelu":  # rwkv6 channel-mix: relu(x)^2
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def masked_act_ref(x, mask, kind: str = "relu", poly=None):
    """y = mask * act(x) + (1-mask) * g(x).

    x:    (..., C) activations
    mask: (C,) float 0/1 — per-channel keep mask (broadcast over leading dims)
    poly: None -> g(x) = x (identity / Network Linearization)
          (3, C) -> g(x) = a*x^2 + b*x + c   (AutoReP-style replacement)
    """
    act = _act(x, kind)
    if poly is None:
        lin = x
    else:
        a, b, c = poly[0], poly[1], poly[2]
        lin = a * x * x + b * x + c
    m = mask.astype(x.dtype)
    return m * act + (1.0 - m) * lin


def rwkv6_chunk_ref(r, k, v, w, u, state):
    """One chunk of the RWKV-6 linear-attention recurrence (oracle).

    Shapes (single head):
      r, k, w : (T, K)     v: (T, V)     u: (K,)    state: (K, V)
    Recurrence per token t:
      y_t   = (u ⊙ k_t) (r_t · ·) v_t  + r_t @ S_t
      S_t+1 = diag(w_t) S_t + k_t^T v_t
    Returns (y: (T, V), new_state).
    """
    T = r.shape[0]
    ys = []
    S = state
    for t in range(T):
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]
        cur = (rt * (u * kt)).sum()[None] * vt  # bonus for current token
        y = rt @ S + cur
        S = wt[:, None] * S + kt[:, None] * vt[None, :]
        ys.append(y)
    return jnp.stack(ys), S
