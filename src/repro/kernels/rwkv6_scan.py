"""Pallas TPU kernel: chunked RWKV-6 linear-attention scan.

The attention-free hot loop of rwkv6 (and the long_500k decode path) is a
token-serial recurrence  S_t = diag(w_t)·S_{t-1} + k_tᵀv_t,
y_t = r_t·S_{t-1} + (r_t·(u⊙k_t))·v_t.  A CUDA port would run it one token per
thread-block; on TPU we *chunk* it so the intra-chunk part becomes two dense
matmuls on the MXU and only the chunk-boundary state is carried serially.

With exclusive in-chunk decay cumprod P_t = Π_{s<t} w_s:
  y  = tril_strict(R' K'ᵀ + diag(r·(u⊙k))) V + R' S₀
  R' = r ⊙ P,   K'_s = k_s / (P_s·w_s)
  S₁ = diag(P_end) S₀ + (k ⊙ P_end/(P·w))ᵀ V

Grid is (batch·heads, T/chunk); the running state lives in a VMEM scratch that
persists across the sequential chunk dimension of the grid.  f32 only — the
1/P term limits safe chunk sizes (default 32), matching public rwkv6 kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  y_ref, sout_ref, state, *, nchunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state[...] = s0_ref[0]

    r = r_ref[0]  # (C, K)
    k = k_ref[0]
    v = v_ref[0]  # (C, V)
    w = w_ref[0]
    u = u_ref[0]  # (1, K)

    S0 = state[...]
    C = r.shape[0]
    p_incl = jnp.cumprod(w, axis=0)           # P_t · w_t  (inclusive)
    p_excl = p_incl / w                       # P_t        (exclusive)
    r_p = r * p_excl
    k_p = k / p_incl
    scores = jnp.dot(r_p, k_p.T, preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    bonus = jnp.sum(r * (u * k), axis=-1)     # (C,)
    scores = jnp.where(si < ti, scores, 0.0)
    scores = scores + jnp.where(si == ti, bonus[:, None], 0.0)
    y = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    y = y + jnp.dot(r_p, S0, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    p_end = p_incl[-1]                        # (K,)
    k_end = k * (p_end / p_incl)
    S1 = p_end[:, None] * S0 + jnp.dot(
        k_end.T, v, preferred_element_type=jnp.float32)
    state[...] = S1

    @pl.when(j == nchunks - 1)
    def _fin():
        sout_ref[0] = S1.astype(sout_ref.dtype)


def rwkv6_scan(r, k, v, w, u, state, *, chunk: int = 32,
               interpret: bool = False):
    """Chunked rwkv6 recurrence over (BH, T, K/V) tensors.

    r,k,w: (BH,T,K)  v: (BH,T,V)  u: (BH,K)  state: (BH,K,V)
    Returns y: (BH,T,V), new_state: (BH,K,V).  T must be divisible by chunk.
    """
    BH, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nchunks = T // chunk
    grid = (BH, nchunks)

    seq = lambda i, j: (i, j, 0)
    full_head = lambda i, j: (i, 0, 0)
    y, sout = pl.pallas_call(
        functools.partial(_rwkv6_kernel, nchunks=nchunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), seq),
            pl.BlockSpec((1, chunk, K), seq),
            pl.BlockSpec((1, chunk, V), seq),
            pl.BlockSpec((1, chunk, K), seq),
            pl.BlockSpec((1, 1, K), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, K, V), full_head),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, V), seq),
            pl.BlockSpec((1, K, V), full_head),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), jnp.float32),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u.reshape(BH, 1, K), state)
    return y, sout
