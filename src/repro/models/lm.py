"""Unified LM backbone for all assigned architectures.

A model is head_blocks (unrolled) + a lax.scan over ``n_repeats`` copies of
``cfg.pattern`` (stacked params ⇒ compact HLO, O(1) compile cost in depth) +
a tail (pattern remainder, unrolled).  Block kinds: dense (attn+FFN), moe
(attn+MoE), mamba (Mamba2), rwkv (RWKV-6 time+channel mix), attn_only
(zamba2's shared attention block — params shared across repeats, caches not).

Masks (core.linearize) attach to every block's elementwise nonlinearity and
ride through the scan as stacked xs, so BCD candidate evaluation re-runs the
same compiled forward with different mask values — no recompilation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, Block
from repro.core import linearize
from . import layers, moe as moe_lib, ssm

# --------------------------------------------------------------- sub-configs


def _attn_cfg(cfg: ArchConfig, blk: Block) -> layers.AttnCfg:
    return layers.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qk_norm=cfg.qk_norm, window=blk.window,
        rope_theta=blk.rope_theta)


def _moe_cfg(cfg: ArchConfig) -> moe_lib.MoECfg:
    return moe_lib.MoECfg(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_ff_expert=cfg.d_ff_expert,
        n_shared=1 if cfg.n_shared_experts else 0,
        d_ff_shared=cfg.d_ff_shared, capacity_factor=cfg.capacity_factor,
        dispatch=cfg.moe_dispatch)


def _mamba_cfg(cfg: ArchConfig) -> ssm.MambaCfg:
    di = cfg.d_inner
    return ssm.MambaCfg(d_model=cfg.d_model, d_inner=di,
                        n_heads=di // cfg.mamba_head_dim,
                        head_dim=cfg.mamba_head_dim, d_state=cfg.ssm_state)


def _rwkv_cfg(cfg: ArchConfig) -> ssm.RWKVCfg:
    return ssm.RWKVCfg(d_model=cfg.d_model, d_ff=cfg.d_ff,
                       head_dim=cfg.rwkv_head_dim)


def _sub(tree: Dict, prefix: str) -> Dict:
    """Sub-tree of a ``"<prefix>.<suffix>"``-keyed dict, keys stripped."""
    return {k.split(".", 1)[1]: v for k, v in tree.items()
            if k.startswith(prefix + ".")}


def _register_barrier_rules():
    """``optimization_barrier`` ships without vmap/AD rules in jax 0.4.x;
    the fence sits on paths the candidate engines vmap (stacked masks) and
    training differentiates, so register the trivial ones: batching maps
    the barrier over the batched operands, and the JVP passes tangents
    through unfenced (the fence constrains compilation, not math)."""
    from jax.interpreters import ad, batching
    from jax._src.lax import lax as _lax
    p = getattr(_lax, "optimization_barrier_p", None)
    if p is None:                           # newer jax: rules built in
        return
    if p not in batching.primitive_batchers:
        batching.primitive_batchers[p] = lambda args, dims: (
            p.bind(*args), dims)
    if p not in ad.primitive_jvps:
        def _jvp(primals, tangents):
            outs = p.bind(*primals)
            tans = [jnp.zeros(o.shape, o.dtype)
                    if isinstance(t, ad.Zero) else t
                    for o, t in zip(outs, tangents)]
            return outs, tans
        ad.primitive_jvps[p] = _jvp


_register_barrier_rules()


def _fence(x):
    """Segment-boundary compilation fence (``lax.optimization_barrier``).

    Every split-forward cut point is a hard program boundary in the
    prefix/suffix jits, so the segment after it compiles in isolation
    there.  In the unsegmented forward the same boundary is an internal
    value that XLA freely fuses across (embed fold into the first head
    block, an unrolled trip-1 scan body into the final norm, …), which can
    change the compiled arithmetic by an ulp or two and break the bitwise
    ``prefix∘suffix == forward`` contract.  Fencing every segment boundary
    in EVERY path makes each segment compile in isolation everywhere, so
    the contract holds by construction.  The fence only blocks fusion
    across the (B, S, D) residual stream — which the residual adds
    materialize anyway — so it is free in practice."""
    return jax.lax.optimization_barrier(x)


def _positions(B: int, S: int, cache_len):
    """(B, S) absolute positions.  ``cache_len`` scalar: every row starts at
    the same offset (the one-shot serve path).  ``cache_len`` (B,): per-row
    offsets — continuous-batching decode, where each slot sits at its own
    sequence position."""
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:
        return jnp.arange(S)[None, :] + cl[:, None]
    return jnp.broadcast_to((jnp.arange(S) + cl)[None, :], (B, S))


def _sites_for(cfg: ArchConfig, blk: Block) -> Dict[str, linearize.MaskSite]:
    rep = cfg.act_when_masked
    if blk.kind == "dense":
        return {"ffn": linearize.MaskSite((cfg.d_ff,), cfg.act, rep)}
    if blk.kind == "moe":
        out = {"moe": linearize.MaskSite(
            (cfg.n_experts, cfg.d_ff_expert), cfg.act, rep)}
        if cfg.n_shared_experts:
            out["moe_shared"] = linearize.MaskSite(
                (cfg.d_ff_shared,), cfg.act, rep)
        return out
    if blk.kind == "mamba":
        return {"mamba": linearize.MaskSite((cfg.d_inner,), "silu", rep)}
    if blk.kind == "rwkv":
        return {"rwkv": linearize.MaskSite((cfg.d_ff,), "sqrelu", rep)}
    return {}


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        # Set by the step factories (train/serve): PartitionSpec for the
        # (B, S, D) activation stream.  GSPMD's fixpoint propagation drops the
        # batch sharding across while-loop (scan) carries, so we re-assert it
        # at the embed output and at every scan-body entry.
        self.activation_spec: Optional[P] = None

    def _constrain(self, x):
        if self.activation_spec is not None:
            return jax.lax.with_sharding_constraint(x, self.activation_spec)
        return x

    # ------------------------------------------------------------ init

    def _layer_init(self, key, blk: Block):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 2)
        d = cfg.d_model
        if blk.kind in ("dense", "moe", "attn_only"):
            p = {"ln1": layers.rmsnorm_init(d),
                 "attn": layers.attn_init(ks[0], _attn_cfg(cfg, blk), dt)}
            if blk.kind == "dense":
                p["ln2"] = layers.rmsnorm_init(d)
                p["ffn"] = layers.ffn_init(ks[1], d, cfg.d_ff,
                                           gated=cfg.gated_ffn, dtype=dt)
            elif blk.kind == "moe":
                p["ln2"] = layers.rmsnorm_init(d)
                p["moe"] = moe_lib.moe_init(ks[1], _moe_cfg(cfg), dt)
            return p
        if blk.kind == "mamba":
            return {"ln": layers.rmsnorm_init(d),
                    "mamba": ssm.mamba_init(ks[0], _mamba_cfg(cfg), dt)}
        if blk.kind == "rwkv":
            return {"ln1": layers.rmsnorm_init(d),
                    "ln2": layers.rmsnorm_init(d),
                    "tmix": ssm.rwkv_init(ks[0], _rwkv_cfg(cfg), dt)}
        raise ValueError(blk.kind)

    def init(self, key):
        cfg, dt = self.cfg, self.dtype
        ke, kh, kst, kt = jax.random.split(key, 4)
        params = {
            "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(dt),
            "final_norm": layers.rmsnorm_init(cfg.d_model),
            "head": [self._layer_init(jax.random.fold_in(kh, i), blk)
                     for i, blk in enumerate(cfg.head_blocks)],
            "tail": [self._layer_init(jax.random.fold_in(kt, i), blk)
                     for i, blk in enumerate(cfg.tail)],
        }
        stack = {}
        R = cfg.n_repeats
        for pos, blk in enumerate(cfg.pattern):
            kp = jax.random.fold_in(kst, pos)
            if blk.shared:
                stack[str(pos)] = self._layer_init(kp, blk)
            else:
                stack[str(pos)] = jax.vmap(
                    lambda k, blk=blk: self._layer_init(k, blk)
                )(jax.random.split(kp, R))
        params["stack"] = stack
        return params

    # ------------------------------------------------------------ masks

    def mask_sites(self) -> Dict[str, linearize.MaskSite]:
        cfg = self.cfg
        out = {}
        for i, blk in enumerate(cfg.head_blocks):
            for suf, site in _sites_for(cfg, blk).items():
                out[f"h{i}.{suf}"] = site
        for pos, blk in enumerate(cfg.pattern):
            for suf, site in _sites_for(cfg, blk).items():
                out[f"s{pos}.{suf}"] = dataclasses.replace(
                    site, shape=(cfg.n_repeats,) + site.shape)
        for i, blk in enumerate(cfg.tail):
            for suf, site in _sites_for(cfg, blk).items():
                out[f"t{i}.{suf}"] = site
        return out

    # unstacked site (per-layer) for use inside the scan body
    def _site(self, blk: Block, suf: str) -> linearize.MaskSite:
        return _sites_for(self.cfg, blk)[suf]

    # ------------------------------------------------------------ blocks

    def _layer_apply(self, blk: Block, p, x, msk, ply, soft, positions,
                     cache, cache_len):
        """One block.  msk/ply: dicts suffix->array (unstacked).  cache: dict
        or None.  Returns (x, new_cache)."""
        cfg = self.cfg
        newc = {} if cache is not None else None
        if blk.kind in ("dense", "moe", "attn_only"):
            h = layers.rmsnorm(p["ln1"], x)
            kv = None if cache is None else cache["kv"]
            a, kv2 = layers.attention(p["attn"], _attn_cfg(cfg, blk), h,
                                      positions, kv_cache=kv,
                                      cache_len=cache_len)
            x = x + a
            if cache is not None:
                newc["kv"] = kv2
            if blk.kind == "dense":
                h = layers.rmsnorm(p["ln2"], x)
                x = x + layers.ffn(p["ffn"], h, msk["ffn"],
                                   self._site(blk, "ffn"),
                                   poly=ply.get("ffn"), soft=soft)
            elif blk.kind == "moe":
                h = layers.rmsnorm(p["ln2"], x)
                mc = _moe_cfg(cfg)
                x = x + moe_lib.moe_ffn(
                    p["moe"], mc, h, msk["moe"], self._site(blk, "moe"),
                    shared_mask=msk.get("moe_shared"),
                    shared_site=(self._site(blk, "moe_shared")
                                 if cfg.n_shared_experts else None),
                    poly=ply.get("moe"), shared_poly=ply.get("moe_shared"),
                    soft=soft, act_spec=self.activation_spec)
            return x, newc
        if blk.kind == "mamba":
            h = layers.rmsnorm(p["ln"], x)
            c = None if cache is None else (cache["ssm"], cache["conv"])
            y, c2 = ssm.mamba_block(p["mamba"], _mamba_cfg(cfg), h,
                                    msk["mamba"], self._site(blk, "mamba"),
                                    poly=ply.get("mamba"), soft=soft, cache=c)
            if cache is not None:
                newc["ssm"], newc["conv"] = c2
            return x + y, newc
        if blk.kind == "rwkv":
            rc = _rwkv_cfg(cfg)
            h = layers.rmsnorm(p["ln1"], x)
            c = None if cache is None else (cache["state"], cache["ptm"])
            y, c2 = ssm.rwkv_time_mix(p["tmix"], rc, h, cache=c)
            x = x + y
            if cache is not None:
                newc["state"], newc["ptm"] = c2
            h = layers.rmsnorm(p["ln2"], x)
            c = None if cache is None else cache["pcm"]
            y, c2 = ssm.rwkv_channel_mix(p["tmix"], rc, h, msk["rwkv"],
                                         self._site(blk, "rwkv"),
                                         poly=ply.get("rwkv"), soft=soft,
                                         cache=c)
            if cache is not None:
                newc["pcm"] = c2
            return x + y, newc
        raise ValueError(blk.kind)

    # ------------------------------------------------------------ forward

    def _run_stack(self, params, masks, x, positions, *, poly, soft,
                   cache=None, cache_len=0, remat=False, lo_repeat=0,
                   hi_repeat=None):
        """The scanned repeat stack: returns (x, scanned_cache).

        Shared verbatim by :meth:`forward` and the split forwards
        (:meth:`forward_prefix` / :meth:`forward_suffix`), so both trace
        the identical scan — the bitwise split-forward contract depends on
        it.

        ``lo_repeat``/``hi_repeat`` run only scan repeats ``[lo, hi)`` —
        the split forwards' per-repeat carry checkpoints.  The slice of the
        stacked xs is static (Python ints), so the scan body traces
        identically to the full run, and the handoff at a repeat boundary
        is bitwise: ``lax.scan`` materializes the carry between iterations
        either way, so running repeats ``[0, r)`` then ``[r, R)`` from the
        returned carry replays the exact per-iteration math of ``[0, R)``.
        In the eval path (``cache=None``) the carry IS the (B, S, D) hidden
        state — the repeat-r checkpoint is an ordinary boundary activation.
        """
        cfg = self.cfg
        pattern = cfg.pattern
        R = cfg.n_repeats
        hi_repeat = R if hi_repeat is None else hi_repeat
        xs = {"params": {str(p): params["stack"][str(p)]
                         for p, blk in enumerate(pattern) if not blk.shared},
              "masks": {f"s{p}.{suf}": masks[f"s{p}.{suf}"]
                        for p, blk in enumerate(pattern)
                        for suf in _sites_for(cfg, blk)},
              # stacked poly arrive as (3, R, ·) — scan slices dim 0, so
              # move R first: (R, 3, ·)
              "poly": {k: jnp.moveaxis(v, 1, 0)
                       for k, v in poly.items() if k.startswith("s")}}
        if cache is not None:
            xs["cache"] = cache["stack"]
        if lo_repeat > 0 or hi_repeat < R:
            xs = jax.tree.map(lambda a: a[lo_repeat:hi_repeat], xs)
        # XLA unrolls trip-count-1 while loops and then fuses the inlined
        # body with surrounding ops (embed fold, final norm), changing the
        # arithmetic vs a multi-trip loop whose body compiles in isolation.
        # Fencing the carry forces a sliced (possibly single-repeat) scan
        # to compile in isolation too, keeping mid-scan prefix∘suffix
        # bitwise-equal to the unsegmented forward.  For a multi-trip scan
        # the loop boundary already isolates the body, so the fence is a
        # no-op there.
        x = _fence(x)
        R = hi_repeat - lo_repeat

        def body(x, sl):
            x = self._constrain(x)
            newcs = {}
            for p, blk in enumerate(pattern):
                lp = (params["stack"][str(p)] if blk.shared
                      else sl["params"][str(p)])
                msk = _sub(sl["masks"], f"s{p}")
                pl = _sub(sl["poly"], f"s{p}")
                c = sl["cache"][str(p)] if cache is not None else None
                x, nc = self._layer_apply(blk, lp, x, msk, pl, soft,
                                          positions, c, cache_len)
                newcs[str(p)] = nc
            return x, (newcs if cache is not None else None)

        G = self.cfg.remat_group
        if remat and cache is None and G > 1 and R % G == 0:
            # Hierarchical remat: outer scan over R/G groups saves only
            # group-boundary activations (G× less stacked-carry memory);
            # the group forward is recomputed (with per-layer inner remat)
            # during backward.  See EXPERIMENTS.md §Perf.
            xsG = jax.tree.map(
                lambda a: a.reshape((R // G, G) + a.shape[1:]), xs)
            inner = jax.checkpoint(body)

            def group_body(x, slG):
                for g in range(G):
                    x, _ = inner(x, jax.tree.map(lambda a: a[g], slG))
                return x, None

            out, scanned = jax.lax.scan(jax.checkpoint(group_body), x, xsG)
            return _fence(out), scanned
        body_fn = jax.checkpoint(body) if remat else body
        out, scanned = jax.lax.scan(body_fn, x, xs)
        return _fence(out), scanned

    def forward(self, params, masks, tokens, *, prefix_embeds=None,
                poly=None, soft=False, cache=None, cache_len=0, remat=False,
                return_hidden=False, pre=None):
        """Returns (logits (B,S,V), new_cache); with return_hidden=True the
        first element is the final-norm hidden state (B,S,D) instead (the
        caller owns the head matmul — e.g. chunked CE, §Perf).

        ``pre``: a cached :meth:`forward_pre` result (the mask-independent
        embed fold) — the fold resumes after segment 0 and ``tokens`` is
        only consumed for its length.  Eval-path only (mutually exclusive
        with ``prefix_embeds``)."""
        cfg = self.cfg
        poly = poly or {}
        if pre is not None:
            x = pre
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
            if prefix_embeds is not None:
                x = jnp.concatenate([prefix_embeds.astype(x.dtype), x],
                                    axis=1)
            x = self._constrain(x)
        B, S, _ = x.shape
        positions = _positions(B, S, cache_len)

        new_cache = {"head": [], "stack": {}, "tail": []} \
            if cache is not None else None

        for i, blk in enumerate(cfg.head_blocks):
            c = None if cache is None else cache["head"][i]
            x, nc = self._layer_apply(blk, params["head"][i], _fence(x),
                                      _sub(masks, f"h{i}"),
                                      _sub(poly, f"h{i}"), soft,
                                      positions, c, cache_len)
            if cache is not None:
                new_cache["head"].append(nc)

        x, scanned_cache = self._run_stack(
            params, masks, x, positions, poly=poly, soft=soft, cache=cache,
            cache_len=cache_len, remat=remat)
        if cache is not None:
            new_cache["stack"] = scanned_cache

        for i, blk in enumerate(cfg.tail):
            c = None if cache is None else cache["tail"][i]
            x, nc = self._layer_apply(blk, params["tail"][i], _fence(x),
                                      _sub(masks, f"t{i}"),
                                      _sub(poly, f"t{i}"), soft,
                                      positions, c, cache_len)
            if cache is not None:
                new_cache["tail"].append(nc)

        x = layers.rmsnorm(params["final_norm"], x)
        if return_hidden:
            return x, new_cache
        logits = x @ params["embed"].T.astype(x.dtype)
        return logits, new_cache

    # ------------------------------------------------------- split forward
    #
    # Segment boundaries for prefix-reuse candidate evaluation
    # (core.engine.SuffixEvaluator): embed | head block i … | stack repeat 0
    # … stack repeat R-1 | tail block i … | final norm + logits.  The
    # scanned stack contributes one segment PER REPEAT: the eval-path scan
    # carry is exactly the (B, S, D) hidden state, so the repeat-r boundary
    # is a carry checkpoint — forward_prefix stops the scan after repeat
    # r-1 and forward_suffix resumes it from the cached carry instead of
    # re-running the whole stack.  Stack sites are addressed two ways: the
    # REAL mask name ("s0.ffn" — the key in the mask tree, whose (R, ·)
    # array spans every repeat) maps to its repeat-0 segment (the
    # shallowest cut its coordinates can force), while virtual
    # repeat-qualified names ("s0.ffn@r") address the per-repeat segments.
    # site_order lists the virtual names; grouping resolves each candidate
    # coordinate's true repeat row arithmetically
    # (masks.group_blocks_by_site repeat_sites=).  The split forwards reuse
    # _layer_apply and _run_stack verbatim, so suffix(prefix(x)) traces the
    # same primitives as forward(x) (eval path: no cache / remat /
    # prefix_embeds).

    def _segment_of_site(self) -> Dict[str, int]:
        cfg = self.cfg
        H = len(cfg.head_blocks)
        R = cfg.n_repeats
        out = {}
        for i, blk in enumerate(cfg.head_blocks):
            for suf in _sites_for(cfg, blk):
                out[f"h{i}.{suf}"] = 1 + i
        for pos, blk in enumerate(cfg.pattern):
            for suf in _sites_for(cfg, blk):
                out[f"s{pos}.{suf}"] = 1 + H
                for r in range(R):
                    out[f"s{pos}.{suf}@{r}"] = 1 + H + r
        for i, blk in enumerate(cfg.tail):
            for suf in _sites_for(cfg, blk):
                out[f"t{i}.{suf}"] = 1 + H + R + i
        return out

    def site_repeats(self) -> Dict[str, int]:
        """Real stack mask name -> scan repeat count its (R, ·) array spans.

        The repeat-aware grouping contract (``masks.group_blocks_by_site``
        ``repeat_sites=``): a stack site's per-repeat segments are
        consecutive from its base (repeat-0) segment, and its flat mask
        coordinates are laid out repeat-major, so a coordinate's segment is
        ``base + local_offset // (size // R)``."""
        cfg = self.cfg
        return {f"s{pos}.{suf}": cfg.n_repeats
                for pos, blk in enumerate(cfg.pattern)
                for suf in _sites_for(cfg, blk)}

    def site_order(self) -> Tuple[str, ...]:
        """All mask sites in forward (topological) order.

        Stack sites appear once per scan repeat under their virtual
        repeat-qualified name (``"s0.ffn@1"``); head/tail sites under their
        real name.  Real stack names are deliberately absent — each segment
        gets exactly one representative, and the engine's per-segment jits
        key off the names listed here."""
        seg = self._segment_of_site()
        reps = self.site_repeats()
        return tuple(sorted((s for s in seg if s not in reps),
                            key=lambda s: (seg[s], s)))

    def site_segments(self) -> Dict[str, int]:
        """site -> segment index (sites sharing a segment share a prefix).

        Contains BOTH namings of stack sites: real mask names at their
        repeat-0 segment (mask-tree diffing, grouping rank lookups) and
        virtual ``@r`` names at repeat r's segment (prefix/suffix cuts)."""
        return self._segment_of_site()

    def suffix_sites(self, site: str) -> Tuple[str, ...]:
        """Real mask names consumed by :meth:`forward_suffix` for this cut.

        These are the keys the engine slices candidate stacked trees by, so
        only real (mask-tree) names appear.  A real site is included iff
        its DEEPEST segment is at/after the cut — a stack site's (R, ·)
        array reaches repeat R-1, so a cut at any repeat ships the full
        stack arrays (rows before the cut repeat ride along but are never
        read: the suffix statically slices the scan xs)."""
        seg = self._segment_of_site()
        cut = seg[site]
        reps = self.site_repeats()

        def deepest(s):
            return seg[s] + (reps[s] - 1 if s in reps else 0)
        return tuple(s for s in sorted((k for k in seg if "@" not in k),
                                       key=lambda s: (seg[s], s))
                     if deepest(s) >= cut)

    def forward_prefix(self, params, masks, tokens, site, *, poly=None,
                       soft=False, from_site=None, cached=None):
        """Forward up to (excluding) the segment applying ``site``; returns
        the cached (B, S, D) boundary hidden state.

        A stack cut at repeat r (virtual site ``"s0.ffn@r"``) stops the
        scan after repeat r-1; the returned hidden state is the scan carry
        at that boundary (the eval-path carry IS the (B, S, D) activation),
        so the trie stores and extends carry checkpoints like any other
        prefix — including repeat-to-repeat extension.

        Multi-depth entry: ``from_site``/``cached`` resume from an earlier
        prefix's boundary state instead of the token embedding, folding
        only segments in ``[seg(from_site), seg(site))`` — the prefix-trie
        extension contract (``prefix_ext(a, b, m, prefix(a)) ==
        prefix(b)``, same fold over the same segment list)."""
        cfg = self.cfg
        poly = poly or {}
        seg = self._segment_of_site()
        cut = seg[site]
        lo = 0 if from_site is None else seg[from_site]
        H = len(cfg.head_blocks)
        R = cfg.n_repeats
        if from_site is None:
            x = jnp.take(params["embed"], tokens, axis=0)
            x = self._constrain(x)
        else:
            x = cached
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        for i, blk in enumerate(cfg.head_blocks):
            if 1 + i >= cut:
                break
            if 1 + i < lo:
                continue
            x, _ = self._layer_apply(blk, params["head"][i], _fence(x),
                                     _sub(masks, f"h{i}"),
                                     _sub(poly, f"h{i}"), soft,
                                     positions, None, 0)
        # repeats whose segment 1+H+r lies in [lo, cut)
        lo_r = min(max(lo - (1 + H), 0), R)
        hi_r = min(max(cut - (1 + H), 0), R)
        if hi_r > lo_r:
            x, _ = self._run_stack(params, masks, x, positions, poly=poly,
                                   soft=soft, lo_repeat=lo_r, hi_repeat=hi_r)
        for i, blk in enumerate(cfg.tail):
            if 1 + H + R + i >= cut:
                break
            if 1 + H + R + i < lo:
                continue
            x, _ = self._layer_apply(blk, params["tail"][i], _fence(x),
                                     _sub(masks, f"t{i}"),
                                     _sub(poly, f"t{i}"), soft,
                                     positions, None, 0)
        return x

    def forward_pre(self, params, tokens):
        """Mask-independent head of the network: the segment-0 embed fold
        (token embedding + constraint).  Computed once per evaluator
        context and fed back through ``forward(..., pre=...)`` — the
        "depth-0 prefix" every candidate shares."""
        return self._constrain(jnp.take(params["embed"], tokens, axis=0))

    def forward_suffix(self, params, masks, cached, site, *, poly=None,
                       soft=False):
        """Finish forward from a :meth:`forward_prefix` cache -> logits.

        For a stack cut at repeat r the scan RESUMES from the cached carry
        (repeats ``[r, R)`` only) — a mid-scan candidate no longer re-runs
        the whole stack."""
        cfg = self.cfg
        poly = poly or {}
        cut = self._segment_of_site()[site]
        H = len(cfg.head_blocks)
        R = cfg.n_repeats
        x = cached
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        for i, blk in enumerate(cfg.head_blocks):
            if 1 + i < cut:
                continue
            x, _ = self._layer_apply(blk, params["head"][i], _fence(x),
                                     _sub(masks, f"h{i}"),
                                     _sub(poly, f"h{i}"), soft,
                                     positions, None, 0)
        lo_r = min(max(cut - (1 + H), 0), R)
        if lo_r < R:
            x, _ = self._run_stack(params, masks, x, positions, poly=poly,
                                   soft=soft, lo_repeat=lo_r, hi_repeat=R)
        for i, blk in enumerate(cfg.tail):
            if 1 + H + R + i < cut:
                continue
            x, _ = self._layer_apply(blk, params["tail"][i], _fence(x),
                                     _sub(masks, f"t{i}"),
                                     _sub(poly, f"t{i}"), soft,
                                     positions, None, 0)
        x = layers.rmsnorm(params["final_norm"], x)
        return x @ params["embed"].T.astype(x.dtype)

    def site_prefix_fractions(self, *, seq_len: int = 64) -> Dict[str, float]:
        """site -> fraction of forward FLOPs strictly before its segment.

        Analytic (roofline.lm_segment_fwd_flops, prefill mode, per-sample);
        the suffix cost model thresholds on it.  ``seq_len`` only matters
        through the attention quadratic term.  Keyed by BOTH namings of
        stack sites: the real mask name carries its repeat-0 (shallowest)
        fraction, virtual ``@r`` names the per-repeat fractions."""
        from repro.analysis import roofline
        # per-segment flops: embed(≈0) | head… | stack repeat 0 … R-1 |
        # tail… | logits (one entry PER scan repeat, MoE at true padded
        # slot capacity)
        seg_flops = roofline.lm_segment_fwd_flops(self.cfg, seq_len=seq_len)
        total = max(sum(seg_flops), 1.0)
        before, cum = [], 0.0
        for v in seg_flops:
            before.append(cum / total)
            cum += v
        return {s: before[i] for s, i in self._segment_of_site().items()}

    def make_suffix_eval_fns(self):
        """Split-forward closure bundle for ``engine.SuffixEvaluator`` —
        same contract as ``CNN.make_suffix_eval_fns`` (ctx = {"params",
        "batch"}; the metric is next-token accuracy [%])."""
        from repro.core import engine

        def prefix_fn(site, masks, ctx):
            return self.forward_prefix(ctx["params"], masks,
                                       ctx["batch"]["tokens"][:, :-1], site)

        def prefix_ext_fn(from_site, site, masks, cached, ctx):
            return self.forward_prefix(ctx["params"], masks,
                                       ctx["batch"]["tokens"][:, :-1], site,
                                       from_site=from_site, cached=cached)

        def suffix_fn(site, masks, cached, ctx):
            logits = self.forward_suffix(ctx["params"], masks, cached, site)
            pred = jnp.argmax(logits, -1)
            return jnp.mean((pred == ctx["batch"]["tokens"][:, 1:])
                            .astype(jnp.float32)) * 100.0

        def pre_fn(ctx):
            return self.forward_pre(ctx["params"],
                                    ctx["batch"]["tokens"][:, :-1])

        return engine.SplitEval(
            prefix=prefix_fn, suffix=suffix_fn,
            full=self.make_joint_eval_fn(),
            site_order=self.site_order(),
            site_segment=self.site_segments(),
            suffix_sites=self.suffix_sites,
            prefix_fraction=self.site_prefix_fractions(),
            prefix_ext=prefix_ext_fn,
            pre=pre_fn,
            site_repeats=self.site_repeats())

    # ------------------------------------------------------- eval closures
    #
    # Same contract as models.resnet.CNN: a traceable single-mask-tree
    # closure for the batched/sharded BCD candidate engines (vmapped over
    # the candidate axis) and a host-callable wrapper for sequential use.
    # The metric is next-token accuracy [%] on a fixed token batch — masks
    # ride through the scanned stack as jit inputs, so candidate evaluation
    # never recompiles.

    def make_param_eval_fn(self, batch):
        """Traceable ``(mask_tree, params) -> accuracy[%]`` — params as an
        evaluator context (jit input), for finetuning-between-steps runs."""
        tokens = jnp.asarray(batch["tokens"])

        def eval_fn(masks, params):
            logits, _ = self.forward(params, masks, tokens[:, :-1])
            pred = jnp.argmax(logits, -1)
            return jnp.mean((pred == tokens[:, 1:])
                            .astype(jnp.float32)) * 100.0
        return eval_fn

    def make_eval_fn(self, params, batch):
        fn = self.make_param_eval_fn(batch)
        return lambda masks: fn(masks, params)

    def make_joint_eval_fn(self):
        """Traceable ``(mask_tree, ctx) -> accuracy[%]`` with
        ``ctx = {"params": ..., "batch": ...}`` — same contract as
        ``CNN.make_joint_eval_fn``: the token batch is evaluator context, so
        on a ``("cand", "batch")`` mesh the eval batch shards over
        ``"batch"`` while candidates shard over ``"cand"`` (joint layout for
        trial chunks smaller than the device count)."""
        def eval_fn(masks, ctx):
            tokens = ctx["batch"]["tokens"]
            # "pre" (optional): the mask-independent embed fold, computed
            # once per context by the evaluator (SplitEval.pre)
            logits, _ = self.forward(ctx["params"], masks, tokens[:, :-1],
                                     pre=ctx.get("pre"))
            pred = jnp.argmax(logits, -1)
            return jnp.mean((pred == tokens[:, 1:])
                            .astype(jnp.float32)) * 100.0
        return eval_fn

    def make_eval_acc(self, params, batch):
        from repro.core import masks as M
        fn = jax.jit(self.make_eval_fn(params, batch))
        return lambda masks: float(fn(M.as_device(masks)))

    # ------------------------------------------------------------ cache

    def _layer_cache(self, blk: Block, B: int, max_len: int):
        cfg, dt = self.cfg, self.dtype
        if blk.kind in ("dense", "moe", "attn_only"):
            kv_shape = (B, max_len, cfg.n_kv_heads, cfg.head_dim)
            return {"kv": (jnp.zeros(kv_shape, dt), jnp.zeros(kv_shape, dt))}
        if blk.kind == "mamba":
            mc = _mamba_cfg(cfg)
            return {"ssm": jnp.zeros((B, mc.n_heads, mc.d_state, mc.head_dim),
                                     jnp.float32),
                    "conv": jnp.zeros((B, mc.d_conv - 1, mc.d_inner), dt)}
        if blk.kind == "rwkv":
            rc = _rwkv_cfg(cfg)
            return {"state": jnp.zeros((B, rc.n_heads, rc.head_dim,
                                        rc.head_dim), jnp.float32),
                    "ptm": jnp.zeros((B, cfg.d_model), dt),
                    "pcm": jnp.zeros((B, cfg.d_model), dt)}
        raise ValueError(blk.kind)

    def init_cache(self, B: int, max_len: int):
        cfg = self.cfg
        R = cfg.n_repeats
        stack = {}
        for pos, blk in enumerate(cfg.pattern):
            one = self._layer_cache(blk, B, max_len)
            stack[str(pos)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (R,) + a.shape), one)
        return {"head": [self._layer_cache(b, B, max_len)
                         for b in cfg.head_blocks],
                "stack": stack,
                "tail": [self._layer_cache(b, B, max_len)
                         for b in cfg.tail]}


# =================================================================== specs

_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_ck", "w_cr", "w_r", "w_k",
        "w_v", "w_g", "w_w", "w_z", "w_x"}    # (..., in, out): TP on out
_ROW = {"wo", "w_down", "w_out", "w_o", "w_cv"}  # (..., in, out): TP on in
_FSDP_ONLY = {"router", "w_bcdt"}


def _leaf_spec(name: str, shape, data: int, model: int,
               fsdp: bool = True) -> P:
    nd = len(shape)

    def ok(dim_idx, axis_size):
        return shape[dim_idx] % axis_size == 0

    if name == "embed":
        return P("model" if ok(0, model) else None, None)
    if name in _COL:
        sp = ["data" if fsdp and ok(nd - 2, data) else None,
              "model" if ok(nd - 1, model) else None]
    elif name in _ROW:
        sp = ["model" if ok(nd - 2, model) else None,
              "data" if fsdp and ok(nd - 1, data) else None]
    elif name in _FSDP_ONLY:
        sp = ["data" if fsdp and ok(nd - 2, data) else None, None]
    elif name == "conv":
        sp = [None, "model" if ok(nd - 1, model) else None]
    else:
        return P()
    return P(*([None] * (nd - 2) + sp))


def param_specs(params_shape, data: int, model: int, fsdp: bool = True):
    """PartitionSpec tree mirroring the params tree (rule-based on leaf name).

    data/model: mesh axis sizes (for divisibility checks).  fsdp=False turns
    off the ZeRO-3 'data'-axis weight sharding (pure TP baseline).
    """
    def f(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        return _leaf_spec(name, leaf.shape, data, model, fsdp)
    return jax.tree_util.tree_map_with_path(f, params_shape)


def cache_specs(cache_shape, dp_axes: Tuple[str, ...], B: int, data: int,
                model: int, shard_seq: bool = False):
    """Sharding for decode caches.  KV caches: batch over dp axes (or the
    sequence axis over 'data' when B == 1, long_500k), heads/state over
    'model' when divisible."""
    def f(path, leaf):
        nd = len(leaf.shape)
        if nd >= 3:  # kv (B,S,KV,hd) | ssm (B,nh,N,hd) | state (B,H,hd,hd)
            batch_ok = B % (data) == 0 and B >= data
            sp = [dp_axes if batch_ok and leaf.shape[0] % data == 0 else None]
            if nd == 4 and leaf.shape[1] > 4096:      # kv cache: (B,S,KV,hd)
                sp.append("data" if (shard_seq and not batch_ok and
                                     leaf.shape[1] % data == 0) else None)
                sp.append("model" if leaf.shape[2] % model == 0 else None)
                sp.append(None if leaf.shape[2] % model == 0 else
                          ("model" if leaf.shape[3] % model == 0 else None))
            else:
                sp.append("model" if leaf.shape[1] % model == 0 else None)
                sp += [None] * (nd - 2)
            return P(*sp)
        if nd == 2:   # prev-token (B,d)
            return P(dp_axes if leaf.shape[0] % data == 0 and B >= data
                     else None,
                     "model" if leaf.shape[1] % model == 0 else None)
        return P()
    return jax.tree_util.tree_map_with_path(f, cache_shape)
