"""Shared transformer layers: norms, RoPE, GQA attention, gated FFN.

All functions are pure: params are nested dicts of jnp arrays; mask trees ride
alongside (core.linearize).  Attention is q-chunked (flash-style, full-row
softmax per chunk) above a sequence threshold so 32k prefills never
materialize (S, S) score tensors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linearize

# ---------------------------------------------------------------- norms


def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    # variance reduce in f32, but scale applied in the stream dtype: keeps the
    # full-tensor f32 copy out of the HLO (XLA hoists convert(saved_stack)
    # out of the backward while-loop otherwise — 2× activation memory).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


# ---------------------------------------------------------------- rope


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) *
                    jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]   # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    window: Optional[int] = None        # sliding-window size (None = full)
    rope_theta: float = 1e4
    q_chunk: int = 2048                 # chunk queries above this seq len


def attn_init(key, c: AttnCfg, dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = c.d_model, c.n_heads, c.n_kv_heads, c.head_dim
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(kq, (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, kvh * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, kvh * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (h * hd, d)) * (h * hd) ** -0.5
               ).astype(dtype),
    }
    if c.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _attend(q, k, v, *, causal_offset, window, scale):
    """q: (B,Sq,H,hd) k,v: (B,Sk,KV,hd). causal_offset = abs pos of q[0] - abs
    pos of k[0] (so query i attends keys j with j <= i + causal_offset).
    causal_offset may be a (B,) vector — per-row offsets for continuous
    batching, where each batch slot sits at its own decode position."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qh = q.reshape(B, Sq, KV, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qh, k).astype(jnp.float32)
    scores = scores * scale
    co = jnp.asarray(causal_offset)
    kj = jnp.arange(k.shape[1])[None, :]
    if co.ndim == 1:
        qi = jnp.arange(Sq)[None, :, None] + co[:, None, None]  # (B,Sq,1)
        mask = kj[None] <= qi
        if window is not None:
            mask &= kj[None] > qi - window
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    else:
        qi = jnp.arange(Sq)[:, None] + causal_offset
        mask = kj <= qi
        if window is not None:
            mask &= kj > qi - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(B, Sq, H, hd)


def attention(p, c: AttnCfg, x, positions, *, kv_cache=None, cache_len=None):
    """Self-attention.  Training/prefill: kv_cache None -> causal over x.
    Decode: kv_cache=(K,V) (B,Smax,KV,hd) updated at cache_len (static-shape
    dynamic_update_slice); returns (out, new_cache).  ``cache_len`` may be a
    (B,) vector — continuous-batching decode, where every slot writes and
    attends at its own offset (per-row scatter + per-row causal mask)."""
    B, S, d = x.shape
    h, kvh, hd = c.n_heads, c.n_kv_heads, c.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = (x @ p["wk"]).reshape(B, S, kvh, hd)
    v = (x @ p["wv"]).reshape(B, S, kvh, hd)
    if c.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = rope(q, positions, c.rope_theta)
    k = rope(k, positions, c.rope_theta)
    scale = hd ** -0.5

    if kv_cache is not None:
        K, V = kv_cache
        cl = jnp.asarray(cache_len)
        if cl.ndim == 1:
            b = jnp.arange(B)[:, None]
            pos = cl[:, None] + jnp.arange(S)[None, :]       # (B, S)
            K = K.at[b, pos].set(k.astype(K.dtype))
            V = V.at[b, pos].set(v.astype(V.dtype))
            kj = jnp.arange(K.shape[1])
            valid = kj[None, :] < (cl + S)[:, None]          # (B, Sk)
            out = _attend(q, jnp.where(valid[:, :, None, None], K, 0),
                          jnp.where(valid[:, :, None, None], V, 0),
                          causal_offset=cl, window=c.window, scale=scale)
            return (out.reshape(B, S, h * hd) @ p["wo"]), (K, V)
        K = jax.lax.dynamic_update_slice(K, k.astype(K.dtype), (0, cache_len, 0, 0))
        V = jax.lax.dynamic_update_slice(V, v.astype(V.dtype), (0, cache_len, 0, 0))
        # mask out cache positions beyond cache_len + S
        kj = jnp.arange(K.shape[1])
        valid = kj < cache_len + S
        out = _attend(q, jnp.where(valid[None, :, None, None], K, 0),
                      jnp.where(valid[None, :, None, None], V, 0),
                      causal_offset=cache_len, window=c.window, scale=scale)
        # invalid keys masked via causal_offset anyway (kj <= i + cache_len)
        out = out.reshape(B, S, h * hd)
        return (out @ p["wo"]), (K, V)

    if S <= c.q_chunk:
        out = _attend(q, k, v, causal_offset=0, window=c.window, scale=scale)
    else:
        assert S % c.q_chunk == 0, (S, c.q_chunk)
        nch = S // c.q_chunk
        qs = q.reshape(B, nch, c.q_chunk, h, hd)

        def chunk(i, q_i):
            return _attend(q_i, k, v, causal_offset=i * c.q_chunk,
                           window=c.window, scale=scale)
        out = jax.lax.map(lambda args: chunk(*args),
                          (jnp.arange(nch), qs.swapaxes(0, 1)))
        out = out.swapaxes(0, 1).reshape(B, S, h, hd)
    out = out.reshape(B, S, h * hd)
    return out @ p["wo"], None


# ---------------------------------------------------------------- gated FFN


def ffn_init(key, d, f, *, gated=True, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    s = d ** -0.5
    p = {"w_up": (jax.random.normal(k1, (d, f)) * s).astype(dtype),
         "w_down": (jax.random.normal(k2, (f, d)) * f ** -0.5).astype(dtype)}
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * s).astype(dtype)
    return p


def ffn(p, x, mask, site: linearize.MaskSite, *, poly=None, soft=False):
    """Gated (SwiGLU-style) or plain FFN with the *masked* activation.

    Masked semantics: act(h) at kept channels, identity (or poly2) at
    linearized channels; for gated FFNs the gate branch activation is the
    mask site (matching DESIGN §4).
    """
    h = x @ (p["w_gate"] if "w_gate" in p else p["w_up"])
    mode = linearize.fused_route_mode()
    if mode is not None and not soft and poly is None:
        # Suffix-engine tracing: gate [· up-branch] · w_down as one Pallas
        # megakernel — the gated (B, S, F) tensor never round-trips HBM
        # between the mask select and the down-projection.
        from repro.kernels import ops
        interpret = mode == "interpret"
        if interpret or ops.fused_dispatch_enabled():
            mul = (x @ p["w_up"]) if "w_gate" in p else None
            return ops.masked_act_matmul_routed(
                h, mask, p["w_down"], mul, kind=site.kind,
                interpret=interpret)
    a = linearize.apply_masked_act(h, mask, site, poly=poly, soft=soft)
    if "w_gate" in p:
        a = a * (x @ p["w_up"])
    return a @ p["w_down"]
