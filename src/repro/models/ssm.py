"""State-space / linear-attention blocks: Mamba2 (SSD) and RWKV-6 (Finch).

Both are expressed as *chunked linear attention* — the TPU-native adaptation
of the token-serial CUDA recurrences (DESIGN §3): intra-chunk work is dense
einsums on the MXU, only chunk-boundary states are carried by lax.scan.
The decode path is the exact O(1)-state recurrence (long_500k cells).

State locality (the split-forward contract these blocks must keep): all
recurrent state — the linear-attention state carried over sequence chunks,
the token-shift left-neighbor — lives WITHIN one block application and is
re-initialized from zeros (prefill) or the decode cache on every call.
Nothing recurrent crosses stack repeats: the only value a repeat hands the
next one is the (B, S, D) residual stream, which is exactly the carry of
``lm.LM._run_stack``'s repeat scan.  That is what makes a mid-scan cut a
plain carry checkpoint — ``forward_suffix`` can resume the stack at repeat
r from the cached hidden state without replaying any per-block recurrence.
A block that carried sequence state across repeats would silently break
the bitwise ``prefix∘suffix == forward`` contract (tests: family cuts in
``tests/test_split_forward.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import linearize
from . import layers


def linattn_chunked(r, k, v, w, u, s0, *, chunk: int, decay_first=False):
    """Generalized decayed linear attention, chunked.

    decay_first=False (RWKV convention):
      y_t = r_t·S_{t-1} + (r·(u⊙k))·v_t ;  S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t
    decay_first=True (Mamba2/SSD convention):
      S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t ;  y_t = r_t·S_t        (u ignored)
    r,k,w: (B,H,T,K)  v: (B,H,T,Vd)  u: (H,K) or None  s0: (B,H,K,Vd).
    Returns y (B,H,T,Vd), S_end.  T % chunk == 0.
    """
    B, H, T, K = r.shape
    Vd = v.shape[-1]
    n = T // chunk
    rc = r.reshape(B, H, n, chunk, K)
    kc = k.reshape(B, H, n, chunk, K)
    vc = v.reshape(B, H, n, chunk, Vd)
    wc = w.reshape(B, H, n, chunk, K)

    ti = jnp.arange(chunk)[:, None]
    si = jnp.arange(chunk)[None, :]
    tri = (si <= ti) if decay_first else (si < ti)

    def step(S, xs):
        rj, kj, vj, wj = xs  # (B,H,chunk,·)
        p_incl = jnp.cumprod(wj, axis=2)
        r_p = rj * (p_incl if decay_first else p_incl / wj)
        k_p = kj / p_incl
        scores = jnp.einsum("bhik,bhjk->bhij", r_p, k_p)
        scores = jnp.where(tri[None, None], scores, 0.0)
        if u is not None and not decay_first:
            bonus = jnp.einsum("bhik,hk,bhik->bhi", rj, u, kj)
            scores = scores + bonus[..., None] * jnp.eye(chunk)[None, None]
        y = jnp.einsum("bhij,bhjv->bhiv", scores, vj)
        y = y + jnp.einsum("bhik,bhkv->bhiv", r_p, S)
        p_end = p_incl[:, :, -1]
        k_end = kj * (p_end[:, :, None, :] / p_incl)
        S1 = p_end[..., None] * S + jnp.einsum("bhjk,bhjv->bhkv", k_end, vj)
        return S1, y

    xs = (rc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
          vc.transpose(2, 0, 1, 3, 4), wc.transpose(2, 0, 1, 3, 4))
    S_end, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, T, Vd)
    return y.astype(r.dtype), S_end


def linattn_step(r, k, v, w, u, S, decay_first=False):
    """Single-token decode: shapes r,k,w (B,H,K), v (B,H,Vd), S (B,H,K,Vd)."""
    if decay_first:
        S = w[..., None] * S + k[..., None] * v[..., None, :]
        return jnp.einsum("bhk,bhkv->bhv", r, S), S
    y = jnp.einsum("bhk,bhkv->bhv", r, S)
    if u is not None:
        y = y + jnp.einsum("bhk,hk,bhk->bh", r, u, k)[..., None] * v
    S = w[..., None] * S + k[..., None] * v[..., None, :]
    return y, S


# ================================================================= Mamba2


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_model: int
    d_inner: int        # typically 2·d_model
    n_heads: int        # d_inner / head_dim
    head_dim: int = 64
    d_state: int = 64
    d_conv: int = 4
    chunk: int = 64


def mamba_init(key, c: MambaCfg, dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, di, nh, N = c.d_model, c.d_inner, c.n_heads, c.d_state
    s = d ** -0.5
    return {
        # separate z/x projections: a fused (d, 2·di) weight's output gets
        # SLICED at di, which crosses the model-shard boundary and makes
        # GSPMD insert per-layer reshard collective-permutes (§Perf, zamba2)
        "w_z": (jax.random.normal(k1, (d, di)) * s).astype(dtype),
        "w_x": (jax.random.normal(jax.random.fold_in(k1, 1), (d, di))
                * s).astype(dtype),
        "conv": (jax.random.normal(k2, (c.d_conv, di)) * 0.1).astype(dtype),
        "w_bcdt": (jax.random.normal(k3, (d, 2 * N + nh)) * s).astype(dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        # decay a_t = exp(-exp(A_log)·dt): init near 1 (≈0.99/token) — the
        # chunked form divides by the in-chunk decay cumprod, so aggressive
        # decay (A_log=0 ⇒ a≈0.5) overflows f32 within a 64-chunk.
        "A_log": jnp.full((nh,), -4.0, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "w_out": (jax.random.normal(k4, (di, d)) * di ** -0.5).astype(dtype),
    }


def _causal_conv(xin, conv, state=None):
    """Depthwise causal conv over seq.  xin: (B,S,di); conv: (dc, di).
    state: (B, dc-1, di) trailing inputs from previous steps (decode)."""
    dc = conv.shape[0]
    if state is None:
        pad = jnp.zeros_like(xin[:, : dc - 1])
    else:
        pad = state.astype(xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)
    out = sum(xp[:, i:i + xin.shape[1]] * conv[i][None, None]
              for i in range(dc))
    new_state = xp[:, -(dc - 1):]
    return out, new_state


def mamba_block(p, c: MambaCfg, x, mask, site, *, poly=None, soft=False,
                cache=None):
    """x: (B,S,d).  cache: None | (ssm_state (B,nh,N,hd), conv_state).
    Returns (y, new_cache)."""
    B, S, d = x.shape
    di, nh, hd, N = c.d_inner, c.n_heads, c.head_dim, c.d_state
    z = x @ p["w_z"]
    xin = x @ p["w_x"]
    xin, conv_state = _causal_conv(
        xin, p["conv"], None if cache is None else cache[1])
    xin = jax.nn.silu(xin)
    bcdt = x @ p["w_bcdt"]
    b, cc, dt = bcdt[..., :N], bcdt[..., N:2 * N], bcdt[..., 2 * N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                          # (B,S,nh)
    v = xin.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)             # (B,nh,S,hd)
    kk = (b[..., None, :] * dt[..., None]).transpose(0, 2, 1, 3)    # (B,nh,S,N)
    rr = jnp.broadcast_to(cc[..., None, :], (B, S, nh, N)
                          ).transpose(0, 2, 1, 3)
    ww = jnp.broadcast_to(a[..., None], (B, S, nh, N)).transpose(0, 2, 1, 3)
    kk = kk.astype(jnp.float32)
    rr = rr.astype(jnp.float32)
    s0 = (jnp.zeros((B, nh, N, hd), jnp.float32) if cache is None
          else cache[0])
    if S == 1 and cache is not None:
        y1, S1 = linattn_step(rr[:, :, 0], kk[:, :, 0], v[:, :, 0].astype(
            jnp.float32), ww[:, :, 0], None, s0, decay_first=True)
        y = y1[:, :, None]
    else:
        y, S1 = linattn_chunked(rr, kk, v.astype(jnp.float32), ww, None, s0,
                                chunk=min(c.chunk, S), decay_first=True)
    y = y + p["D"][None, :, None, None] * v.astype(y.dtype)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    # masked gate: the block's maskable nonlinearity (DESIGN §4)
    gate = linearize.apply_masked_act(z, mask, site, poly=poly, soft=soft)
    y = y * gate
    out = y @ p["w_out"]
    new_cache = None if cache is None else (S1, conv_state)
    return out, new_cache


# ================================================================= RWKV-6


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    d_model: int
    d_ff: int
    head_dim: int = 64
    chunk: int = 32

    @property
    def n_heads(self):
        return self.d_model // self.head_dim


def rwkv_init(key, c: RWKVCfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 8)
    d, f, H, hd = c.d_model, c.d_ff, c.n_heads, c.head_dim
    s = d ** -0.5
    proj = lambda k, m, n, sc: (jax.random.normal(k, (m, n)) * sc).astype(dtype)
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),      # token-shift lerp r,k,v,w,g
        "w_r": proj(ks[0], d, d, s), "w_k": proj(ks[1], d, d, s),
        "w_v": proj(ks[2], d, d, s), "w_g": proj(ks[3], d, d, s),
        "w_w": proj(ks[4], d, d, s * 0.1),
        "w_bias": jnp.full((d,), -2.0, jnp.float32),
        "u": (jax.random.normal(ks[5], (H, hd)) * 0.3).astype(jnp.float32),
        "w_o": proj(ks[6], d, d, s),
        "ln_x": layers.rmsnorm_init(hd),
        "mu_c": jnp.full((2, d), 0.5, jnp.float32),    # channel-mix shift
        "w_ck": proj(ks[7], d, f, s),
        "w_cv": (jax.random.normal(jax.random.fold_in(key, 99), (f, d))
                 * f ** -0.5).astype(dtype),
        "w_cr": proj(jax.random.fold_in(key, 98), d, d, s),
    }


def _shift(x, prev):
    """Token shift: returns x_{t-1} with x_{-1} = prev (B,d) (zeros if None)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def rwkv_time_mix(p, c: RWKVCfg, x, *, cache=None):
    """cache: None | (state (B,H,hd,hd) f32, prev_x (B,d)).  -> (y, cache)."""
    B, S, d = x.shape
    H, hd = c.n_heads, c.head_dim
    prev = None if cache is None else cache[1]
    xs = _shift(x, prev)
    mix = lambda i: (p["mu"][i] * x + (1 - p["mu"][i]) * xs).astype(x.dtype)
    r = (mix(0) @ p["w_r"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (mix(1) @ p["w_k"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (mix(2) @ p["w_v"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    wdec = jnp.exp(-jnp.exp((mix(3) @ p["w_w"]).astype(jnp.float32)
                            + p["w_bias"]))
    wdec = wdec.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(mix(4) @ p["w_g"])
    s0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if cache is None
          else cache[0])
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if S == 1 and cache is not None:
        y1, S1 = linattn_step(rf[:, :, 0], kf[:, :, 0], vf[:, :, 0],
                              wdec[:, :, 0], p["u"], s0)
        y = y1[:, :, None]
    else:
        y, S1 = linattn_chunked(rf, kf, vf, wdec, p["u"], s0,
                                chunk=min(c.chunk, S))
    y = layers.rmsnorm(p["ln_x"], y)                    # per-head norm
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d).astype(x.dtype)
    y = (y * g) @ p["w_o"]
    new_cache = None if cache is None else (S1, x[:, -1])
    return y, new_cache


def rwkv_channel_mix(p, c: RWKVCfg, x, mask, site, *, poly=None, soft=False,
                     cache=None):
    """Channel-mix with the sqrelu mask site.  cache: prev_x (B,d) | None."""
    prev = cache
    xs = _shift(x, prev)
    xk = (p["mu_c"][0] * x + (1 - p["mu_c"][0]) * xs).astype(x.dtype)
    xr = (p["mu_c"][1] * x + (1 - p["mu_c"][1]) * xs).astype(x.dtype)
    h = xk @ p["w_ck"]
    a = linearize.apply_masked_act(h, mask, site, poly=poly, soft=soft)
    y = (a @ p["w_cv"]) * jax.nn.sigmoid(xr @ p["w_cr"])
    new_cache = None if cache is None else x[:, -1]
    return y, new_cache
