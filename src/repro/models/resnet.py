"""The paper's backbones: CIFAR-style ResNet18 and WideResNet-22-8.

Every ReLU is a mask site with the *full per-pixel activation shape*
(H, W, C), shared across the batch — exactly the paper's mask granularity
(ResNet18 @32×32 ≈ 557K ReLUs; the paper's Table 1 says 570K — the delta is
the counting convention for the stem ReLU, documented in EXPERIMENTS.md).

BatchNorm uses batch statistics in both train and eval (synthetic-data
reproduction; see DESIGN §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import linearize, masks as M


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * (2.0 / fan) ** 0.5


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(p, x, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    n_classes: int
    image_size: int
    # (channels, n_blocks, stride) per stage
    stages: Tuple[Tuple[int, int, int], ...]
    stem_channels: int
    wide: bool = False          # WRN pre-activation blocks

    @staticmethod
    def resnet18(n_classes=10, image_size=32):
        return CNNConfig("resnet18", n_classes, image_size,
                         ((64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)),
                         stem_channels=64)

    @staticmethod
    def wrn22_8(n_classes=10, image_size=32):
        return CNNConfig("wrn22_8", n_classes, image_size,
                         ((128, 3, 1), (256, 3, 2), (512, 3, 2)),
                         stem_channels=16, wide=True)


class CNN:
    """Masked-ReLU CNN.  API mirrors models.lm.LM where it matters."""

    def __init__(self, cfg: CNNConfig):
        self.cfg = cfg
        self._site_shapes = self._compute_site_shapes()

    # ---------------------------------------------------------- structure

    def _block_plan(self):
        """Yields (stage, block, cin, cout, stride, hw) tuples."""
        cfg = self.cfg
        hw = cfg.image_size
        cin = cfg.stem_channels
        for si, (cout, n, stride) in enumerate(cfg.stages):
            for bi in range(n):
                s = stride if bi == 0 else 1
                hw_out = hw // s
                yield si, bi, cin, cout, s, hw_out
                cin, hw = cout, hw_out

    def _compute_site_shapes(self):
        cfg = self.cfg
        shapes: Dict[str, Tuple[int, ...]] = {}
        if not cfg.wide:
            shapes["stem.relu"] = (cfg.image_size, cfg.image_size,
                                   cfg.stem_channels)
        for si, bi, cin, cout, s, hw in self._block_plan():
            if cfg.wide:
                hw_in = hw * s
                shapes[f"g{si}b{bi}.relu1"] = (hw_in, hw_in, cin)
                shapes[f"g{si}b{bi}.relu2"] = (hw, hw, cout)
            else:
                shapes[f"g{si}b{bi}.relu1"] = (hw, hw, cout)
                shapes[f"g{si}b{bi}.relu2"] = (hw, hw, cout)
        if cfg.wide:
            hw_f = cfg.image_size // 4
            shapes["final.relu"] = (hw_f, hw_f, cfg.stages[-1][0])
        return shapes

    def mask_sites(self) -> Dict[str, linearize.MaskSite]:
        return {k: linearize.MaskSite(v, "relu")
                for k, v in self._site_shapes.items()}

    def relu_count(self) -> int:
        return sum(int(jnp.prod(jnp.asarray(s)))
                   for s in self._site_shapes.values())

    # ---------------------------------------------------------- params

    def init(self, key):
        cfg = self.cfg
        p = {"stem": {"conv": _conv_init(jax.random.fold_in(key, 0), 3, 3, 3,
                                         cfg.stem_channels),
                      "bn": _bn_init(cfg.stem_channels)}}
        for si, bi, cin, cout, s, hw in self._block_plan():
            k = jax.random.fold_in(key, 100 + si * 10 + bi)
            blk = {"conv1": _conv_init(jax.random.fold_in(k, 1), 3, 3, cin,
                                       cout),
                   "bn1": _bn_init(cin if cfg.wide else cout),
                   "conv2": _conv_init(jax.random.fold_in(k, 2), 3, 3, cout,
                                       cout),
                   "bn2": _bn_init(cout)}
            if s != 1 or cin != cout:
                blk["proj"] = _conv_init(jax.random.fold_in(k, 3), 1, 1, cin,
                                         cout)
            p[f"g{si}b{bi}"] = blk
        cfinal = cfg.stages[-1][0]
        if cfg.wide:
            p["final_bn"] = _bn_init(cfinal)
        p["fc"] = {"w": jax.random.normal(jax.random.fold_in(key, 7),
                                          (cfinal, cfg.n_classes))
                   * cfinal ** -0.5,
                   "b": jnp.zeros((cfg.n_classes,))}
        return p

    # ---------------------------------------------------------- forward

    def _relu(self, x, masks, name, poly, soft):
        site = linearize.MaskSite(self._site_shapes[name], "relu")
        return linearize.apply_masked_act(
            x, masks[name], site,
            poly=None if poly is None else poly.get(name), soft=soft)

    def forward(self, params, masks, images, *, poly=None, soft=False):
        cfg = self.cfg
        x = images
        if cfg.wide:
            x = _conv(x, params["stem"]["conv"])
            for si, bi, cin, cout, s, hw in self._block_plan():
                blk = params[f"g{si}b{bi}"]
                h = self._relu(_bn(blk["bn1"], x), masks,
                               f"g{si}b{bi}.relu1", poly, soft)
                y = _conv(h, blk["conv1"], s)
                y = self._relu(_bn(blk["bn2"], y), masks,
                               f"g{si}b{bi}.relu2", poly, soft)
                y = _conv(y, blk["conv2"])
                sc = _conv(h, blk["proj"], s) if "proj" in blk else x
                x = y + sc
            x = self._relu(_bn(params["final_bn"], x), masks, "final.relu",
                           poly, soft)
        else:
            x = _bn(params["stem"]["bn"], _conv(x, params["stem"]["conv"]))
            x = self._relu(x, masks, "stem.relu", poly, soft)
            for si, bi, cin, cout, s, hw in self._block_plan():
                blk = params[f"g{si}b{bi}"]
                y = self._relu(_bn(blk["bn1"], _conv(x, blk["conv1"], s)),
                               masks, f"g{si}b{bi}.relu1", poly, soft)
                y = _bn(blk["bn2"], _conv(y, blk["conv2"]))
                sc = _conv(x, blk["proj"], s) if "proj" in blk else x
                x = self._relu(y + sc, masks, f"g{si}b{bi}.relu2", poly, soft)
        x = jnp.mean(x, axis=(1, 2))
        return x @ params["fc"]["w"] + params["fc"]["b"]

    # ------------------------------------------------------- eval closures
    #
    # BCD's candidate-evaluation engine (core.engine) needs two views of
    # "accuracy under a mask tree": a *traceable* single-tree closure that
    # the batched/sharded backends can vmap over the candidate axis, and a
    # plain host callable for the sequential reference / per-step base accs.

    def make_param_eval_fn(self, batch):
        """Traceable ``(mask_tree, params) -> accuracy[%]`` — for evaluator
        backends whose params change between BCD outer steps (finetuning):
        params ride as a jit input / evaluator context, never a baked
        closure constant."""
        images = jnp.asarray(batch["images"])
        labels = jnp.asarray(batch["labels"])

        def eval_fn(masks, params):
            logits = self.forward(params, masks, images)
            return jnp.mean((jnp.argmax(logits, -1) == labels)
                            .astype(jnp.float32)) * 100.0
        return eval_fn

    def make_eval_fn(self, params, batch):
        """Traceable ``mask_tree -> accuracy[%]`` closure over a fixed
        (params, batch).  Masks are traced inputs — safe under jit/vmap,
        never recompiles across candidates."""
        fn = self.make_param_eval_fn(batch)
        return lambda masks: fn(masks, params)

    def make_joint_eval_fn(self):
        """Traceable ``(mask_tree, ctx) -> accuracy[%]`` with
        ``ctx = {"params": ..., "batch": ...}`` — params AND the eval batch
        ride as evaluator context (jit inputs), so a ShardedEvaluator on a
        ``("cand", "batch")`` mesh (``launch.mesh.make_cand_batch_mesh``)
        can lay the batch axis across the ``"batch"`` devices while the
        candidate axis shards over ``"cand"``: the joint layout that keeps
        every device busy when a trial chunk has fewer candidates than the
        mesh has devices."""
        def eval_fn(masks, ctx):
            batch = ctx["batch"]
            logits = self.forward(ctx["params"], masks, batch["images"])
            return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                            .astype(jnp.float32)) * 100.0
        return eval_fn

    def make_eval_acc(self, params, batch):
        """Host callable ``mask_tree -> float`` (jitted single-candidate
        path) — what ``run_bcd``'s eval_acc argument expects."""
        fn = jax.jit(self.make_eval_fn(params, batch))
        return lambda masks: float(fn(M.as_device(masks)))
