"""The paper's backbones: CIFAR-style ResNet18 and WideResNet-22-8.

Every ReLU is a mask site with the *full per-pixel activation shape*
(H, W, C), shared across the batch — exactly the paper's mask granularity
(ResNet18 @32×32 ≈ 557K ReLUs; the paper's Table 1 says 570K — the delta is
the counting convention for the stem ReLU, documented in EXPERIMENTS.md).

BatchNorm uses batch statistics in both train and eval (synthetic-data
reproduction; see DESIGN §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import linearize, masks as M


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * (2.0 / fan) ** 0.5


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(p, x, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    n_classes: int
    image_size: int
    # (channels, n_blocks, stride) per stage
    stages: Tuple[Tuple[int, int, int], ...]
    stem_channels: int
    wide: bool = False          # WRN pre-activation blocks

    @staticmethod
    def resnet18(n_classes=10, image_size=32):
        return CNNConfig("resnet18", n_classes, image_size,
                         ((64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)),
                         stem_channels=64)

    @staticmethod
    def wrn22_8(n_classes=10, image_size=32):
        return CNNConfig("wrn22_8", n_classes, image_size,
                         ((128, 3, 1), (256, 3, 2), (512, 3, 2)),
                         stem_channels=16, wide=True)


class CNN:
    """Masked-ReLU CNN.  API mirrors models.lm.LM where it matters."""

    def __init__(self, cfg: CNNConfig):
        self.cfg = cfg
        self._site_shapes = self._compute_site_shapes()
        self._segs = self._build_segments()
        self._seg_of_site = {s: i for i, (_, sites, _) in
                             enumerate(self._segs) for s in sites}

    # ---------------------------------------------------------- structure

    def _block_plan(self):
        """Yields (stage, block, cin, cout, stride, hw) tuples."""
        cfg = self.cfg
        hw = cfg.image_size
        cin = cfg.stem_channels
        for si, (cout, n, stride) in enumerate(cfg.stages):
            for bi in range(n):
                s = stride if bi == 0 else 1
                hw_out = hw // s
                yield si, bi, cin, cout, s, hw_out
                cin, hw = cout, hw_out

    def _compute_site_shapes(self):
        cfg = self.cfg
        shapes: Dict[str, Tuple[int, ...]] = {}
        if not cfg.wide:
            shapes["stem.relu"] = (cfg.image_size, cfg.image_size,
                                   cfg.stem_channels)
        for si, bi, cin, cout, s, hw in self._block_plan():
            if cfg.wide:
                hw_in = hw * s
                shapes[f"g{si}b{bi}.relu1"] = (hw_in, hw_in, cin)
                shapes[f"g{si}b{bi}.relu2"] = (hw, hw, cout)
            else:
                shapes[f"g{si}b{bi}.relu1"] = (hw, hw, cout)
                shapes[f"g{si}b{bi}.relu2"] = (hw, hw, cout)
        if cfg.wide:
            hw_f = cfg.image_size // 4
            shapes["final.relu"] = (hw_f, hw_f, cfg.stages[-1][0])
        return shapes

    def mask_sites(self) -> Dict[str, linearize.MaskSite]:
        return {k: linearize.MaskSite(v, "relu")
                for k, v in self._site_shapes.items()}

    def relu_count(self) -> int:
        return sum(int(jnp.prod(jnp.asarray(s)))
                   for s in self._site_shapes.values())

    # ---------------------------------------------------------- params

    def init(self, key):
        cfg = self.cfg
        p = {"stem": {"conv": _conv_init(jax.random.fold_in(key, 0), 3, 3, 3,
                                         cfg.stem_channels),
                      "bn": _bn_init(cfg.stem_channels)}}
        for si, bi, cin, cout, s, hw in self._block_plan():
            k = jax.random.fold_in(key, 100 + si * 10 + bi)
            blk = {"conv1": _conv_init(jax.random.fold_in(k, 1), 3, 3, cin,
                                       cout),
                   "bn1": _bn_init(cin if cfg.wide else cout),
                   "conv2": _conv_init(jax.random.fold_in(k, 2), 3, 3, cout,
                                       cout),
                   "bn2": _bn_init(cout)}
            if s != 1 or cin != cout:
                blk["proj"] = _conv_init(jax.random.fold_in(k, 3), 1, 1, cin,
                                         cout)
            p[f"g{si}b{bi}"] = blk
        cfinal = cfg.stages[-1][0]
        if cfg.wide:
            p["final_bn"] = _bn_init(cfinal)
        p["fc"] = {"w": jax.random.normal(jax.random.fold_in(key, 7),
                                          (cfinal, cfg.n_classes))
                   * cfinal ** -0.5,
                   "b": jnp.zeros((cfg.n_classes,))}
        return p

    # ---------------------------------------------------------- forward
    #
    # The forward is a fold over an ordered *segment* list.  Each segment is
    # (name, sites_it_applies, fn(params, masks, x, poly, soft) -> x); the
    # full forward, forward_prefix, and forward_suffix all fold the same
    # list, so the split-forward contract
    #     forward_suffix(p, m, forward_prefix(p, m, x, site), site)
    #         == forward(p, m, x)
    # holds bitwise *by construction* — prefix/suffix trace exactly the
    # primitives forward traces (core.engine.SuffixEvaluator relies on it).

    def _relu(self, x, masks, name, poly, soft):
        site = linearize.MaskSite(self._site_shapes[name], "relu")
        return linearize.apply_masked_act(
            x, masks[name], site,
            poly=None if poly is None else poly.get(name), soft=soft)

    def _relu_conv(self, x, masks, name, ply, soft, w, stride=1):
        """Masked ReLU at ``name`` feeding a 3x3 conv.  Under
        ``linearize.fused_suffix_route`` (the suffix engine traces its
        suffix jits with it armed) hard-mask sites run gate + conv as one
        Pallas megakernel (``kernels.ops.masked_act_conv3x3_routed``) — the
        gated tensor stays in VMEM instead of round-tripping HBM between
        two dispatches.  Everywhere else (CPU, soft relaxation, poly2
        replacement) it is the plain unfused pair."""
        p = None if ply is None else ply.get(name)
        mode = linearize.fused_route_mode()
        if mode is not None and not soft and p is None:
            from repro.kernels import ops
            interpret = mode == "interpret"
            if interpret or ops.fused_dispatch_enabled():
                return ops.masked_act_conv3x3_routed(
                    x, masks[name], w, stride=stride, kind="relu",
                    interpret=interpret)
        return _conv(self._relu(x, masks, name, ply, soft), w, stride)

    def _stem_pre(self, p, x):
        """Mask-independent stem fold: input -> the first gate's
        pre-activation (conv [+ bn]).  Depends only on (params, images), so
        evaluator backends compute it ONCE per context (``forward_pre``)
        and every candidate's full forward starts from the cached result
        (``forward(..., pre=...)``) instead of re-tracing it."""
        if self.cfg.wide:
            return _conv(x, p["stem"]["conv"])
        return _bn(p["stem"]["bn"], _conv(x, p["stem"]["conv"]))

    def _stem_gate(self, p, m, x, ply, soft):
        """The mask-dependent remainder of the stem segment (no-op for the
        wide config, whose first gate lives in g0b0)."""
        if self.cfg.wide:
            return x
        return self._relu(x, m, "stem.relu", ply, soft)

    def _build_segments(self):
        cfg = self.cfg
        segs = []
        # stem = _stem_gate(_stem_pre(x)): the same two folds forward's
        # pre= entry composes, so full-with-pre traces exactly the
        # primitives full-from-images traces (bitwise selection contract)
        segs.append(("stem", () if cfg.wide else ("stem.relu",),
                     lambda p, m, x, ply, soft:
                     self._stem_gate(p, m, self._stem_pre(p, x), ply, soft)))
        for si, bi, cin, cout, s, hw in self._block_plan():
            name = f"g{si}b{bi}"
            if cfg.wide:
                def blk_fn(p, m, x, ply, soft, name=name, s=s):
                    blk = p[name]
                    # relu1's output feeds both conv1 and the projection
                    # shortcut, so only relu2 -> conv2 (single consumer)
                    # is fusable
                    h = self._relu(_bn(blk["bn1"], x), m,
                                   f"{name}.relu1", ply, soft)
                    y = _conv(h, blk["conv1"], s)
                    y = self._relu_conv(_bn(blk["bn2"], y), m,
                                        f"{name}.relu2", ply, soft,
                                        blk["conv2"])
                    sc = _conv(h, blk["proj"], s) if "proj" in blk else x
                    return y + sc
            else:
                def blk_fn(p, m, x, ply, soft, name=name, s=s):
                    blk = p[name]
                    y = self._relu_conv(_bn(blk["bn1"], _conv(x, blk["conv1"],
                                                              s)),
                                        m, f"{name}.relu1", ply, soft,
                                        blk["conv2"])
                    y = _bn(blk["bn2"], y)
                    sc = _conv(x, blk["proj"], s) if "proj" in blk else x
                    return self._relu(y + sc, m, f"{name}.relu2", ply, soft)
            segs.append((name, (f"{name}.relu1", f"{name}.relu2"), blk_fn))

        def head_fn(p, m, x, ply, soft):
            if cfg.wide:
                x = self._relu(_bn(p["final_bn"], x), m, "final.relu",
                               ply, soft)
            x = jnp.mean(x, axis=(1, 2))
            return x @ p["fc"]["w"] + p["fc"]["b"]
        segs.append(("head", ("final.relu",) if cfg.wide else (), head_fn))
        return segs

    def forward(self, params, masks, images, *, poly=None, soft=False,
                pre=None):
        """Full forward.  ``pre``: a cached :meth:`forward_pre` result —
        the fold resumes at the first gate and ``images`` is ignored
        (evaluator contexts carry the pre-activation so per-candidate work
        skips the mask-independent stem)."""
        if pre is not None:
            x = self._stem_gate(params, masks, pre, poly, soft)
            segs = self._segs[1:]
        else:
            x = images
            segs = self._segs
        for _, _, fn in segs:
            x = fn(params, masks, x, poly, soft)
        return x

    def forward_pre(self, params, images):
        """Mask-independent head of the network (input -> first gate's
        pre-activation).  Computed once per evaluator context and fed back
        through ``forward(..., pre=...)`` — the "depth-0 prefix" every
        candidate shares regardless of which masks it mutates."""
        return self._stem_pre(params, images)

    # ------------------------------------------------------- split forward
    #
    # BCD candidates are local mask edits: a candidate whose earliest
    # touched site sits in segment k shares everything before segment k with
    # the base masks.  forward_prefix computes that shared part once;
    # forward_suffix finishes the net from the cached activation.  ``site``
    # is a Python-level (static) argument — the engine jits one suffix per
    # cut segment.

    def site_order(self) -> Tuple[str, ...]:
        """All mask sites in forward (topological) order."""
        return tuple(s for _, sites, _ in self._segs for s in sites)

    def site_segments(self) -> Dict[str, int]:
        """site name -> index of the segment that applies it.  Sites that
        share a segment share a prefix (and a suffix jit cache entry)."""
        return dict(self._seg_of_site)

    def suffix_sites(self, site: str) -> Tuple[str, ...]:
        """The sites forward_suffix(site) consumes: every site applied by
        the cut segment or later (the candidate mask values the suffix
        evaluator must ship per candidate)."""
        cut = self._seg_of_site[site]
        return tuple(s for _, sites, _ in self._segs[cut:] for s in sites)

    def forward_prefix(self, params, masks, images, site, *, poly=None,
                       soft=False, from_site=None, cached=None):
        """Run forward up to (excluding) the segment that applies ``site``;
        returns the cached boundary activation (the suffix's input).

        Multi-depth entry: ``from_site``/``cached`` resume from an earlier
        prefix instead of the input — folding only the segments in
        ``[seg(from_site), seg(site))``, so
        ``forward_prefix(..., site=b, from_site=a, cached=prefix(a))``
        computes exactly ``forward_prefix(..., site=b)`` (same fold over the
        same segment list — the prefix-trie extension contract)."""
        lo = 0
        x = images
        if from_site is not None:
            lo = self._seg_of_site[from_site]
            x = cached
        for _, _, fn in self._segs[lo:self._seg_of_site[site]]:
            x = fn(params, masks, x, poly, soft)
        return x

    def forward_suffix(self, params, masks, cached, site, *, poly=None,
                       soft=False):
        """Finish forward from a :meth:`forward_prefix` cache: folds the
        segment applying ``site`` and everything after it to logits."""
        x = cached
        for _, _, fn in self._segs[self._seg_of_site[site]:]:
            x = fn(params, masks, x, poly, soft)
        return x

    def _segment_flops(self) -> List[float]:
        """Per-sample forward FLOPs per segment (conv + fc terms only —
        the >99% of the work; used by the suffix cost model)."""
        cfg = self.cfg
        flops = [0.0] * len(self._segs)
        seg_idx = {name: i for i, (name, _, _) in enumerate(self._segs)}
        flops[seg_idx["stem"]] = (
            2.0 * 9 * 3 * cfg.stem_channels * cfg.image_size ** 2)
        for si, bi, cin, cout, s, hw in self._block_plan():
            f = 2.0 * 9 * cin * cout * hw ** 2          # conv1 (stride s)
            f += 2.0 * 9 * cout * cout * hw ** 2        # conv2
            if s != 1 or cin != cout:
                f += 2.0 * cin * cout * hw ** 2         # 1x1 proj
            flops[seg_idx[f"g{si}b{bi}"]] += f
        flops[seg_idx["head"]] = 2.0 * cfg.stages[-1][0] * cfg.n_classes
        return flops

    def site_prefix_fractions(self) -> Dict[str, float]:
        """site -> fraction of full-forward FLOPs strictly before its
        segment.  0.0 for first-segment sites (suffix mode buys nothing),
        approaching 1.0 for the deepest sites — the suffix cost model
        (analysis.roofline.SuffixCostModel) thresholds on this."""
        seg_flops = self._segment_flops()
        total = max(sum(seg_flops), 1.0)
        cum = 0.0
        before = []
        for f in seg_flops:
            before.append(cum / total)
            cum += f
        return {s: before[i] for s, i in self._seg_of_site.items()}

    # ------------------------------------------------------- eval closures
    #
    # BCD's candidate-evaluation engine (core.engine) needs two views of
    # "accuracy under a mask tree": a *traceable* single-tree closure that
    # the batched/sharded backends can vmap over the candidate axis, and a
    # plain host callable for the sequential reference / per-step base accs.

    def make_param_eval_fn(self, batch):
        """Traceable ``(mask_tree, params) -> accuracy[%]`` — for evaluator
        backends whose params change between BCD outer steps (finetuning):
        params ride as a jit input / evaluator context, never a baked
        closure constant."""
        images = jnp.asarray(batch["images"])
        labels = jnp.asarray(batch["labels"])

        def eval_fn(masks, params):
            logits = self.forward(params, masks, images)
            return jnp.mean((jnp.argmax(logits, -1) == labels)
                            .astype(jnp.float32)) * 100.0
        return eval_fn

    def make_eval_fn(self, params, batch):
        """Traceable ``mask_tree -> accuracy[%]`` closure over a fixed
        (params, batch).  Masks are traced inputs — safe under jit/vmap,
        never recompiles across candidates."""
        fn = self.make_param_eval_fn(batch)
        return lambda masks: fn(masks, params)

    def make_joint_eval_fn(self):
        """Traceable ``(mask_tree, ctx) -> accuracy[%]`` with
        ``ctx = {"params": ..., "batch": ...}`` — params AND the eval batch
        ride as evaluator context (jit inputs), so a ShardedEvaluator on a
        ``("cand", "batch")`` mesh (``launch.mesh.make_cand_batch_mesh``)
        can lay the batch axis across the ``"batch"`` devices while the
        candidate axis shards over ``"cand"``: the joint layout that keeps
        every device busy when a trial chunk has fewer candidates than the
        mesh has devices."""
        def eval_fn(masks, ctx):
            batch = ctx["batch"]
            # "pre" (optional): the mask-independent stem fold, computed
            # once per context by the evaluator (SplitEval.pre) — presence
            # is a trace-time (pytree structure) decision, never a retrace
            logits = self.forward(ctx["params"], masks, batch["images"],
                                  pre=ctx.get("pre"))
            return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                            .astype(jnp.float32)) * 100.0
        return eval_fn

    def make_suffix_eval_fns(self):
        """Split-forward closure bundle for ``engine.SuffixEvaluator``.

        ``prefix(site, masks, ctx) -> cached`` runs the shared part of the
        net once per (site, step); ``suffix(site, masks, cached, ctx) ->
        acc[%]`` is what the engine vmaps over the candidate axis —
        per-candidate work shrinks to the layers at/after the mutated site.
        ``ctx = {"params", "batch"}`` rides as evaluator context exactly
        like :meth:`make_joint_eval_fn` (batch-shardable on a
        ``("cand", "batch")`` mesh, so the cached prefix never gathers).
        """
        from repro.core import engine

        def prefix_fn(site, masks, ctx):
            return self.forward_prefix(ctx["params"], masks,
                                       ctx["batch"]["images"], site)

        def prefix_ext_fn(from_site, site, masks, cached, ctx):
            return self.forward_prefix(ctx["params"], masks,
                                       ctx["batch"]["images"], site,
                                       from_site=from_site, cached=cached)

        def suffix_fn(site, masks, cached, ctx):
            logits = self.forward_suffix(ctx["params"], masks, cached, site)
            return jnp.mean((jnp.argmax(logits, -1) == ctx["batch"]["labels"])
                            .astype(jnp.float32)) * 100.0

        def pre_fn(ctx):
            return self.forward_pre(ctx["params"], ctx["batch"]["images"])

        return engine.SplitEval(
            prefix=prefix_fn, suffix=suffix_fn,
            full=self.make_joint_eval_fn(),
            site_order=self.site_order(),
            site_segment=self.site_segments(),
            suffix_sites=self.suffix_sites,
            prefix_fraction=self.site_prefix_fractions(),
            prefix_ext=prefix_ext_fn,
            pre=pre_fn)

    def make_eval_acc(self, params, batch):
        """Host callable ``mask_tree -> float`` (jitted single-candidate
        path) — what ``run_bcd``'s eval_acc argument expects."""
        fn = jax.jit(self.make_eval_fn(params, batch))
        return lambda masks: float(fn(M.as_device(masks)))
