"""Mixture-of-Experts FFN with sort-based (dropping) dispatch.

Dispatch is done *per batch row* (vmap over B): top-k routing, argsort by
expert id, capacity clip, gather → (E, C, d) → batched expert einsums →
scatter-add back.  Because the sort runs over the (unsharded) sequence axis
and batch is the data-parallel axis, GSPMD keeps all dispatch local to each
data shard; expert weights are tensor-parallel over 'model' (d_ff split), so
no quadratic one-hot dispatch matmuls and no token all-to-alls — FLOPs stay
≈ top_k/E-proportional (MODEL_FLOPS ratio stays honest).

Covers mixtral (8e top-2) and deepseek-moe (2 shared + 64e top-6,
fine-grained d_ff).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import linearize
from . import layers


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # deepseek: always-on shared experts
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    # 'scatter': d-wide scatter dispatch (baseline; GSPMD replicates the
    #            scatter operands — see EXPERIMENTS.md §Perf).
    # 'gather':  d-wide ops are gathers only; scatters touch int32 index
    #            vectors (tiny).  GSPMD partitions gathers cleanly.
    dispatch: str = "scatter"


def moe_init(key, c: MoECfg, dtype=jnp.bfloat16):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, e, f = c.d_model, c.n_experts, c.d_ff_expert
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(kr, (d, e)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(kg, (e, d, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(ku, (e, d, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(kd, (e, f, d)) * f ** -0.5).astype(dtype),
    }
    if c.n_shared:
        p["shared"] = layers.ffn_init(ks, d, c.d_ff_shared, gated=True,
                                      dtype=dtype)
    return p


def _capacity(c: MoECfg, seq: int) -> int:
    cap = int(seq * c.top_k * c.capacity_factor / c.n_experts) + 1
    if seq == 1:        # decode: exact capacity — a token routes to at most
        return 1        # one slot per expert (§Perf: the rounded-up 8 slots
                        # per expert cost 8x dispatch traffic per step)
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8


def _dispatch_row(x, logits, c: MoECfg, C: int):
    """x: (S, d), logits: (S, E) -> gathered (E*C, d), slot bookkeeping.

    The bookkeeping is carried in UNSORTED per-(token, k) layout (sort
    inverted via the int32 scatter-of-a-permutation idiom from
    :func:`_route` — unique indices, order-independent) so
    :func:`_combine_row` is a fixed-order gather + top-k reduction with no
    duplicate-index scatter."""
    S = x.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eidx = jax.lax.top_k(probs, c.top_k)          # (S, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    flat_e = eidx.reshape(-1)                            # (S*k,)
    flat_t = jnp.repeat(jnp.arange(S), c.top_k)
    order = jnp.argsort(flat_e)
    se, st = flat_e[order], flat_t[order]
    # position within expert along the sorted order
    onehot = jax.nn.one_hot(se, c.n_experts, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0), se[:, None],
                              axis=1)[:, 0] - 1
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, c.n_experts * C)  # overflow slot
    # duplicate indices occur only at the overflow slot, where every write
    # is zeros — kept slots are unique, so the .set is order-independent
    xg = jnp.zeros((c.n_experts * C + 1, x.shape[1]), x.dtype)
    xg = xg.at[slot].set(jnp.where(keep[:, None], x[st], 0))
    slot_tk = jnp.zeros((S * c.top_k,), jnp.int32).at[order].set(
        slot.astype(jnp.int32)).reshape(S, c.top_k)
    keep_tk = jnp.zeros((S * c.top_k,), bool).at[order].set(
        keep).reshape(S, c.top_k)
    return xg[:-1], (gates, slot_tk, keep_tk)


def _combine_row(y_slots, book, S, d):
    """Combine expert outputs back to tokens with a fixed-order top-k sum.

    Replaces the historical ``out.at[st].add(ys * w)`` scatter-add: ``st``
    held every token ``top_k`` times, and XLA's accumulation order over
    duplicate scatter indices is unspecified — so the same routing could
    combine in different orders under the per-row vmap vs the
    candidate-stacked (double-vmapped) lowering, breaking the engine's
    bitwise stacked-vs-sequential contract when capacity overflow drops
    tokens.  Gathering per (token, k) and reducing over the k axis is a
    plain fixed-association sum — identical however it is batched, and
    matching ``batched_gather``'s einsum combine."""
    gates, slot_tk, keep_tk = book
    k = slot_tk.shape[1]
    ypad = jnp.concatenate([y_slots, jnp.zeros((1, d), y_slots.dtype)],
                           axis=0)
    ytk = ypad[slot_tk.reshape(-1)].reshape(S, k, d)
    w = gates.astype(ytk.dtype) * keep_tk.astype(ytk.dtype)
    return jnp.einsum("skd,sk->sd", ytk, w)


def _route(logits, c: MoECfg, C: int):
    """Shared routing bookkeeping — only small int/float vectors, no d-wide
    tensors.  Returns per-(token,k) slot ids and per-slot source tokens."""
    S = logits.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eidx = jax.lax.top_k(probs, c.top_k)              # (S, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    flat_e = eidx.reshape(-1)                                # (S*k,)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    st = jnp.repeat(jnp.arange(S), c.top_k)[order]
    onehot = jax.nn.one_hot(se, c.n_experts, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0), se[:, None],
                              axis=1)[:, 0] - 1
    keep = pos < C
    slot_sorted = jnp.where(keep, se * C + pos, c.n_experts * C)
    # per-slot source token (int32 scatter over E*C+1 — tiny)
    slot_src = jnp.full((c.n_experts * C + 1,), S, jnp.int32)
    slot_src = slot_src.at[slot_sorted].set(st.astype(jnp.int32))
    # per-(token,k) slot id, unsorted (int32 scatter over S*k — tiny)
    inv = jnp.zeros((S * c.top_k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    slot_tk = inv.reshape(S, c.top_k)
    return gates, slot_src, slot_tk


def moe_ffn(p, c: MoECfg, x, mask, site: linearize.MaskSite,
            shared_mask=None, shared_site=None, *, poly=None,
            shared_poly=None, soft=False, act_spec=None):
    """x: (B, S, d).  mask: (E, F) per-expert channel masks.  shared_poly:
    poly2 coefficients for the shared-expert FFN gate (the ``moe_shared``
    site — distinct from the routed experts' ``poly``).  act_spec: the
    model's (B,S,D) PartitionSpec — its batch axes are re-asserted on the
    (B,E,C,·) expert tensors (GSPMD drops batch sharding through the
    dispatch gathers otherwise — §Perf, mixtral)."""
    B, S, d = x.shape
    C = _capacity(c, S)
    bspec = act_spec[0] if act_spec is not None else None

    def keep_batch(t, last=None):
        if act_spec is None:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(bspec, *([None] * (t.ndim - 2) + [last])))
    logits = x.astype(jnp.float32) @ p["router"]

    def experts(xe):
        h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        # masked activation per expert: flatten (E, C, F) with (E, F) mask
        a = linearize.apply_masked_act(
            h.transpose(1, 0, 2), mask, site, poly=poly, soft=soft
        ).transpose(1, 0, 2)
        a = a * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
        return jnp.einsum("ecf,efd->ecd", a, p["w_down"])

    def row_scatter(xr, lr):
        xg, book = _dispatch_row(xr, lr, c, C)
        ye = experts(xg.reshape(c.n_experts, C, d))
        return _combine_row(ye.reshape(-1, d), book, S, d)

    def batched_gather(x, logits):
        """Batched (vmap-free) gather dispatch: d-wide ops are batched
        take_along_axis gathers, which GSPMD partitions along the batch axis
        without replication (a vmapped per-row gather does not — §Perf)."""
        gates, slot_src, slot_tk = jax.vmap(lambda lr: _route(lr, c, C))(
            logits)
        xpad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
        xe = jnp.take_along_axis(
            xpad, slot_src[:, :-1, None].astype(jnp.int32), axis=1)
        xe = keep_batch(xe.reshape(B, c.n_experts, C, d))
        h = keep_batch(jnp.einsum("becd,edf->becf", xe, p["w_gate"]),
                       "model")
        a = linearize.apply_masked_act(
            h.transpose(0, 2, 1, 3), mask, site, poly=poly, soft=soft
        ).transpose(0, 2, 1, 3)
        a = a * jnp.einsum("becd,edf->becf", xe, p["w_up"])
        ye = jnp.einsum("becf,efd->becd", a, p["w_down"]).reshape(B, -1, d)
        ye = keep_batch(ye)
        ypad = jnp.concatenate([ye, jnp.zeros((B, 1, d), ye.dtype)], axis=1)
        idx = jnp.minimum(slot_tk, c.n_experts * C).reshape(B, -1)
        ytk = jnp.take_along_axis(ypad, idx[..., None], axis=1)
        ytk = ytk.reshape(B, S, c.top_k, d)
        valid = (slot_tk < c.n_experts * C).astype(ytk.dtype)
        w = gates.astype(ytk.dtype) * valid
        return jnp.einsum("bskd,bsk->bsd", ytk, w)

    if c.dispatch == "gather":
        y = batched_gather(x, logits)
    else:
        y = jax.vmap(row_scatter)(x, logits)
    if "shared" in p:
        y = y + layers.ffn(p["shared"], x, shared_mask, shared_site,
                           poly=shared_poly, soft=soft)
    return y.astype(x.dtype)
