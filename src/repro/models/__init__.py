from . import layers, moe, ssm, lm, resnet  # noqa: F401
