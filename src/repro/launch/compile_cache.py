"""Opt-in JAX persistent compilation cache for sweep/bench restarts.

A resumed sweep (or a repeated benchmark run) re-traces the exact same
jitted evaluators and pays full XLA re-compilation for every one of them —
on the CPU containers this repo's smoke sweeps run in, recompiles dominate
restart latency.  :func:`enable` points jax's persistent compilation cache
at a directory (with the entry-size / compile-time thresholds dropped to
zero so the small smoke-scale executables qualify), and :func:`hit_counter`
subscribes to jax's cache telemetry so runners can log how much a restart
actually reused.

Wired behind ``--compile-cache DIR`` in ``examples/resnet18_bcd_pipeline.py``
and ``benchmarks/bench_bcd_eval.py``.  Cache keys include jax/XLA versions
and compile options, so a stale directory is never incorrect — just cold.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

_HIT_EVENT = "/jax/compilation_cache/cache_hits"
_MISS_EVENT = "/jax/compilation_cache/cache_misses"


def enable(cache_dir: str) -> None:
    """Turn on jax's persistent compilation cache rooted at ``cache_dir``.

    Safe to call before or after the first jit; creates the directory.
    Thresholds are zeroed so every executable is cached — the sweeps this
    serves re-jit many small programs, exactly the population the default
    "only big/slow compiles" policy would skip.
    """
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    try:
        # jax latches "is the cache used?" on the first compile of the
        # process; if any jit ran before enable(), unlatch it so the new
        # directory takes effect (no-op on a fresh process)
        from jax._src import compilation_cache
        compilation_cache.reset_cache()
    except Exception:
        pass


class HitCounter:
    """Counts persistent-cache hits/misses via jax's monitoring events.

    jax only exposes the persistent compilation cache's effectiveness as
    telemetry events; this adapter turns them into a queryable counter so
    runners can print "N of M compiles served from cache" at exit.
    """

    def __init__(self) -> None:
        """Subscribe to the cache-hit/miss monitoring events."""
        self.hits = 0
        self.misses = 0
        self._ok = False
        try:
            from jax._src import monitoring

            def _on_event(event: str, **kw) -> None:
                if event == _HIT_EVENT:
                    self.hits += 1
                elif event == _MISS_EVENT:
                    self.misses += 1

            monitoring.register_event_listener(_on_event)
            self._ok = True
        except Exception:           # jax internals moved: count nothing,
            pass                    # never break the run for telemetry

    def summary(self) -> Dict[str, int]:
        """``{"hits": N, "misses": M}`` observed since construction."""
        return {"hits": self.hits, "misses": self.misses}

    def log_line(self) -> str:
        """One human-readable line for the runner's exit log."""
        if not self._ok:
            return "[compile-cache] hit telemetry unavailable in this jax"
        total = self.hits + self.misses
        return (f"[compile-cache] {self.hits}/{total} compile requests "
                f"served from the persistent cache")


def hit_counter() -> HitCounter:
    """Construct a :class:`HitCounter` (call before the jits you care
    about; events fired earlier are not replayed)."""
    return HitCounter()


def entry_count(cache_dir: Optional[str]) -> int:
    """Number of cached executables under ``cache_dir`` (0 if unset or
    missing) — a coarse cross-process complement to :class:`HitCounter`.
    """
    if not cache_dir or not os.path.isdir(cache_dir):
        return 0
    return sum(1 for name in os.listdir(cache_dir)
               if not name.startswith("."))
