"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b \
        --shape train_4k --multi-pod --out reports/dryrun

Per cell it jits the train/prefill/decode step with production shardings,
``.lower().compile()``s it, prints memory_analysis() / cost_analysis(), and
writes a JSON record (roofline terms included) for EXPERIMENTS.md.

NOTE: the XLA_FLAGS line below MUST run before any other import — jax locks
the device count at first init.  Smoke tests / benches never import this
module, so they see the real single CPU device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, cell_applicable, get_config,
                           input_specs)
from repro.models.lm import LM
from repro.training import optimizer as opt_lib
from repro.training import serve as serve_lib
from repro.training import train as train_lib
from repro.analysis import roofline as rl
from repro.launch.mesh import dp_axes as mesh_dp_axes, make_production_mesh


def _mask_sds(model):
    sites = model.mask_sites()
    return {k: jax.ShapeDtypeStruct(s.shape, jnp.float32)
            for k, s in sites.items()}


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               fsdp: bool = True, remat: bool = True, donate: bool = True,
               overrides: dict | None = None, loss_chunk: int = 0):
    """Returns (lowered, meta) for one cell."""
    import dataclasses
    cfg = get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = mesh_dp_axes(mesh)
    model = LM(cfg)
    specs = input_specs(cfg, shape)
    mask_sds = _mask_sds(model)

    with jax.set_mesh(mesh):
        if shape.mode == "train":
            opt = opt_lib.adamw(lr=1e-4, grad_clip=1.0)
            tcfg = train_lib.TrainStepCfg(
                remat=remat, dp_axes=dp, fsdp=fsdp, loss_chunk=loss_chunk,
                seq_shard_acts=bool(int(os.environ.get(
                    "REPRO_SEQ_SHARD_ACTS", "0"))))
            step = train_lib.jit_train_step(model, opt, mesh, tcfg)
            state_sds = jax.eval_shape(
                lambda: train_lib.make_state(model, opt,
                                             jax.random.PRNGKey(0)))
            lowered = step.lower(state_sds, specs, mask_sds)
        elif shape.mode == "prefill":
            scfg = serve_lib.ServeCfg(dp_axes=dp, max_len=shape.seq_len,
                                      batch=shape.global_batch)
            jitted = serve_lib.jit_prefill(model, mesh, scfg,
                                           with_prefix=bool(cfg.prefix_len))
            params_sds = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            args = [params_sds, mask_sds, specs["tokens"], cache_sds]
            if cfg.prefix_len:
                args.append(specs["prefix_embeds"])
            lowered = jitted.lower(*args)
        else:  # decode
            scfg = serve_lib.ServeCfg(dp_axes=dp, max_len=shape.seq_len,
                                      batch=shape.global_batch)
            jitted = serve_lib.jit_decode_step(model, mesh, scfg)
            params_sds = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            lowered = jitted.lower(params_sds, mask_sds, specs["tokens"],
                                   cache_sds,
                                   jax.ShapeDtypeStruct((), jnp.int32))
    meta = {"arch": arch_id, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": mesh.size, "mode": shape.mode}
    return lowered, meta, cfg, shape


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             fsdp: bool = True, remat: bool = True, variant: str = "base",
             overrides: dict | None = None, loss_chunk: int = 0):
    """Lower + compile one (arch, shape, mesh) cell; return its JSON record
    (memory analysis, collectives, roofline terms) or a skip marker."""
    cfg = get_config(arch_id)
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "skipped": why}
    t0 = time.time()
    lowered, meta, cfg, shape = lower_cell(
        arch_id, shape_name, multi_pod=multi_pod, fsdp=fsdp, remat=remat,
        overrides=overrides, loss_chunk=loss_chunk)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = rl.xla_cost(compiled)
    hlo = compiled.as_text()
    g = cfg.remat_group if (meta["mode"] == "train"
                            and cfg.remat_group > 1) else 1
    coll = rl.parse_collectives(hlo, meta["chips"],
                                loop_trip_count=max(1, cfg.n_repeats // g))
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    an_flops, an_bytes = rl.analytic_cell(cfg, shape, meta["mode"],
                                          remat=remat)
    roof = rl.Roofline(
        arch=arch_id, shape=shape_name, mesh=meta["mesh"],
        chips=meta["chips"], flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_global=coll.bytes_moved_global,
        model_flops_global=rl.model_flops(cfg, shape, meta["mode"]),
        analytic_flops_global=an_flops, analytic_bytes_global=an_bytes)
    rec = dict(meta)
    rec.update({
        "variant": variant,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes_per_device": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
        "collectives": coll.counts,
        "collectives_in_loop": coll.in_loop_count,
        "collective_bytes_by_op": coll.bytes_by_op,
    })
    rec.update(roof.row())
    return rec


def main(argv=None):
    """CLI entry: run the selected dry-run cells and write their reports."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "scatter", "gather"])
    ap.add_argument("--remat-group", type=int, default=None)
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args(argv)
    overrides = {}
    if args.moe_dispatch:
        overrides["moe_dispatch"] = args.moe_dispatch
    if args.remat_group:
        overrides["remat_group"] = args.remat_group

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}.{shape}.{'2x16x16' if mp else '16x16'}" \
                      + ("" if args.variant == "base" else f".{args.variant}")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip-cached] {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   fsdp=not args.no_fsdp,
                                   remat=not args.no_remat,
                                   variant=args.variant,
                                   overrides=overrides or None,
                                   loss_chunk=args.loss_chunk)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if "error" in rec:
                    print(f"[FAIL] {tag}: {rec['error']}")
                elif "skipped" in rec:
                    print(f"[skipped] {tag}: {rec['skipped']}")
                else:
                    print(f"[ok] {tag} compile={rec['compile_s']}s "
                          f"peak={rec['peak_bytes_per_device']/2**30:.2f}GiB "
                          f"dom={rec['bottleneck']} "
                          f"roofline={rec['roofline_fraction']:.3f}")
                sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
