"""Deterministic fault injection for the serving tier.

Overload/failure robustness is only testable if failure is *reproducible*:
a chaos run that sheds different requests every time cannot be gated in CI.
This module gives the serve loop three deterministic primitives:

- :class:`FaultPlan` — a seedable schedule of injected faults at named
  **crosspoints** (``prefill``, ``decode``, ``fingerprint``, ``burst``).
  Each crosspoint owns an independent counter-based RNG stream, so the draw
  sequence at one crosspoint is invariant to how often the others fire;
  the same ``(specs, seed)`` pair replays the exact same fault schedule.
- :class:`RetryPolicy` — per-crosspoint bounded retry with linear backoff
  and an injected-delay timeout, so every injected fault is either retried
  to success, degraded, or shed — never a hung loop.
- :class:`VirtualClock` — a monotonically advancing logical clock the loop
  can substitute for ``time.perf_counter``.  Virtual time advances by the
  *modeled* cost of each operation (the PI protocol's per-token latency),
  making every timestamp — and therefore every deadline-driven
  admit/degrade/shed decision — bit-for-bit reproducible across runs and
  hosts.

``benchmarks/bench_serve.py --overload N --fault-plan default`` threads a
:func:`default_chaos_plan` through ``launch.serve_loop.ServeLoop``; the CI
``chaos-smoke`` job runs it twice and asserts the decision logs are
identical.  See ``docs/serving.md`` §"Overload & failure semantics".
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, Optional, Tuple

import numpy as np

#: The crosspoint names the serve loop injects at.  ``prefill``: the B=1
#: prefill call (kinds: fail, slow); ``decode``: a lane's decode tick
#: (kind: stall); ``fingerprint``: mask-set fingerprint verification at
#: admission (kind: corrupt); ``burst``: load-generator arrival bursts that
#: drive queues to their bound (kind: burst).
CROSSPOINTS = ("prefill", "decode", "fingerprint", "burst")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injectable fault: where, what, how often.

    ``rate`` is the per-opportunity injection probability; ``delay_s`` is
    the virtual delay a ``slow``/``stall`` fault adds; ``burst`` is the
    number of extra arrivals a ``burst`` fault injects at once.
    """

    crosspoint: str
    kind: str                  # fail | slow | stall | corrupt | burst
    rate: float
    delay_s: float = 0.0
    burst: int = 0

    def __post_init__(self):
        if self.crosspoint not in CROSSPOINTS:
            raise ValueError(
                f"unknown crosspoint {self.crosspoint!r} "
                f"(have: {CROSSPOINTS})")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate {self.rate} outside [0, 1]")


class FaultError(RuntimeError):
    """An injected fault fired at a crosspoint (carried for retry loops)."""

    def __init__(self, spec: FaultSpec, attempt: int):
        super().__init__(
            f"injected {spec.kind} fault at crosspoint "
            f"{spec.crosspoint!r} (attempt {attempt})")
        self.spec = spec
        self.attempt = attempt


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry for one crosspoint.

    ``max_attempts`` bounds total tries (first try included);
    ``backoff_s`` is added to the clock per failed attempt, scaled
    linearly (attempt 1 waits 1×, attempt 2 waits 2×, …);
    ``timeout_s``: an injected ``slow``/``stall`` delay beyond this is
    treated as a *failed* attempt (the caller timed the call out) rather
    than absorbed as latency.
    """

    max_attempts: int = 3
    backoff_s: float = 0.005
    timeout_s: float = math.inf


#: Per-crosspoint retry defaults used by ServeLoop when none are passed.
DEFAULT_RETRIES: Dict[str, RetryPolicy] = {
    "prefill": RetryPolicy(max_attempts=3, backoff_s=0.005),
    "decode": RetryPolicy(max_attempts=2, backoff_s=0.002),
    "fingerprint": RetryPolicy(max_attempts=2, backoff_s=0.0),
}


class FaultPlan:
    """A deterministic, seedable schedule of faults over crosspoints.

    Each crosspoint draws from its own :func:`numpy.random.default_rng`
    stream seeded by ``(seed, sha256(crosspoint))``, so the schedule at one
    crosspoint does not shift when another crosspoint is consulted more or
    fewer times.  Given the same specs, seed, and per-crosspoint call
    sequence (which the virtual clock makes deterministic), :meth:`draw`
    returns the identical fault sequence on every run.
    """

    def __init__(self, specs: Tuple[FaultSpec, ...], seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._by_cross: Dict[str, Tuple[FaultSpec, ...]] = {
            c: tuple(s for s in self.specs if s.crosspoint == c)
            for c in CROSSPOINTS}
        self._rngs = {c: np.random.default_rng(
            [self.seed, _stable_id(c)]) for c in CROSSPOINTS}
        self.injected: Dict[str, Dict[str, int]] = {}

    def draw(self, crosspoint: str) -> Optional[FaultSpec]:
        """One injection opportunity; returns the fault to inject or None.

        Consumes exactly one uniform per spec declared at the crosspoint
        (fixed consumption keeps later draws aligned regardless of which
        faults fired earlier); the first spec whose rate covers its draw
        wins.
        """
        rng = self._rngs[crosspoint]
        hit = None
        for spec in self._by_cross[crosspoint]:
            u = float(rng.random())
            if hit is None and u < spec.rate:
                hit = spec
        if hit is not None:
            per = self.injected.setdefault(crosspoint, {})
            per[hit.kind] = per.get(hit.kind, 0) + 1
        return hit

    def stats(self) -> dict:
        """JSON-ready injected-fault counts per crosspoint and kind."""
        return {c: dict(kinds) for c, kinds in sorted(self.injected.items())}

    def describe(self) -> dict:
        """JSON-ready identity of the plan (for bench report configs)."""
        return {"seed": self.seed,
                "specs": [dataclasses.asdict(s) for s in self.specs]}


def _stable_id(name: str) -> int:
    """Process-invariant 32-bit id for a crosspoint name (hash() is salted
    per process, which would break cross-run determinism)."""
    return int.from_bytes(
        hashlib.sha256(name.encode()).digest()[:4], "big")


def default_chaos_plan(seed: int = 0) -> FaultPlan:
    """The committed chaos schedule the CI ``chaos-smoke`` job runs.

    Covers every crosspoint: failed and slow prefills, decode stalls,
    corrupted mask-set fingerprints, and queue-filling arrival bursts.
    Rates are chosen so a ~40-request overload run injects several faults
    of each kind while still completing quickly on a CPU runner.
    """
    return FaultPlan((
        FaultSpec("prefill", "fail", rate=0.12),
        FaultSpec("prefill", "slow", rate=0.10, delay_s=0.25),
        FaultSpec("decode", "stall", rate=0.06, delay_s=0.10),
        FaultSpec("fingerprint", "corrupt", rate=0.08),
        FaultSpec("burst", "burst", rate=0.12, burst=3),
    ), seed=seed)


def corrupt_fingerprint(fingerprint: str) -> str:
    """The garbage hash a ``corrupt`` fault makes verification observe
    (deterministic: flips the real digest, so it never accidentally
    matches)."""
    return hashlib.sha256(
        ("corrupt:" + fingerprint).encode()).hexdigest()


class VirtualClock:
    """Deterministic logical clock: ``now()`` returns accumulated seconds.

    The serve loop advances it by the *modeled* cost of each operation
    (PI per-token latency × tokens, injected delays, retry backoff).  With
    every timestamp derived from the model instead of the host, deadline
    arithmetic — and every admit/degrade/shed decision downstream of it —
    replays bit-for-bit under the same seed and fault plan.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        """Current virtual time in seconds."""
        return self._t

    def advance(self, seconds: float) -> float:
        """Move time forward (negative advances are rejected)."""
        if seconds < 0:
            raise ValueError(f"cannot advance time by {seconds}")
        self._t += float(seconds)
        return self._t
