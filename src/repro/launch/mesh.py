"""Production meshes.  Functions, not constants — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod meshes: single pod (16,16) data×model (256 chips);
    multi-pod (2,16,16) pod×data×model (512 chips).

    On the host-platform dry-run there are 512 placeholder devices; the
    single-pod mesh uses the first 256 (jax.make_mesh requires an exact
    device count, so we fall back to an explicit subset when needed).
    """
    import numpy as np
    from jax.sharding import Mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()[: data * model]
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))


def make_candidate_mesh(n_devices: int | None = None):
    """1-D mesh over local devices for BCD candidate-parallel evaluation.

    The candidate axis of a stacked mask tree shards over ``"cand"``
    (core.engine.ShardedEvaluator); params/data replicate.  Works on any
    device count including 1 (degenerates to the batched evaluator).
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    assert n <= len(devs), f"need {n} devices, have {len(devs)}"
    return Mesh(np.array(devs[:n]), ("cand",))


def make_cand_batch_mesh(cand: int | None = None, batch: int | None = None):
    """2-D ``("cand", "batch")`` mesh for joint candidate×batch BCD eval.

    A pure candidate layout idles ``n_devices - RT`` devices whenever a trial
    chunk has fewer candidates than the mesh has devices; this mesh lets
    ``core.engine.ShardedEvaluator`` shard small chunks over ``"cand"`` while
    a batch-sharded evaluator context splits each candidate's forward over
    ``"batch"`` (big chunks still shard jointly over both axes — the spec is
    chosen per call).  Give either factor; the other defaults to using every
    local device.  ``batch`` must divide the eval-batch leading dim.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs)
    if cand is None and batch is None:
        cand, batch = n, 1
    elif cand is None:
        cand = n // batch
    elif batch is None:
        batch = n // cand
    assert cand >= 1 and batch >= 1, (cand, batch)
    assert cand * batch <= n, \
        f"need {cand}x{batch} devices, have {n}"
    return Mesh(np.array(devs[:cand * batch]).reshape(cand, batch),
                ("cand", "batch"))


def dp_axes(mesh) -> tuple:
    """Mesh axes that carry data parallelism (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def process_info() -> tuple:
    """``(process_index, process_count)`` of this host in the jax job.

    The bridge between jax's multi-process runtime and
    :mod:`repro.launch.coordinator`: on a real cluster
    (``jax.distributed.initialize``) a launcher maps these onto
    ``REPRO_COORD_RANK``/``REPRO_COORD_WORLD``; single-process runs get
    ``(0, 1)``.  Calling this initializes jax's backend, so launch-time code
    should consult the coordinator env vars first (coordinator.from_env)
    and fall back here only when it actually needs device state.
    """
    return int(jax.process_index()), int(jax.process_count())
