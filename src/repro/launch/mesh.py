"""Production meshes.  Functions, not constants — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod meshes: single pod (16,16) data×model (256 chips);
    multi-pod (2,16,16) pod×data×model (512 chips).

    On the host-platform dry-run there are 512 placeholder devices; the
    single-pod mesh uses the first 256 (jax.make_mesh requires an exact
    device count, so we fall back to an explicit subset when needed).
    """
    import numpy as np
    from jax.sharding import Mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()[: data * model]
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))


def make_candidate_mesh(n_devices: int | None = None):
    """1-D mesh over local devices for BCD candidate-parallel evaluation.

    The candidate axis of a stacked mask tree shards over ``"cand"``
    (core.engine.ShardedEvaluator); params/data replicate.  Works on any
    device count including 1 (degenerates to the batched evaluator).
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    assert n <= len(devs), f"need {n} devices, have {len(devs)}"
    return Mesh(np.array(devs[:n]), ("cand",))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
