"""Multi-budget BCD sweep driver: the paper's accuracy-vs-budget curve.

The headline experiment (Fig. 4 protocol) descends a budget schedule
``[B1 > B2 > ... > B_target]`` with finetuning interleaved, warm-starting
each stage from the previous stage's result and stage 0 from an SNL or
AutoReP reference checkpoint.  ``run_sweep`` turns that into a restartable
pipeline on top of ``core.runner``:

    out_dir/
        init/                    stage-init checkpoint (warm start, persisted
                                 on first run; later runs load it so a resume
                                 never depends on the caller re-deriving it)
        stage_00_b<B1>/
            ckpt/                BCDRunner checkpoints (one per accepted block)
            final/               stage-init checkpoint for stage 1's warm start
            result.json          stage summary (written only on completion)
        stage_01_b<B2>/ ...
        SWEEP_<name>.json        the curve artifact, rewritten after every
                                 stage

Kill the process at ANY point — including SIGKILL mid-stage — and rerunning
the same command resumes: completed stages are skipped via their
``result.json`` + ``final/`` checkpoint, and the in-flight stage resumes from
its newest valid runner checkpoint, replaying bit-identically (same blocks,
same logs; ``wall_s`` excepted).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, List, Optional, Tuple

from repro.core import bcd as bcd_lib
from repro.core import masks as M
from repro.core import runner as runner_lib


@dataclasses.dataclass
class SweepConfig:
    budgets: List[int]            # strictly descending ReLU budgets
    out_dir: str
    name: str = "model"           # artifact: SWEEP_<name>.json
    checkpoint_every: int = 1
    keep: int = 3
    verbose: bool = False

    def validate(self, b_init: Optional[int] = None) -> None:
        if not self.budgets:
            raise ValueError("sweep schedule is empty")
        if any(b < 0 for b in self.budgets):
            raise ValueError(f"budgets must be >= 0: {self.budgets}")
        if any(a <= b for a, b in zip(self.budgets, self.budgets[1:])):
            raise ValueError(
                f"sweep schedule must be strictly descending: {self.budgets}")
        if b_init is not None and self.budgets[0] >= b_init:
            raise ValueError(
                f"first sweep budget {self.budgets[0]} must be below the "
                f"warm-start budget {b_init}")


def _stage_dir(cfg: SweepConfig, i: int) -> str:
    return os.path.join(cfg.out_dir, f"stage_{i:02d}_b{cfg.budgets[i]}")


def init_dir(cfg: SweepConfig) -> str:
    """The persisted warm-start location (callers must not hardcode it)."""
    return os.path.join(cfg.out_dir, "init")


def artifact_path(cfg: SweepConfig) -> str:
    return os.path.join(cfg.out_dir, f"SWEEP_{cfg.name}.json")


def _atomic_write_json(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2)
    os.replace(tmp, path)


def update_notes(cfg: SweepConfig, extra: dict) -> None:
    """Atomically merge keys into the artifact's ``notes`` (e.g. the
    auto-prefetch report, known only after the run)."""
    path = artifact_path(cfg)
    with open(path) as f:
        payload = json.load(f)
    payload.setdefault("notes", {}).update(extra)
    _atomic_write_json(path, payload)


def _log_jsonable(h: bcd_lib.BCDStepLog) -> dict:
    """A step log for the curve artifact, with ``wall_s`` split out: the
    remaining fields are the run's deterministic identity (what the
    kill-and-resume smoke job compares across runs)."""
    d = dataclasses.asdict(h)
    d.pop("wall_s")
    return d


def _write_artifact(cfg: SweepConfig, stages: List[dict],
                    complete: bool, notes: Optional[dict] = None) -> dict:
    path = artifact_path(cfg)
    # keep notes keys added out-of-band (update_notes) across rewrites —
    # a resumed sweep must not silently drop e.g. the auto-prefetch report
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f).get("notes", {}) or {}
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(notes or {})
    payload = {
        "name": cfg.name,
        "schedule": list(cfg.budgets),
        "complete": complete,
        "stages": stages,
        "notes": merged,
    }
    _atomic_write_json(path, payload)
    payload["artifact"] = path
    return payload


def run_sweep(
    sweep_cfg: SweepConfig,
    make_bcd_cfg: Callable[[int], bcd_lib.BCDConfig],
    eval_acc: Callable[[M.MaskTree], float],
    *,
    init: Optional[dict] = None,
    finetune: Optional[Callable[[M.MaskTree], None]] = None,
    evaluator=None,
    params_io: Optional[Tuple[Callable[[], object],
                              Callable[[object], None]]] = None,
    eval_test: Optional[Callable[[M.MaskTree], float]] = None,
    notes: Optional[dict] = None,
) -> dict:
    """Descend the budget schedule; returns the curve artifact payload.

    make_bcd_cfg(budget) builds each stage's BCDConfig (b_target must equal
    the budget).  ``init`` — a ``{kind, masks, params, aux}`` warm start
    (e.g. ``SNLResult.stage_init()``) — is required on the first run and
    ignored afterwards: the persisted ``out_dir/init`` checkpoint wins, so
    resumed sweeps never drift from the original warm start.  ``params_io``
    and ``finetune`` follow the :class:`~repro.core.runner.BCDRunner`
    contract; ``eval_test`` (optional) scores each completed stage for the
    curve.  ``notes`` is stored verbatim in the artifact.
    """
    os.makedirs(sweep_cfg.out_dir, exist_ok=True)
    init_path = init_dir(sweep_cfg)

    # -- warm start: persisted init wins over the caller's argument (so a
    # resumed sweep can never drift from its original warm start); the
    # argument doubles as the restore template, so it is always required
    if init is None:
        raise ValueError(
            "run_sweep needs `init`: the warm start on the first run, the "
            "restore template (mask shapes / params structure) on a resume")
    try:
        start = runner_lib.load_stage_init(
            init_path, init["masks"],
            params_template=params_io[0]() if params_io else None)
    except runner_lib.CheckpointError:      # absent/corrupt: first run
        runner_lib.save_stage_init(init_path, init)
        start = dict(init)
    b_init = M.count(start["masks"])
    sweep_cfg.validate(b_init)

    masks = start["masks"]
    if params_io is not None and start.get("params") is not None:
        params_io[1](start["params"])

    stages: List[dict] = []
    complete = True
    for i, budget in enumerate(sweep_cfg.budgets):
        sdir = _stage_dir(sweep_cfg, i)
        result_path = os.path.join(sdir, "result.json")
        final_dir = os.path.join(sdir, "final")
        bcd_cfg = make_bcd_cfg(budget)
        if bcd_cfg.b_target != budget:
            raise ValueError(
                f"make_bcd_cfg({budget}).b_target == {bcd_cfg.b_target}")

        if os.path.exists(result_path):
            try:
                # completed stage: reuse its summary, warm-start from final
                done = runner_lib.load_stage_init(
                    final_dir, masks,
                    params_template=params_io[0]() if params_io else None)
                with open(result_path) as f:
                    stage = json.load(f)
            except (runner_lib.CheckpointError, json.JSONDecodeError,
                    OSError):
                pass            # final/ or summary unusable: re-run below
            else:
                masks = done["masks"]
                if params_io is not None and done.get("params") is not None:
                    params_io[1](done["params"])
                if sweep_cfg.verbose:
                    print(f"[sweep] stage {i} (b={budget}) already complete "
                          "— skipped")
                stages.append(stage)
                # no artifact rewrite here: nothing new happened, and
                # clobbering a complete artifact with a partial one would
                # open a crash window on an otherwise-finished sweep
                continue

        t0 = time.perf_counter()
        runner = runner_lib.BCDRunner(
            bcd_cfg,
            runner_lib.RunnerConfig(
                ckpt_dir=os.path.join(sdir, "ckpt"),
                checkpoint_every=sweep_cfg.checkpoint_every,
                keep=sweep_cfg.keep, verbose=sweep_cfg.verbose),
            eval_acc, finetune, evaluator=evaluator, params_io=params_io)
        res = runner.run(masks)
        if runner.stopped_early:
            complete = False
            break
        masks = res.masks

        stage = {
            "stage": i,
            "budget": budget,
            "mask_fingerprint": M.fingerprint(masks),
            "steps": len(res.history),
            "trials_total": int(sum(h.trials for h in res.history)),
            "history": [_log_jsonable(h) for h in res.history],
            "resumed_from": runner.resumed_from,
            "wall_s": time.perf_counter() - t0,
        }
        if eval_test is not None:
            stage["test_acc"] = float(eval_test(masks))
        # persist the stage's warm-start for its successor BEFORE the
        # summary: a crash between the two re-runs a no-op stage rather
        # than warm-starting from a missing checkpoint
        runner_lib.save_stage_init(final_dir, {
            "kind": "bcd_stage", "masks": masks,
            "params": params_io[0]() if params_io else None})
        _atomic_write_json(result_path, stage)
        stages.append(stage)
        _write_artifact(sweep_cfg, stages, False, notes)
        if sweep_cfg.verbose:
            acc = stage.get("test_acc")
            print(f"[sweep] stage {i} done: b={budget} "
                  f"fingerprint={stage['mask_fingerprint'][:12]} "
                  f"acc={acc if acc is not None else 'n/a'}")

    complete = complete and len(stages) == len(sweep_cfg.budgets)
    payload = _write_artifact(sweep_cfg, stages, complete, notes)
    payload["final_masks"] = masks
    return payload
