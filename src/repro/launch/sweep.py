"""Multi-budget BCD sweep driver: the paper's accuracy-vs-budget curve.

The headline experiment (Fig. 4 protocol) descends a budget schedule
``[B1 > B2 > ... > B_target]`` with finetuning interleaved, warm-starting
each stage from the previous stage's result and stage 0 from an SNL or
AutoReP reference checkpoint.  ``run_sweep`` turns that into a restartable
pipeline on top of ``core.runner``:

    out_dir/
        init/                    stage-init checkpoint (warm start, persisted
                                 on first run; later runs load it so a resume
                                 never depends on the caller re-deriving it)
        stage_00_b<B1>/
            ckpt/                BCDRunner checkpoints (one per accepted block)
            final/               stage-init checkpoint for stage 1's warm start
            result.json          stage summary (written only on completion)
        stage_01_b<B2>/ ...
        SWEEP_<name>.json        the curve artifact, rewritten after every
                                 stage

Kill the process at ANY point — including SIGKILL mid-stage — and rerunning
the same command resumes: completed stages are skipped via their
``result.json`` + ``final/`` checkpoint, and the in-flight stage resumes from
its newest valid runner checkpoint, replaying bit-identically (same blocks,
same logs; ``wall_s`` excepted).

**Overlapped stages** (``SweepConfig(overlap=True)``): stage ``i+1``'s BCD
descent launches the moment stage ``i``'s accepted-mask stage-init lands in
``final/``, while stage ``i``'s *reporting tail* — the per-stage
``stage_finetune`` and ``stage_eval`` scoring pass — completes concurrently
on a worker thread.  The descent lineage (masks + lightly-finetuned params)
never waits on the reporting tail in either mode, so overlapped and serial
sweeps emit bit-identical masks and step histories; only wall-clock and the
time at which ``test_acc`` lands in the artifact differ.

**Multi-host** (``coordinator=``): every rank runs the same deterministic
descent; only the writer rank commits stage-inits, summaries, and the curve
artifact (readers rendezvous at per-stage barriers and read the writer's
files).  See :mod:`repro.launch.coordinator` and ``docs/architecture.md``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, List, Optional, Tuple

import numpy as np
import jax

from repro.core import bcd as bcd_lib
from repro.core import engine
from repro.core import masks as M
from repro.core import runner as runner_lib


def make_bcd_evaluator(engine_name: str, model, eval_b, holder, *,
                       chunk_size: int, rt: int, prefetch=2,
                       fused_kernels: bool = True):
    """Build the BCD candidate engine for any model family.

    Model-agnostic: works for every model exposing the shared eval-closure
    contract (``make_param_eval_fn`` / ``make_suffix_eval_fns`` — CNNs and
    every ``models.lm`` family, including scanned-stack SSM/RWKV and MoE
    configs whose suffix engine cuts mid-scan via carry checkpoints).
    Params are evaluator *context* (a jit input) because finetuning
    rewrites them between outer steps; ``holder`` is the live
    ``{"params": ...}`` box the caller mutates.

    Returns ``(evaluator, eval_acc, set_ctx)``: call ``set_ctx(params)``
    after every finetune — engines differ in context shape (the suffix
    engine carries the eval batch alongside params), so callers never
    touch ``set_context`` directly.  ``fused_kernels=False`` keeps the
    activation gate un-fused on the suffix backend (required when the move
    set can produce share ties — see ``linearize._apply_share_ties``).
    """
    eval_fn_p = model.make_param_eval_fn(eval_b)
    acc_jit = jax.jit(eval_fn_p)
    eval_acc = lambda m: float(acc_jit(M.as_device(m), holder["params"]))
    if engine_name == "sequential":
        return engine.make_evaluator("sequential", eval_acc=eval_acc), \
            eval_acc, lambda p: None
    # don't let ragged-chunk padding exceed RT (sharded may still
    # round up to the device count; extras are sliced off)
    pad = min(chunk_size, rt)
    if engine_name == "suffix":
        batch_np = {k: np.asarray(v) for k, v in eval_b.items()}
        evaluator = engine.make_evaluator(
            "suffix", split=model.make_suffix_eval_fns(),
            context={"params": holder["params"], "batch": batch_np},
            pad_to=pad, prefetch=prefetch, fused_kernels=fused_kernels)
        return evaluator, eval_acc, lambda p: evaluator.set_context(
            {"params": p, "batch": batch_np})
    evaluator = engine.make_evaluator(
        engine_name, eval_fn=eval_fn_p, pad_to=pad,
        context=holder["params"], prefetch=prefetch)
    return evaluator, eval_acc, evaluator.set_context


@dataclasses.dataclass
class SweepConfig:
    """Schedule + layout knobs for one sweep (see module docstring).

    ``overlap`` moves each stage's reporting tail (``stage_finetune`` +
    ``stage_eval``) onto a background thread so the next stage's descent
    starts immediately; mask selection is bit-identical either way.
    """

    budgets: List[int]            # strictly descending ReLU budgets
    out_dir: str
    name: str = "model"           # artifact: SWEEP_<name>.json
    checkpoint_every: int = 1
    keep: int = 3
    overlap: bool = False         # overlap stage i's reporting with i+1
    wait_timeout_s: float = 300.0   # multi-host readers: max wait for the
    #                                 writer's commit before declaring it
    #                                 dead (RunnerConfig.wait_timeout_s)
    verbose: bool = False

    def validate(self, b_init: Optional[int] = None) -> None:
        """Reject schedules that cannot descend (empty, non-descending,
        negative, or not strictly below the ``b_init`` warm-start budget)."""
        if not self.budgets:
            raise ValueError("sweep schedule is empty")
        if any(b < 0 for b in self.budgets):
            raise ValueError(f"budgets must be >= 0: {self.budgets}")
        if any(a <= b for a, b in zip(self.budgets, self.budgets[1:])):
            raise ValueError(
                f"sweep schedule must be strictly descending: {self.budgets}")
        if b_init is not None and self.budgets[0] >= b_init:
            raise ValueError(
                f"first sweep budget {self.budgets[0]} must be below the "
                f"warm-start budget {b_init}")


def _stage_dir(cfg: SweepConfig, i: int) -> str:
    return os.path.join(cfg.out_dir, f"stage_{i:02d}_b{cfg.budgets[i]}")


def init_dir(cfg: SweepConfig) -> str:
    """The persisted warm-start location (callers must not hardcode it)."""
    return os.path.join(cfg.out_dir, "init")


def artifact_path(cfg: SweepConfig) -> str:
    """Where the curve artifact (``SWEEP_<name>.json``) lands."""
    return os.path.join(cfg.out_dir, f"SWEEP_{cfg.name}.json")


def _atomic_write_json(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2)
    os.replace(tmp, path)


def update_notes(cfg: SweepConfig, extra: dict) -> None:
    """Atomically merge keys into the artifact's ``notes`` (e.g. the
    auto-prefetch report, known only after the run)."""
    path = artifact_path(cfg)
    with open(path) as f:
        payload = json.load(f)
    payload.setdefault("notes", {}).update(extra)
    _atomic_write_json(path, payload)


def _log_jsonable(h: bcd_lib.BCDStepLog) -> dict:
    """A step log for the curve artifact, with ``wall_s`` split out: the
    remaining fields are the run's deterministic identity (what the
    kill-and-resume smoke job compares across runs)."""
    d = dataclasses.asdict(h)
    d.pop("wall_s")
    return d


def _merged_notes(cfg: SweepConfig, notes: Optional[dict]) -> dict:
    """Caller notes merged over any already in the on-disk artifact — keys
    added out-of-band (update_notes, e.g. the auto-prefetch report) must
    survive rewrites and appear in every rank's returned payload."""
    merged = {}
    path = artifact_path(cfg)
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f).get("notes", {}) or {}
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(notes or {})
    return merged


def _payload(cfg: SweepConfig, stages: List[dict], complete: bool,
             notes: Optional[dict]) -> dict:
    return {
        "name": cfg.name,
        "schedule": list(cfg.budgets),
        "complete": complete,
        "stages": stages,
        "notes": _merged_notes(cfg, notes),
    }


def _write_artifact(cfg: SweepConfig, stages: List[dict],
                    complete: bool, notes: Optional[dict] = None) -> dict:
    path = artifact_path(cfg)
    payload = _payload(cfg, stages, complete, notes)
    _atomic_write_json(path, payload)
    payload["artifact"] = path
    return payload


class _StageReporter:
    """Runs each completed stage's reporting tail and folds the score back
    into ``result.json`` + the curve artifact.

    Serial mode calls :meth:`submit` inline; overlap mode runs it on a
    daemon thread so the next stage's descent proceeds immediately.  All
    file writes and ``stages`` mutations happen under one lock shared with
    the sweep loop.  A crash mid-report leaves ``result.json`` without
    ``test_acc``; the resume path notices and re-submits, so the artifact
    converges to fully-scored either way.
    """

    def __init__(self, cfg: SweepConfig, stages: List[dict],
                 stage_finetune, stage_eval, eval_test,
                 notes: Optional[dict]):
        self.cfg = cfg
        self.stages = stages
        self.lock = threading.Lock()
        self._stage_finetune = stage_finetune
        self._stage_eval = stage_eval
        self._eval_test = eval_test
        self._notes = notes
        self._threads: List[threading.Thread] = []
        self._errors: List[BaseException] = []

    @property
    def scores(self) -> bool:
        """Whether any reporting callback was supplied at all."""
        return (self._stage_finetune is not None
                or self._stage_eval is not None
                or self._eval_test is not None)

    def _report(self, i: int, stage: dict, masks: M.MaskTree,
                params) -> None:
        if self._stage_finetune is not None:
            params = self._stage_finetune(params, masks)
        if self._stage_eval is not None:
            acc = float(self._stage_eval(masks, params))
        elif self._eval_test is not None:
            acc = float(self._eval_test(masks))
        else:
            return
        with self.lock:
            stage["test_acc"] = acc
            _atomic_write_json(
                os.path.join(_stage_dir(self.cfg, i), "result.json"),
                stage)
            self._fold_into_artifact(i, stage)
        if self.cfg.verbose:
            print(f"[sweep] stage {i} scored: test_acc={acc:.2f}")

    def _fold_into_artifact(self, i: int, stage: dict) -> None:
        """Merge one scored stage into the artifact (caller holds the lock).

        On a resume re-score the on-disk artifact may already describe MORE
        stages than this loop has revisited — patch the stage in place
        rather than clobbering a complete artifact with a partial stages
        list (the same crash-window rule the skip path follows).
        """
        path = artifact_path(self.cfg)
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = None
        if existing is not None and \
                len(existing.get("stages", [])) > len(self.stages) and \
                i < len(existing["stages"]):
            existing["stages"][i] = stage
            _atomic_write_json(path, existing)
        else:
            _write_artifact(self.cfg, list(self.stages), False, self._notes)

    def _report_in_thread(self, i, stage, masks, params) -> None:
        try:
            self._report(i, stage, masks, params)
        except BaseException as e:          # surfaced at join()
            self._errors.append(e)
            if not isinstance(e, Exception):
                raise

    def submit(self, i: int, stage: dict, masks: M.MaskTree,
               params) -> None:
        """Score stage ``i`` — inline (serial) or on a thread (overlap).

        ``masks``/``params`` must be snapshots the descent loop will not
        mutate: the mask tree is copied here; params are expected to be
        functionally-updated pytrees (the repo-wide convention), so holding
        the reference is safe.
        """
        if not self.scores:
            return
        masks = {k: v.copy() for k, v in masks.items()}
        if self.cfg.overlap:
            t = threading.Thread(target=self._report_in_thread,
                                 args=(i, stage, masks, params),
                                 name=f"sweep-report-{i}", daemon=True)
            self._threads.append(t)
            t.start()
        else:
            # inline: a scoring failure aborts the sweep immediately —
            # never descend further stages on a broken reporting tail
            self._report(i, stage, masks, params)

    def join(self, reraise: bool = True) -> None:
        """Wait for in-flight reports; re-raise the first failure.

        ``reraise=False`` (the error-unwind path) still waits — abandoning
        a thread mid-write to ``result.json`` is how artifacts corrupt —
        but only prints stored scoring errors, preserving the primary
        exception already propagating.
        """
        for t in self._threads:
            t.join()
        if self._errors:
            if reraise:
                raise self._errors[0]
            for e in self._errors:
                print(f"[sweep] stage scoring also failed during unwind: "
                      f"{type(e).__name__}: {e}")


def run_sweep(
    sweep_cfg: SweepConfig,
    make_bcd_cfg: Callable[[int], bcd_lib.BCDConfig],
    eval_acc: Callable[[M.MaskTree], float],
    *,
    init: Optional[dict] = None,
    finetune: Optional[Callable[[M.MaskTree], None]] = None,
    evaluator=None,
    params_io: Optional[Tuple[Callable[[], object],
                              Callable[[object], None]]] = None,
    eval_test: Optional[Callable[[M.MaskTree], float]] = None,
    stage_finetune: Optional[Callable[[object, M.MaskTree], object]] = None,
    stage_eval: Optional[Callable[[M.MaskTree, object], float]] = None,
    notes: Optional[dict] = None,
    coordinator=None,
) -> dict:
    """Descend the budget schedule; returns the curve artifact payload.

    ``make_bcd_cfg(budget)`` builds each stage's BCDConfig (``b_target``
    must equal the budget).  ``init`` — a ``{kind, masks, params, aux}``
    warm start (e.g. ``SNLResult.stage_init()``) — is required on the first
    run and ignored afterwards: the persisted ``out_dir/init`` checkpoint
    wins, so resumed sweeps never drift from the original warm start.
    ``params_io`` and ``finetune`` follow the
    :class:`~repro.core.runner.BCDRunner` contract.  ``notes`` is stored
    verbatim in the artifact.

    Scoring each completed stage for the curve, two forms:

    - ``eval_test(masks) -> acc`` — legacy, serial-only: it may close over
      live state (e.g. the params holder), which the next stage mutates, so
      it is rejected when ``overlap=True`` unless ``stage_eval`` is given.
    - ``stage_finetune(params, masks) -> params'`` (optional) then
      ``stage_eval(masks, params') -> acc`` — the overlap-safe reporting
      tail.  Both must be pure in their arguments (no live holders): in
      overlap mode they run on a worker thread while the next stage's
      descent mutates the live params.  The finetuned params are *reporting
      only* — the descent lineage continues from the descent-end state in
      BOTH modes, which is why overlapped and serial sweeps produce
      bit-identical masks.

    ``coordinator`` (see :mod:`repro.launch.coordinator`) runs the sweep
    multi-host: all ranks descend identically, the writer rank owns every
    file, and readers rendezvous at per-stage barriers.
    """
    coord = coordinator
    is_writer = coord is None or coord.is_writer
    multi = coord is not None and coord.world_size > 1
    if sweep_cfg.overlap and eval_test is not None and stage_eval is None:
        raise ValueError(
            "overlap=True cannot use eval_test(masks): it may read state "
            "the next stage's descent is mutating concurrently — pass "
            "stage_eval(masks, params) (and optionally stage_finetune), "
            "which are pure in their arguments")
    if is_writer:
        os.makedirs(sweep_cfg.out_dir, exist_ok=True)
    init_path = init_dir(sweep_cfg)

    # -- warm start: persisted init wins over the caller's argument (so a
    # resumed sweep can never drift from its original warm start); the
    # argument doubles as the restore template, so it is always required
    if init is None:
        raise ValueError(
            "run_sweep needs `init`: the warm start on the first run, the "
            "restore template (mask shapes / params structure) on a resume")
    if is_writer:
        try:
            start = runner_lib.load_stage_init(
                init_path, init["masks"],
                params_template=params_io[0]() if params_io else None)
        except runner_lib.CheckpointError:      # absent/corrupt: first run
            runner_lib.save_stage_init(init_path, init)
            start = dict(init)
        if multi:
            coord.barrier("sweep_init")
    else:
        coord.barrier("sweep_init")             # wait for writer's persist
        start = runner_lib.load_stage_init(
            init_path, init["masks"],
            params_template=params_io[0]() if params_io else None)
    b_init = M.relu_cost(start["masks"])
    sweep_cfg.validate(b_init)

    masks = start["masks"]
    if params_io is not None and start.get("params") is not None:
        params_io[1](start["params"])

    stages: List[dict] = []
    reporter = _StageReporter(sweep_cfg, stages, stage_finetune, stage_eval,
                              eval_test, notes)
    masks_box = [masks]
    try:
        complete = _sweep_stages(
            sweep_cfg, make_bcd_cfg, eval_acc, finetune, evaluator,
            params_io, coord, is_writer, multi, masks_box,
            stages, reporter)
    except BaseException:
        # the descent failed: still drain in-flight scoring threads (an
        # abandoned thread mid-write corrupts artifacts) without letting a
        # secondary scoring error mask this one
        reporter.join(reraise=False)
        raise
    masks = masks_box[0]

    reporter.join()
    complete = complete and len(stages) == len(sweep_cfg.budgets)
    if is_writer:
        payload = _write_artifact(sweep_cfg, stages, complete, notes)
    else:
        # readers return the same payload shape without writing it
        payload = _payload(sweep_cfg, stages, complete, notes)
        payload["artifact"] = artifact_path(sweep_cfg)
    payload["final_masks"] = masks
    return payload


def _sweep_stages(sweep_cfg, make_bcd_cfg, eval_acc, finetune, evaluator,
                  params_io, coord, is_writer, multi, masks_box, stages,
                  reporter) -> bool:
    """The per-stage descent loop of :func:`run_sweep` (its docstring has
    the contract).  Mutates ``masks_box[0]``/``stages``; returns False when
    a stage stopped early (preemption drill), True otherwise."""
    masks = masks_box[0]
    for i, budget in enumerate(sweep_cfg.budgets):
        sdir = _stage_dir(sweep_cfg, i)
        result_path = os.path.join(sdir, "result.json")
        final_dir = os.path.join(sdir, "final")
        bcd_cfg = make_bcd_cfg(budget)
        if bcd_cfg.b_target != budget:
            raise ValueError(
                f"make_bcd_cfg({budget}).b_target == {bcd_cfg.b_target}")

        # -- skip-or-run: decided from the writer's filesystem view only.
        # Ranks deciding independently could diverge (e.g. a stale NFS
        # attribute cache hiding result.json from one rank), desynchronizing
        # the use-counted rendezvous sequence — so the writer decides and
        # every rank follows its broadcast.
        done = stage = None
        if is_writer and os.path.exists(result_path):
            try:
                # completed stage: reuse its summary, warm-start from final
                done = runner_lib.load_stage_init(
                    final_dir, masks,
                    params_template=params_io[0]() if params_io else None)
                with open(result_path) as f:
                    stage = json.load(f)
            except (runner_lib.CheckpointError, json.JSONDecodeError,
                    OSError):
                done = stage = None     # unusable: re-run below
        skip = done is not None
        if multi:
            skip = coord.broadcast(f"stage_plan_{i}",
                                   {"skip": skip} if is_writer else None
                                   )["skip"]
            if skip and not is_writer:
                # the writer just validated these files; a reader that
                # cannot load them is diverged, not behind — fail loudly
                # rather than re-running a completed stage solo
                done = runner_lib.load_stage_init(
                    final_dir, masks,
                    params_template=params_io[0]() if params_io else None)
                with open(result_path) as f:
                    stage = json.load(f)
        if skip:
            masks = masks_box[0] = done["masks"]
            if params_io is not None and done.get("params") is not None:
                params_io[1](done["params"])
            if sweep_cfg.verbose:
                print(f"[sweep] stage {i} (b={budget}) already complete "
                      "— skipped")
            with reporter.lock:
                stages.append(stage)
            # a crash between result.json and its score leaves the
            # stage unscored — finish the reporting tail on resume
            if is_writer and reporter.scores and "test_acc" not in stage:
                reporter.submit(i, stage, done["masks"], done["params"])
            # no full artifact rewrite here: nothing new happened, and
            # clobbering a complete artifact with a partial one would
            # open a crash window on an otherwise-finished sweep
            continue

        t0 = time.perf_counter()
        runner = runner_lib.BCDRunner(
            bcd_cfg,
            runner_lib.RunnerConfig(
                ckpt_dir=os.path.join(sdir, "ckpt"),
                checkpoint_every=sweep_cfg.checkpoint_every,
                keep=sweep_cfg.keep,
                wait_timeout_s=sweep_cfg.wait_timeout_s,
                verbose=sweep_cfg.verbose),
            eval_acc, finetune, evaluator=evaluator, params_io=params_io,
            coordinator=coord)
        res = runner.run(masks)
        if runner.stopped_early:
            return False
        masks = masks_box[0] = res.masks
        params_now = params_io[0]() if params_io else None

        if is_writer:
            stage = {
                "stage": i,
                "budget": budget,
                "mask_fingerprint": M.fingerprint(masks),
                "steps": len(res.history),
                "trials_total": int(sum(h.trials for h in res.history)),
                "history": [_log_jsonable(h) for h in res.history],
                "resumed_from": runner.resumed_from,
                "move_stats": res.move_stats,
                "wall_s": time.perf_counter() - t0,
            }
            # persist the stage's warm-start for its successor BEFORE the
            # summary: a crash between the two re-runs a no-op stage rather
            # than warm-starting from a missing checkpoint
            runner_lib.save_stage_init(final_dir, {
                "kind": "bcd_stage", "masks": masks, "params": params_now})
            with reporter.lock:
                _atomic_write_json(result_path, stage)
                stages.append(stage)
                _write_artifact(sweep_cfg, list(stages), False,
                                reporter._notes)
            if multi:
                coord.barrier(f"stage_done_{i}")
            # the reporting tail: inline when serial, concurrent with stage
            # i+1's descent when overlap=True — the descent lineage above
            # never depends on its output
            reporter.submit(i, stage, masks, params_now)
        else:
            coord.barrier(f"stage_done_{i}")
            with open(result_path) as f:
                stage = json.load(f)
            with reporter.lock:
                stages.append(stage)
        if sweep_cfg.verbose:
            print(f"[sweep] stage {i} done: b={budget} "
                  f"fingerprint={stage['mask_fingerprint'][:12]}")
    return True
