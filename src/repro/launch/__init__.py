# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from . import mesh, sweep  # noqa: F401
