"""Launch layer: meshes, process coordination, sweeps, and entry points.

NOTE: ``dryrun`` is deliberately NOT imported here — it sets XLA_FLAGS
(forced host device count) at import time, which must never happen in test
or production processes.
"""
from . import coordinator, mesh, sweep  # noqa: F401
