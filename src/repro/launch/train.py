"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_1p6b \
        --reduced --steps 20 --mesh 1,1 --ckpt-dir /tmp/ck

On a real cluster this binary runs once per host (jax.distributed.initialize
picks up the pod topology); here --mesh data,model builds the mesh over local
devices.  Uses the same jit_train_step the dry-run proves out, under the
fault-tolerance supervisor with checkpointing.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import linearize, masks as M
from repro.data import MarkovTokens, host_slice
from repro.models.lm import LM
from repro.training import ft
from repro.training import optimizer as opt_lib, train as train_lib
from .mesh import dp_axes as mesh_dp_axes, make_host_mesh


def main(argv=None):
    """CLI entry: supervised, checkpointed training over a local mesh."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1,1", help="data,model axis sizes")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat-group", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat_group=args.remat_group)
    d, m = (int(x) for x in args.mesh.split(","))
    mesh = make_host_mesh(d, m)
    model = LM(cfg)
    opt = opt_lib.adamw(lr=args.lr, grad_clip=1.0,
                        schedule=opt_lib.cosine(args.lr, args.steps))
    tcfg = train_lib.TrainStepCfg(remat=True, dp_axes=("data",),
                                  compress_grads=args.compress_grads)
    mt = MarkovTokens(cfg.vocab, seed=0)
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    sl = host_slice(args.global_batch)

    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        step_jit = train_lib.jit_train_step(model, opt, mesh, tcfg)

        def init_state():
            return train_lib.make_state(model, opt, jax.random.PRNGKey(0))

        losses = []

        def step_fn(state, i):
            b = mt.batch(args.global_batch, args.seq, i)
            b = {k: jnp.asarray(v[sl]) for k, v in b.items()}
            state, metrics = step_jit(state, b, masks)
            losses.append(float(metrics["loss"]))
            print(f"step {i} loss {losses[-1]:.4f}")
            return state

        out = ft.run_supervised(init_state, step_fn, n_steps=args.steps,
                                ckpt_dir=args.ckpt_dir,
                                ckpt_every=args.ckpt_every,
                                watchdog=ft.StragglerWatchdog())
    print(f"finished {out['completed_steps']} steps; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"restarts={out['restarts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
