"""Production serving launcher: batched prefill + decode over a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_3b --reduced \
        --batch 4 --prompt-len 16 --gen 8 --mesh 1,1
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import linearize, masks as M
from repro.models.lm import LM
from repro.training import serve as serve_lib
from .mesh import make_host_mesh


def main(argv=None):
    """CLI entry: batched prefill + decode benchmark over a local mesh."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--keep-frac", type=float, default=1.0,
                    help="fraction of nonlinearities kept (random "
                         "thresholding — synthetic; prefer --masks-from)")
    ap.add_argument("--masks-from", default=None, metavar="RUN_DIR",
                    help="serve checkpointed masks from a launch.sweep run "
                         "dir (fingerprint-validated) instead of random "
                         "thresholding")
    ap.add_argument("--mask-set", default=None, metavar="NAME",
                    help="which set from --masks-from to serve (e.g. b1024; "
                         "default: the first/highest budget)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    d, m = (int(x) for x in args.mesh.split(","))
    mesh = make_host_mesh(d, m)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.masks_from:
        shapes = {k: s.shape for k, s in model.mask_sites().items()}
        try:
            store = serve_lib.MaskSetStore.from_run_dir(
                args.masks_from, shapes,
                names=[args.mask_set] if args.mask_set else None)
        except serve_lib.MaskSetError as e:
            raise SystemExit(f"error: {e}")
        name = args.mask_set or store.names[0]
        try:
            store.verify(name)       # refuse to serve a corrupted set
        except serve_lib.MaskSetError as e:
            raise SystemExit(f"error: {e}")
        info = store.info(name)
        print(f"serving mask set {name!r} from {info.source} "
              f"(relu_cost={info.relu_cost}, "
              f"fingerprint={info.fingerprint[:12]})")
        masks0 = store.host(name)
    else:
        masks0 = linearize.init_masks(model.mask_sites())
        if args.keep_frac < 1.0:
            rng = np.random.default_rng(0)
            masks0 = M.threshold(
                {k: rng.random(v.shape).astype(np.float32)
                 for k, v in masks0.items()},
                int(M.count(masks0) * args.keep_frac))
    mdev = M.as_device(masks0)

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    scfg = serve_lib.ServeCfg(dp_axes=("data",), max_len=max_len, batch=B)
    with mesh:
        prefill = jax.jit(serve_lib.make_prefill(model))
        decode = serve_lib.jit_decode_step(model, mesh, scfg) \
            if mesh.size > 1 else jax.jit(serve_lib.make_decode_step(model))
        rng = np.random.default_rng(1)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P),
                                           dtype=np.int32))
        cache = model.init_cache(B, max_len)
        t0 = time.perf_counter()
        last, cache = prefill(params, mdev, prompts, cache)
        tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        toks = [tok]
        for t in range(G - 1):
            tok, cache = decode(params, mdev, tok, cache,
                                jnp.asarray(P + t, jnp.int32))
            toks.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    gen = np.asarray(jnp.concatenate(toks, 1))
    print("generated:", gen[:, :12])
    print(f"{B} seqs x ({P} prefill + {G} decode) in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s decode-equivalent)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
