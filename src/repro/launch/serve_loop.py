"""Continuous-batching serve loop with per-request ReLU-budget SLOs.

The deployment story of the paper: ReLU count ≈ Private-Inference latency,
so a served request's *price* is set by the mask set it runs under.  This
loop serves several ReLU budgets from ONE resident parameter set
(``training.serve.MaskSetStore``), routing each request to a budget by its
SLO class, with:

- **deadline-aware admission** — per-class bounded queues ordered
  earliest-deadline-first; each candidate admission is priced against a
  per-request latency estimate (PI protocol cost seeding measured
  prefill/decode EWMAs) and resolved into an explicit decision:
  **admit**, **degrade** (route to the next-cheaper mask set on a declared
  :class:`DegradationLadder` — the sweep's checkpointed budget/accuracy
  ladder makes "serve a cheaper mask set" strictly better than rejecting),
  or **shed** (reject with a reason *before* wasting prefill).  Expired
  requests are cancelled un-billed;
- **prefill/decode disaggregation** — prefill runs as its own B=1 jitted
  call, then the fresh cache is scattered into one slot of the resident
  per-class decode cache (``training.serve.make_insert_slot``), so long
  prompts never stall other streams' decode steps;
- **continuous batching** — each class's lane decodes all live slots every
  tick with a per-slot ``(B,)`` ``cache_len`` vector (ragged decode:
  every slot sits at its own sequence position); finished slots free up
  and the queue refills them mid-stream;
- **fault tolerance** — a seedable :class:`repro.launch.faults.FaultPlan`
  injects failures at named crosspoints (failed/slow prefill, decode
  stall, corrupted mask-set fingerprint); per-crosspoint
  :class:`repro.launch.faults.RetryPolicy` bounds mean every injected
  fault is retried to success, degraded, or shed — never a hung loop, and
  never an unbilled completion;
- **request-level PI billing** — on completion each request is billed via
  :func:`repro.core.pi_cost.bill_request` applied to the mask set it was
  *actually* served under (fingerprint + any ``degraded_from`` provenance
  stamped into the bill for audit).

Mask-set hot-swap never re-jits: mask trees are jit *arguments* with
set-independent shapes, so one compiled decode step serves every budget.

Determinism: pass ``clock=faults.VirtualClock()`` and every timestamp is
derived from the PI cost model instead of the host — the same seed and
fault plan replay identical admit/degrade/shed decisions bit-for-bit
(``decision_log`` records them; CI's ``chaos-smoke`` asserts equality
across runs).

Quickstart (synthetic budgets)::

    PYTHONPATH=src python -m repro.launch.serve_loop --arch stablelm_1p6b \
        --reduced --requests 8 --budget-fracs 1.0,0.5

See ``docs/serving.md`` for the architecture and the overload/failure
semantics (admit/degrade/shed state diagram).
"""
from __future__ import annotations

import argparse
import dataclasses
import heapq
import json
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import masks as M, pi_cost
from repro.launch import faults as faults_lib
from repro.models.lm import LM
from repro.training import serve as serve_lib

#: Block kinds whose caches carry recurrent state (exact-length prefill
#: required — see ServeLoop's ``prompt_bucket`` docstring).
_RECURRENT_KINDS = frozenset({"mamba", "rwkv"})


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service tier: which mask set (ReLU budget) serves it.

    ``max_new_tokens`` is the tier's generation cap — a premium tier can
    pair a high ReLU budget with longer generations, an economy tier the
    reverse.  ``deadline_ms`` is the tier's end-to-end latency budget per
    request (arrival → last token); ``None`` means best-effort (never
    degraded or shed on time grounds).  ``priority`` breaks ties between
    equal deadlines during admission (higher admits first).
    """

    name: str
    mask_set: str
    max_new_tokens: int = 16
    deadline_ms: Optional[float] = None
    priority: int = 0


@dataclasses.dataclass(frozen=True)
class DegradationLadder:
    """Declared order of mask sets to fall back through under pressure.

    ``rungs`` are mask-set names at strictly descending billable ReLU
    cost — the sweep's stage outputs ARE this ladder (each checkpointed
    budget has a known PI cost and a known accuracy).  A request that
    cannot meet its deadline (or whose lane faulted) is re-routed to the
    first cheaper rung that fits instead of being rejected.
    """

    rungs: Tuple[str, ...]

    def validate(self, store: serve_lib.MaskSetStore) -> None:
        """Every rung stored, costs strictly descending — else ValueError."""
        missing = [r for r in self.rungs if r not in store.names]
        if missing:
            raise ValueError(
                f"ladder rung(s) {missing} not in the mask-set store "
                f"({store.names})")
        costs = [store.info(r).relu_cost for r in self.rungs]
        if any(a <= b for a, b in zip(costs, costs[1:])):
            raise ValueError(
                f"ladder rungs must have strictly descending ReLU cost, "
                f"got {dict(zip(self.rungs, costs))}")

    def below(self, store: serve_lib.MaskSetStore,
              mask_set: str) -> Tuple[str, ...]:
        """Rungs strictly cheaper than ``mask_set``, costliest first."""
        cost = store.info(mask_set).relu_cost
        return tuple(r for r in self.rungs
                     if store.info(r).relu_cost < cost)

    @classmethod
    def from_store(cls, store: serve_lib.MaskSetStore) -> "DegradationLadder":
        """All stored sets ordered by descending billable ReLU cost."""
        rungs = sorted(store.names,
                       key=lambda n: -store.info(n).relu_cost)
        return cls(tuple(rungs))


@dataclasses.dataclass
class Request:
    """One inference request and its measured + billed lifecycle.

    ``state`` walks queued → live → served | degraded, or terminates
    early as shed (with ``shed_reason``) or cancelled.  ``degraded_from``
    records the mask set the SLO class originally routed to when the
    admission controller moved the request down the ladder.
    """

    rid: int
    slo: str
    prompt: np.ndarray
    max_new: int = 1
    deadline_s: Optional[float] = None
    priority: int = 0
    t_arrival: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    mask_set: str = ""
    mask_fingerprint: str = ""
    degraded_from: Optional[str] = None
    state: str = "queued"
    shed_reason: str = ""
    bill: Optional[dict] = None
    cancelled: bool = False

    @property
    def queue_s(self) -> float:
        """Seconds spent waiting in the admission queue."""
        return self.t_admit - self.t_arrival

    @property
    def prefill_s(self) -> float:
        """Seconds from admission to first token (prefill + slot insert)."""
        return self.t_first - self.t_admit

    @property
    def decode_s(self) -> float:
        """Seconds spent in the decode stream after the first token."""
        return self.t_done - self.t_first

    @property
    def total_s(self) -> float:
        """End-to-end seconds from arrival to completion."""
        return self.t_done - self.t_arrival

    @property
    def deadline_hit(self) -> bool:
        """Completed, and within the deadline (trivially true without one)."""
        if self.state not in ("served", "degraded"):
            return False
        return self.deadline_s is None or self.t_done <= self.deadline_s

    def _key(self):
        """EDF heap key: earliest deadline, then priority, then arrival."""
        d = math.inf if self.deadline_s is None else self.deadline_s
        return (d, -self.priority, self.rid)


class _Lane:
    """One SLO class's decode lane: resident cache + slot bookkeeping."""

    def __init__(self, slo: SLOClass, cache, slots: int):
        self.slo = slo
        self.cache = cache
        self.heap: list = []           # (edf_key, Request)
        self.live = np.zeros((slots,), bool)
        self.cache_len = np.zeros((slots,), np.int32)
        self.tok = np.zeros((slots,), np.int32)
        self.reqs: List[Optional[Request]] = [None] * slots

    def push(self, req: Request) -> None:
        heapq.heappush(self.heap, (req._key(), req))

    def pop(self) -> Request:
        return heapq.heappop(self.heap)[1]


class _LatencyModel:
    """Per-mask-set EWMAs of per-token prefill/decode seconds.

    Seeded from the PI protocol cost model (the paper's ReLU ≈ latency
    claim gives every budget a price before any request has run), then
    refined with measured latencies as requests complete — the admission
    controller prices candidate admissions against these estimates.
    """

    def __init__(self, store: serve_lib.MaskSetStore,
                 proto: pi_cost.PIProtocol, alpha: float = 0.2):
        self.alpha = float(alpha)
        self.prefill_tok_s: Dict[str, float] = {}
        self.decode_tok_s: Dict[str, float] = {}
        for name in store.names:
            per = store.pi_cost_per_token(name, proto).online_latency_s
            self.prefill_tok_s[name] = per
            self.decode_tok_s[name] = per

    def _ewma(self, table: Dict[str, float], name: str, value: float):
        table[name] += self.alpha * (float(value) - table[name])

    def observe_prefill(self, name: str, seconds: float, tokens: int):
        """Fold one measured prefill (``tokens`` prompt positions)."""
        if tokens > 0 and seconds > 0:
            self._ewma(self.prefill_tok_s, name, seconds / tokens)

    def observe_decode(self, name: str, seconds: float, tokens: int):
        """Fold one request's measured decode tail (``tokens`` generated)."""
        if tokens > 0 and seconds > 0:
            self._ewma(self.decode_tok_s, name, seconds / tokens)

    def estimate_s(self, name: str, prompt_tokens: int,
                   gen_tokens: int) -> float:
        """Remaining-latency estimate for one request under set ``name``."""
        return self.prefill_tok_s[name] * prompt_tokens \
            + self.decode_tok_s[name] * gen_tokens


class ServeLoop:
    """Continuous-batching scheduler over one model + one MaskSetStore.

    ``slots`` decode slots per SLO class; ``max_len`` bounds
    prompt + generation per slot.  ``prompt_bucket`` pads prompts up to a
    multiple of the bucket before the B=1 prefill so a handful of compiled
    prefill shapes serve every prompt length (exact for attention caches:
    causality keeps pad positions out of real tokens' outputs, and the
    pad rows' K/V are hidden from decode by per-slot validity masking;
    recurrent-state models — any ``mamba``/``rwkv`` block — carry their
    state *through* pad positions, so bucketing corrupts it: construction
    fails loudly unless ``prompt_bucket=None`` — exact-length prefill, one
    compile per distinct length).  ``mesh``: optional — lane decode steps
    run under ``training.serve.jit_decode_step``'s production cache
    shardings instead of single-device jit.

    Overload/fault knobs (all default to the fair-weather PR-8 behavior):

    - ``ladder``: a :class:`DegradationLadder`; requests that cannot meet
      their deadline (or hit unrecoverable faults) are re-routed to the
      first cheaper rung served by some lane, instead of shed.
    - ``queue_cap``: bound per-class admission queues; arrivals beyond it
      are shed immediately with reason ``queue_full`` (backpressure beats
      unbounded latency).
    - ``clock``: a :class:`repro.launch.faults.VirtualClock` makes every
      timestamp model-derived and every decision reproducible; ``None``
      uses the host clock.
    - ``fault_plan`` / ``retries``: a
      :class:`repro.launch.faults.FaultPlan` injected at the named
      crosspoints, with per-crosspoint
      :class:`repro.launch.faults.RetryPolicy` bounds.
    - ``proto``: the :class:`repro.core.pi_cost.PIProtocol` pricing
      estimates and (under a virtual clock) elapsing time.
    """

    def __init__(self, model: LM, params, store: serve_lib.MaskSetStore,
                 classes: Sequence[SLOClass], *, slots: int = 4,
                 max_len: int = 64, prompt_bucket: Optional[int] = 16,
                 mesh=None, ladder: Optional[DegradationLadder] = None,
                 queue_cap: Optional[int] = None,
                 clock: Optional[faults_lib.VirtualClock] = None,
                 fault_plan: Optional[faults_lib.FaultPlan] = None,
                 retries: Optional[Dict[str, faults_lib.RetryPolicy]] = None,
                 proto: pi_cost.PIProtocol = pi_cost.PIProtocol()):
        """Build lanes (one resident decode cache per SLO class) and jits."""
        if not classes:
            raise ValueError("ServeLoop needs at least one SLO class")
        for c in classes:
            if c.mask_set not in store.names:
                raise serve_lib.MaskSetError(
                    f"SLO class {c.name!r} routes to mask set "
                    f"{c.mask_set!r}, not in the store ({store.names})")
        kinds = {b.kind for b in (tuple(model.cfg.head_blocks)
                                  + tuple(model.cfg.pattern)
                                  + tuple(model.cfg.tail))}
        recurrent = sorted(kinds & _RECURRENT_KINDS)
        if recurrent and prompt_bucket is not None:
            raise ValueError(
                f"model {model.cfg.name!r} has recurrent-state block(s) "
                f"{recurrent}: their caches carry state through padded "
                f"prompt positions, so bucketed prefill "
                f"(prompt_bucket={prompt_bucket}) would corrupt every "
                "stream in the lane.  Construct the ServeLoop with "
                "prompt_bucket=None (exact-length prefill, one compile "
                "per distinct prompt length).")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {queue_cap}")
        if ladder is not None:
            ladder.validate(store)
        self.model, self.params, self.store = model, params, store
        self.slots, self.max_len = slots, max_len
        self.prompt_bucket = prompt_bucket
        self.mesh = mesh
        self.ladder = ladder
        self.queue_cap = queue_cap
        self.clock = clock
        self.proto = proto
        self.fault_plan = fault_plan
        self.retries = dict(faults_lib.DEFAULT_RETRIES)
        if retries:
            self.retries.update(retries)
        self._prefill = jax.jit(_make_last_logit_prefill(model))
        self._insert = jax.jit(serve_lib.make_insert_slot(model))
        if mesh is not None and mesh.size > 1:
            scfg = serve_lib.ServeCfg(dp_axes=("data",), max_len=max_len,
                                      batch=slots)
            self._decode = serve_lib.jit_decode_step(model, mesh, scfg)
        else:
            self._decode = jax.jit(serve_lib.make_decode_step(model))
        self.lanes: Dict[str, _Lane] = {
            c.name: _Lane(c, model.init_cache(slots, max_len), slots)
            for c in classes}
        # degrade routing: the first lane serving each mask set
        self._lane_for_set: Dict[str, str] = {}
        for c in classes:
            self._lane_for_set.setdefault(c.mask_set, c.name)
        self.latency = _LatencyModel(store, proto)
        # virtual-time cost basis: fixed per set, so clocks replay exactly
        self._virtual_tok_s = {
            name: store.pi_cost_per_token(name, proto).online_latency_s
            for name in store.names}
        self.completed: List[Request] = []
        self.shed: List[Request] = []
        self.decision_log: List[dict] = []
        self.fault_stats: Dict[str, Dict[str, int]] = {}
        self._next_rid = 0
        self._accepting = True

    # ------------------------------------------------------------- clock

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.perf_counter()

    def _elapse(self, seconds: float) -> None:
        """Advance virtual time (no-op on the host clock — it advances
        itself)."""
        if self.clock is not None and seconds > 0:
            self.clock.advance(seconds)

    # ------------------------------------------------------------ faults

    def _draw(self, crosspoint: str) -> Optional[faults_lib.FaultSpec]:
        if self.fault_plan is None:
            return None
        return self.fault_plan.draw(crosspoint)

    def _count(self, crosspoint: str, outcome: str) -> None:
        per = self.fault_stats.setdefault(
            crosspoint, {"injected": 0, "retried": 0, "gave_up": 0})
        per[outcome] += 1

    def _policy(self, crosspoint: str) -> faults_lib.RetryPolicy:
        return self.retries.get(crosspoint, faults_lib.RetryPolicy())

    # ------------------------------------------------------------ intake

    def submit(self, prompt: np.ndarray, slo: str) -> Request:
        """Enqueue a prompt under an SLO class; returns its Request.

        With a bounded queue (``queue_cap``) a full class queue sheds the
        arrival immediately (``state == "shed"``, reason ``queue_full``)
        instead of queueing unbounded latency — check ``Request.state``.
        """
        if not self._accepting:
            raise RuntimeError("serve loop is shut down")
        if slo not in self.lanes:
            raise KeyError(f"unknown SLO class {slo!r} "
                           f"(have: {sorted(self.lanes)})")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        lane = self.lanes[slo]
        cap = self.max_len - lane.slo.max_new_tokens
        if not 0 < len(prompt) <= cap:
            raise ValueError(
                f"prompt length {len(prompt)} outside (0, {cap}] "
                f"(max_len {self.max_len} minus the class's "
                f"{lane.slo.max_new_tokens} generation budget)")
        now = self._now()
        deadline = None if lane.slo.deadline_ms is None \
            else now + lane.slo.deadline_ms / 1e3
        req = Request(rid=self._next_rid, slo=slo, prompt=prompt,
                      max_new=lane.slo.max_new_tokens,
                      deadline_s=deadline, priority=lane.slo.priority,
                      t_arrival=now)
        self._next_rid += 1
        if self.queue_cap is not None and len(lane.heap) >= self.queue_cap:
            self._shed(req, "queue_full")
            return req
        lane.push(req)
        return req

    # ------------------------------------------------------------ ticking

    def step(self) -> int:
        """One scheduler tick: admit into free slots, decode every lane.

        Returns the number of requests still in flight (queued + live).
        """
        ctx = self.mesh if self.mesh is not None else _NullCtx()
        with ctx:
            for lane in self.lanes.values():
                self._admit(lane)
            for lane in self.lanes.values():
                self._decode_lane(lane)
        return self.pending()

    def pending(self) -> int:
        """Requests not yet terminal: queued plus occupying a slot."""
        return sum(len(ln.heap) + int(ln.live.sum())
                   for ln in self.lanes.values())

    def run_until_drained(self, max_steps: int = 100000) -> None:
        """Tick until every queue and slot is empty (or ``max_steps``)."""
        for _ in range(max_steps):
            if self.step() == 0:
                return
        raise RuntimeError(
            f"serve loop failed to drain within {max_steps} steps "
            f"({self.pending()} requests still pending)")

    def shutdown(self, drain: bool = True) -> List[Request]:
        """Stop intake; drain in-flight work (or cancel it) and return
        every completed request.

        ``drain=True`` runs the loop until queues and slots are empty —
        every admitted request reaches a terminal state (served, degraded,
        shed, or expired) and only served work is billed.  ``drain=False``
        cancels queued and in-flight requests (marked ``cancelled``, never
        billed) and releases every lane slot, so a fresh loop on the same
        store starts from clean state.
        """
        self._accepting = False
        if drain:
            self.run_until_drained()
        else:
            for lane in self.lanes.values():
                queued = [r for _, r in lane.heap]
                for req in queued + [r for r in lane.reqs if r]:
                    req.cancelled = True
                    req.state = "cancelled"
                lane.heap.clear()
                lane.live[:] = False
                lane.cache_len[:] = 0
                lane.tok[:] = 0
                lane.reqs = [None] * self.slots
        return self.completed

    # ------------------------------------------------------------ decisions

    def _decide(self, req: Request, decision: str, **detail) -> None:
        entry = {"rid": req.rid, "slo": req.slo, "decision": decision}
        entry.update(detail)
        self.decision_log.append(entry)

    def _shed(self, req: Request, reason: str) -> None:
        """Terminal rejection: recorded with a reason, never billed."""
        req.state = "shed"
        req.shed_reason = reason
        self.shed.append(req)
        self._decide(req, "shed", reason=reason)

    def _try_degrade(self, req: Request, lane: _Lane, now: float,
                     reason: str) -> bool:
        """Route ``req`` one or more rungs down the ladder.

        Picks the first strictly-cheaper rung that (a) some lane serves
        and (b) whose latency estimate fits the request's remaining
        deadline budget (any rung, when the request has no deadline).
        Returns False when no rung qualifies — caller sheds.
        """
        if self.ladder is None:
            return False
        current = lane.slo.mask_set
        for rung in self.ladder.below(self.store, current):
            target_name = self._lane_for_set.get(rung)
            if target_name is None:
                continue
            est = self.latency.estimate_s(rung, len(req.prompt), req.max_new)
            if req.deadline_s is not None and now + est > req.deadline_s:
                continue
            if req.degraded_from is None:
                req.degraded_from = current
            self.lanes[target_name].push(req)
            self._decide(req, "degrade", reason=reason,
                         from_set=current, to_set=rung)
            return True
        return False

    # ------------------------------------------------------------ internals

    def _bucket(self, n: int) -> int:
        b = self.prompt_bucket
        return n if not b else min(-(-n // b) * b, self.max_len - 1)

    def _verify_masks(self, lane: _Lane) -> bool:
        """Fingerprint-verify the lane's mask set (fault crosspoint
        ``fingerprint``), retrying per policy; False = unrecoverable."""
        pol = self._policy("fingerprint")
        name = lane.slo.mask_set
        for attempt in range(1, pol.max_attempts + 1):
            fault = self._draw("fingerprint")
            observed = None
            if fault is not None and fault.kind == "corrupt":
                self._count("fingerprint", "injected")
                observed = faults_lib.corrupt_fingerprint(
                    self.store.info(name).fingerprint)
            try:
                self.store.verify(name, observed=observed)
                return True
            except serve_lib.MaskSetError:
                if attempt < pol.max_attempts:
                    self._count("fingerprint", "retried")
                    self._elapse(pol.backoff_s * attempt)
        self._count("fingerprint", "gave_up")
        return False

    def _admit(self, lane: _Lane) -> None:
        """EDF admission for one lane: pop by earliest deadline and decide
        admit / degrade / shed per candidate until slots or queue run out."""
        free = list(np.flatnonzero(~lane.live))
        while lane.heap and free:
            req = lane.pop()
            now = self._now()
            # expired while queued: cancel un-billed before any prefill
            if req.deadline_s is not None and now >= req.deadline_s:
                req.cancelled = True
                self._shed(req, "deadline_expired")
                continue
            est = self.latency.estimate_s(lane.slo.mask_set,
                                          len(req.prompt), req.max_new)
            if req.deadline_s is not None and now + est > req.deadline_s:
                if not self._try_degrade(req, lane, now,
                                         reason="deadline_unmeetable"):
                    self._shed(req, "deadline_unmeetable")
                continue
            if not self._verify_masks(lane):
                if not self._try_degrade(req, lane, self._now(),
                                         reason="mask_corrupt"):
                    self._shed(req, "mask_corrupt")
                continue
            slot = int(free[0])
            if self._prefill_into_slot(lane, slot, req):
                free.pop(0)
                self._decide(req, "admit", set=lane.slo.mask_set,
                             slot=slot)
            else:
                if not self._try_degrade(req, lane, self._now(),
                                         reason="prefill_failed"):
                    self._shed(req, "prefill_failed")

    def _prefill_into_slot(self, lane: _Lane, slot: int,
                           req: Request) -> bool:
        """Run the B=1 prefill and scatter its cache into ``slot``.

        The ``prefill`` fault crosspoint fires per attempt: ``fail``
        faults (and ``slow`` delays beyond the policy timeout) consume an
        attempt with backoff; exhausting the policy returns False and the
        caller degrades or sheds — an injected fault never half-admits.
        """
        pol = self._policy("prefill")
        for attempt in range(1, pol.max_attempts + 1):
            fault = self._draw("prefill")
            if fault is not None:
                self._count("prefill", "injected")
                if fault.kind == "slow" and fault.delay_s <= pol.timeout_s:
                    self._elapse(fault.delay_s)     # absorbed as latency
                else:                               # fail (or timed out)
                    if attempt < pol.max_attempts:
                        self._count("prefill", "retried")
                        self._elapse(pol.backoff_s * attempt)
                        continue
                    self._count("prefill", "gave_up")
                    return False
            req.t_admit = self._now()
            L = len(req.prompt)
            toks = np.zeros((1, self._bucket(L)), np.int32)
            toks[0, :L] = req.prompt
            masks = self.store.select(lane.slo.mask_set)
            small = self.model.init_cache(1, self.max_len)
            nxt, small = self._prefill(self.params, masks,
                                       jnp.asarray(toks), small,
                                       jnp.asarray(L - 1, jnp.int32))
            lane.cache = self._insert(lane.cache, small,
                                      jnp.asarray(slot, jnp.int32))
            first = int(jax.block_until_ready(nxt)[0, 0])
            self._elapse(self._virtual_tok_s[lane.slo.mask_set] * L)
            req.t_first = self._now()
            self.latency.observe_prefill(lane.slo.mask_set,
                                         req.prefill_s, L)
            req.tokens.append(first)
            info = self.store.info(lane.slo.mask_set)
            req.mask_set, req.mask_fingerprint = info.name, info.fingerprint
            req.state = "live"
            lane.live[slot] = True
            lane.cache_len[slot] = L
            lane.tok[slot] = first
            lane.reqs[slot] = req
            if req.max_new <= 1:
                self._finish(lane, slot)
            return True
        return False

    def _decode_lane(self, lane: _Lane) -> None:
        if not lane.live.any():
            return
        fault = self._draw("decode")
        if fault is not None and fault.kind == "stall":
            # a stalled tick is retried in place: the injected delay lands
            # on every live stream's clock, then the decode step proceeds
            self._count("decode", "injected")
            self._count("decode", "retried")
            self._elapse(fault.delay_s)
        masks = self.store.select(lane.slo.mask_set)
        tok = jnp.asarray(lane.tok[:, None])
        cl = jnp.asarray(lane.cache_len)
        nxt, lane.cache = self._decode(self.params, masks, tok,
                                       lane.cache, cl)
        nxt = np.asarray(jax.block_until_ready(nxt)).reshape(-1)
        self._elapse(self._virtual_tok_s[lane.slo.mask_set])
        for slot in np.flatnonzero(lane.live):
            req = lane.reqs[slot]
            req.tokens.append(int(nxt[slot]))
            lane.tok[slot] = nxt[slot]
            lane.cache_len[slot] += 1
            done = len(req.tokens) >= req.max_new
            if done or lane.cache_len[slot] + 1 >= self.max_len:
                self._finish(lane, slot)

    def _finish(self, lane: _Lane, slot: int) -> None:
        req = lane.reqs[slot]
        req.t_done = self._now()
        gen = len(req.tokens) - 1
        if gen > 0:
            self.latency.observe_decode(lane.slo.mask_set,
                                        req.decode_s, gen)
        info = self.store.info(lane.slo.mask_set)
        req.bill = pi_cost.bill_request(
            info.relu_cost, len(self.store.site_shapes),
            tokens=len(req.prompt) + len(req.tokens), proto=self.proto,
            mask_set=info.name, fingerprint=info.fingerprint,
            degraded_from=req.degraded_from)
        req.state = "degraded" if req.degraded_from else "served"
        lane.live[slot] = False
        lane.reqs[slot] = None
        self.completed.append(req)

    # ------------------------------------------------------------ reporting

    def stats(self) -> dict:
        """Per-SLO-class latency/throughput/billing/robustness aggregates.

        ``decode_tok_s`` is per-slot decode rate (generated tokens over
        in-slot decode seconds, summed per class); percentiles are
        milliseconds over completed requests.  Robustness keys:
        per class ``served``/``degraded``/``shed`` counts,
        ``shed_reasons``, and ``deadline_hit_rate`` (completed within
        deadline over all terminal requests of the class — shed requests
        count as misses); totals add ``goodput_tok_s`` (generated tokens
        of deadline-hitting requests per second of serving span),
        ``degrade_rate``/``shed_rate``, per-crosspoint ``retries``, and
        ``decisions_sha256`` (hash of the ordered admit/degrade/shed log —
        equal hashes == bit-identical scheduling).
        """
        out: dict = {"classes": {}}
        for name, lane in self.lanes.items():
            reqs = [r for r in self.completed if r.slo == name]
            shed = [r for r in self.shed if r.slo == name]
            info = self.store.info(lane.slo.mask_set)
            per_tok = self.store.pi_cost_per_token(lane.slo.mask_set,
                                                   self.proto)
            cls = {"mask_set": lane.slo.mask_set,
                   "relu_cost": info.relu_cost,
                   "mask_fingerprint": info.fingerprint,
                   "pi_online_s_per_tok": per_tok.online_latency_s,
                   "deadline_ms": lane.slo.deadline_ms,
                   "priority": lane.slo.priority,
                   "requests": len(reqs),
                   "served": sum(r.state == "served" for r in reqs),
                   "degraded": sum(r.state == "degraded" for r in reqs),
                   "shed": len(shed),
                   "shed_reasons": _histogram(r.shed_reason for r in shed)}
            terminal = len(reqs) + len(shed)
            if terminal:
                cls["deadline_hit_rate"] = \
                    sum(r.deadline_hit for r in reqs) / terminal
            if reqs:
                gen = sum(len(r.tokens) - 1 for r in reqs)
                dec = sum(r.decode_s for r in reqs)
                cls["decode_tok_s"] = gen / dec if dec > 0 else 0.0
                for key, get in (("queue", lambda r: r.queue_s),
                                 ("prefill", lambda r: r.prefill_s),
                                 ("decode", lambda r: r.decode_s),
                                 ("total", lambda r: r.total_s)):
                    vals = np.array([get(r) for r in reqs]) * 1e3
                    cls[f"{key}_ms_p50"] = float(np.percentile(vals, 50))
                    cls[f"{key}_ms_p95"] = float(np.percentile(vals, 95))
                cls["relus_billed"] = sum(r.bill["relus_billed"]
                                          for r in reqs)
                cls["pi_online_s"] = sum(r.bill["pi_online_s"]
                                         for r in reqs)
            out["classes"][name] = cls
        out["completed"] = len(self.completed)
        out["shed"] = len(self.shed)
        out["terminal"] = len(self.completed) + len(self.shed)
        out["pending"] = self.pending()
        out["degrade_rate"] = _rate(
            sum(r.state == "degraded" for r in self.completed),
            out["terminal"])
        out["shed_rate"] = _rate(len(self.shed), out["terminal"])
        hits = [r for r in self.completed if r.deadline_hit]
        out["deadline_hit_rate"] = _rate(len(hits), out["terminal"])
        span = self._serving_span()
        good = sum(len(r.tokens) - 1 for r in hits)
        out["goodput_tok_s"] = good / span if span > 0 else 0.0
        out["retries"] = {c: dict(v)
                          for c, v in sorted(self.fault_stats.items())}
        out["faults_injected"] = (self.fault_plan.stats()
                                  if self.fault_plan else {})
        out["decisions_sha256"] = decisions_fingerprint(self.decision_log)
        return out

    def _serving_span(self) -> float:
        """Seconds from the first arrival to the last completion."""
        terminal = self.completed + self.shed
        if not self.completed or not terminal:
            return 0.0
        t0 = min(r.t_arrival for r in terminal)
        t1 = max(r.t_done for r in self.completed)
        return t1 - t0


def _histogram(values) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for v in values:
        out[v] = out.get(v, 0) + 1
    return dict(sorted(out.items()))


def _rate(n: int, total: int) -> float:
    return n / total if total else 0.0


def decisions_fingerprint(decision_log: List[dict]) -> str:
    """sha256 over the ordered decision log — the reproducibility witness
    (equal fingerprints == bit-identical admit/degrade/shed scheduling)."""
    import hashlib
    blob = json.dumps(decision_log, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


class _NullCtx:
    """No-op context manager (single-device loops have no mesh scope)."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def _make_last_logit_prefill(model: LM):
    """B=1 prefill: argmax logits at the prompt's true last position.

    Prompts arrive right-padded to a bucket length; ``last_idx`` (traced)
    picks the real final position so one compiled shape serves every
    prompt length in the bucket.
    """
    def prefill(params, masks, tokens, cache, last_idx):
        logits, cache = model.forward(params, masks, tokens, cache=cache,
                                      cache_len=0)
        last = jax.lax.dynamic_index_in_dim(logits, last_idx, axis=1,
                                            keepdims=False)
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache
    return prefill


def threshold_mask_sets(model: LM, fracs: Sequence[float],
                        seed: int = 0) -> serve_lib.MaskSetStore:
    """Synthetic named budgets: one random-priority threshold per keep-frac.

    Serving smoke tests and the load generator use this when no sweep run
    directory is available; real deployments load checkpointed masks via
    :meth:`repro.training.serve.MaskSetStore.from_run_dir`.
    """
    shapes = {k: s.shape for k, s in model.mask_sites().items()}
    full = M.full_masks(shapes)
    total = M.count(full)
    rng = np.random.default_rng(seed)
    soft = {k: rng.random(v.shape).astype(np.float32)
            for k, v in full.items()}
    sets = {f"kf{int(round(f * 100)):03d}": M.threshold(soft,
                                                        int(total * f))
            for f in fracs}
    return serve_lib.MaskSetStore(shapes, sets)


def default_classes(store: serve_lib.MaskSetStore,
                    max_new_tokens: int = 8,
                    deadline_ms: Optional[Dict[str, float]] = None
                    ) -> List[SLOClass]:
    """One SLO class per stored budget, named after its mask set.

    ``deadline_ms`` optionally assigns per-set deadlines (name → ms).
    """
    deadline_ms = deadline_ms or {}
    return [SLOClass(name=n, mask_set=n, max_new_tokens=max_new_tokens,
                     deadline_ms=deadline_ms.get(n))
            for n in store.names]


def main(argv=None):
    """CLI demo: serve random prompts at ≥2 synthetic budgets and print
    the per-class stats JSON."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--budget-fracs", default="1.0,0.5",
                    help="comma list of keep-fracs -> synthetic mask sets")
    ap.add_argument("--masks-from", default=None, metavar="RUN_DIR",
                    help="load checkpointed mask sets from a launch.sweep "
                         "run dir instead of synthetic thresholds")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request end-to-end deadline applied to every "
                         "class (default: best-effort, no deadlines)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shapes = {k: s.shape for k, s in model.mask_sites().items()}
    if args.masks_from:
        store = serve_lib.MaskSetStore.from_run_dir(args.masks_from, shapes)
    else:
        fracs = [float(x) for x in args.budget_fracs.split(",")]
        store = threshold_mask_sets(model, fracs, seed=args.seed)
    deadlines = ({n: args.deadline_ms for n in store.names}
                 if args.deadline_ms else None)
    loop = ServeLoop(model, params, store,
                     default_classes(store, args.max_new, deadlines),
                     slots=args.slots, max_len=args.max_len,
                     ladder=DegradationLadder.from_store(store))
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        slo = store.names[i % len(store.names)]
        plen = int(rng.integers(4, args.max_len - args.max_new))
        loop.submit(rng.integers(0, cfg.vocab, plen), slo)
    loop.shutdown(drain=True)
    print(json.dumps(loop.stats(), indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
