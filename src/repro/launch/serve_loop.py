"""Continuous-batching serve loop with per-request ReLU-budget SLOs.

The deployment story of the paper: ReLU count ≈ Private-Inference latency,
so a served request's *price* is set by the mask set it runs under.  This
loop serves several ReLU budgets from ONE resident parameter set
(``training.serve.MaskSetStore``), routing each request to a budget by its
SLO class, with:

- **admission queues** — per-class FIFO; requests wait for a free decode
  slot (queue time is measured and reported);
- **prefill/decode disaggregation** — prefill runs as its own B=1 jitted
  call, then the fresh cache is scattered into one slot of the resident
  per-class decode cache (``training.serve.make_insert_slot``), so long
  prompts never stall other streams' decode steps;
- **continuous batching** — each class's lane decodes all live slots every
  tick with a per-slot ``(B,)`` ``cache_len`` vector (ragged decode:
  every slot sits at its own sequence position); finished slots free up
  and the queue refills them mid-stream;
- **request-level PI billing** — on completion each request is billed via
  :func:`repro.core.pi_cost.bill_request` applied to the mask set it was
  actually served under (fingerprint recorded for audit).

Mask-set hot-swap never re-jits: mask trees are jit *arguments* with
set-independent shapes, so one compiled decode step serves every budget.

Quickstart (synthetic budgets)::

    PYTHONPATH=src python -m repro.launch.serve_loop --arch stablelm_1p6b \
        --reduced --requests 8 --budget-fracs 1.0,0.5

See ``docs/serving.md`` for the architecture.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import masks as M, pi_cost
from repro.models.lm import LM
from repro.training import serve as serve_lib


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service tier: which mask set (ReLU budget) serves it.

    ``max_new_tokens`` is the tier's generation cap — a premium tier can
    pair a high ReLU budget with longer generations, an economy tier the
    reverse.
    """

    name: str
    mask_set: str
    max_new_tokens: int = 16


@dataclasses.dataclass
class Request:
    """One inference request and its measured + billed lifecycle."""

    rid: int
    slo: str
    prompt: np.ndarray
    t_arrival: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    mask_set: str = ""
    mask_fingerprint: str = ""
    bill: Optional[dict] = None
    cancelled: bool = False

    @property
    def queue_s(self) -> float:
        """Seconds spent waiting in the admission queue."""
        return self.t_admit - self.t_arrival

    @property
    def prefill_s(self) -> float:
        """Seconds from admission to first token (prefill + slot insert)."""
        return self.t_first - self.t_admit

    @property
    def decode_s(self) -> float:
        """Seconds spent in the decode stream after the first token."""
        return self.t_done - self.t_first

    @property
    def total_s(self) -> float:
        """End-to-end seconds from arrival to completion."""
        return self.t_done - self.t_arrival


class _Lane:
    """One SLO class's decode lane: resident cache + slot bookkeeping."""

    def __init__(self, slo: SLOClass, cache, slots: int):
        self.slo = slo
        self.cache = cache
        self.queue: collections.deque = collections.deque()
        self.live = np.zeros((slots,), bool)
        self.cache_len = np.zeros((slots,), np.int32)
        self.tok = np.zeros((slots,), np.int32)
        self.reqs: List[Optional[Request]] = [None] * slots


class ServeLoop:
    """Continuous-batching scheduler over one model + one MaskSetStore.

    ``slots`` decode slots per SLO class; ``max_len`` bounds
    prompt + generation per slot.  ``prompt_bucket`` pads prompts up to a
    multiple of the bucket before the B=1 prefill so a handful of compiled
    prefill shapes serve every prompt length (exact for attention caches:
    causality keeps pad positions out of real tokens' outputs, and the
    pad rows' K/V are hidden from decode by per-slot validity masking;
    recurrent-state models need ``prompt_bucket=None`` — exact-length
    prefill, one compile per distinct length).  ``mesh``: optional — lane
    decode steps run under ``training.serve.jit_decode_step``'s production
    cache shardings instead of single-device jit.
    """

    def __init__(self, model: LM, params, store: serve_lib.MaskSetStore,
                 classes: Sequence[SLOClass], *, slots: int = 4,
                 max_len: int = 64, prompt_bucket: Optional[int] = 16,
                 mesh=None):
        """Build lanes (one resident decode cache per SLO class) and jits."""
        if not classes:
            raise ValueError("ServeLoop needs at least one SLO class")
        for c in classes:
            if c.mask_set not in store.names:
                raise serve_lib.MaskSetError(
                    f"SLO class {c.name!r} routes to mask set "
                    f"{c.mask_set!r}, not in the store ({store.names})")
        self.model, self.params, self.store = model, params, store
        self.slots, self.max_len = slots, max_len
        self.prompt_bucket = prompt_bucket
        self.mesh = mesh
        self._prefill = jax.jit(_make_last_logit_prefill(model))
        self._insert = jax.jit(serve_lib.make_insert_slot(model))
        if mesh is not None and mesh.size > 1:
            scfg = serve_lib.ServeCfg(dp_axes=("data",), max_len=max_len,
                                      batch=slots)
            self._decode = serve_lib.jit_decode_step(model, mesh, scfg)
        else:
            self._decode = jax.jit(serve_lib.make_decode_step(model))
        self.lanes: Dict[str, _Lane] = {
            c.name: _Lane(c, model.init_cache(slots, max_len), slots)
            for c in classes}
        self.completed: List[Request] = []
        self._next_rid = 0
        self._accepting = True

    # ------------------------------------------------------------ intake

    def submit(self, prompt: np.ndarray, slo: str) -> Request:
        """Enqueue a prompt under an SLO class; returns its Request."""
        if not self._accepting:
            raise RuntimeError("serve loop is shut down")
        if slo not in self.lanes:
            raise KeyError(f"unknown SLO class {slo!r} "
                           f"(have: {sorted(self.lanes)})")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        lane = self.lanes[slo]
        cap = self.max_len - lane.slo.max_new_tokens
        if not 0 < len(prompt) <= cap:
            raise ValueError(
                f"prompt length {len(prompt)} outside (0, {cap}] "
                f"(max_len {self.max_len} minus the class's "
                f"{lane.slo.max_new_tokens} generation budget)")
        req = Request(rid=self._next_rid, slo=slo, prompt=prompt,
                      t_arrival=time.perf_counter())
        self._next_rid += 1
        lane.queue.append(req)
        return req

    # ------------------------------------------------------------ ticking

    def step(self) -> int:
        """One scheduler tick: admit into free slots, decode every lane.

        Returns the number of requests still in flight (queued + live).
        """
        ctx = self.mesh if self.mesh is not None else _NullCtx()
        with ctx:
            for lane in self.lanes.values():
                self._admit(lane)
            for lane in self.lanes.values():
                self._decode_lane(lane)
        return self.pending()

    def pending(self) -> int:
        """Requests not yet completed: queued plus occupying a slot."""
        return sum(len(ln.queue) + int(ln.live.sum())
                   for ln in self.lanes.values())

    def run_until_drained(self, max_steps: int = 100000) -> None:
        """Tick until every queue and slot is empty (or ``max_steps``)."""
        for _ in range(max_steps):
            if self.step() == 0:
                return
        raise RuntimeError(
            f"serve loop failed to drain within {max_steps} steps "
            f"({self.pending()} requests still pending)")

    def shutdown(self, drain: bool = True) -> List[Request]:
        """Stop intake; drain in-flight work (or cancel it) and return
        every completed request.

        ``drain=True`` runs the loop until queues and slots are empty —
        every accepted request completes and is billed.  ``drain=False``
        cancels queued and in-flight requests (marked ``cancelled``, never
        billed).
        """
        self._accepting = False
        if drain:
            self.run_until_drained()
        else:
            for lane in self.lanes.values():
                for req in list(lane.queue) + [r for r in lane.reqs if r]:
                    req.cancelled = True
                lane.queue.clear()
                lane.live[:] = False
                lane.reqs = [None] * self.slots
        return self.completed

    # ------------------------------------------------------------ internals

    def _bucket(self, n: int) -> int:
        b = self.prompt_bucket
        return n if not b else min(-(-n // b) * b, self.max_len - 1)

    def _admit(self, lane: _Lane) -> None:
        free = np.flatnonzero(~lane.live)
        while lane.queue and free.size:
            slot, free = int(free[0]), free[1:]
            req = lane.queue.popleft()
            req.t_admit = time.perf_counter()
            L = len(req.prompt)
            toks = np.zeros((1, self._bucket(L)), np.int32)
            toks[0, :L] = req.prompt
            masks = self.store.select(lane.slo.mask_set)
            small = self.model.init_cache(1, self.max_len)
            nxt, small = self._prefill(self.params, masks,
                                       jnp.asarray(toks), small,
                                       jnp.asarray(L - 1, jnp.int32))
            lane.cache = self._insert(lane.cache, small,
                                      jnp.asarray(slot, jnp.int32))
            first = int(jax.block_until_ready(nxt)[0, 0])
            req.t_first = time.perf_counter()
            req.tokens.append(first)
            info = self.store.info(lane.slo.mask_set)
            req.mask_set, req.mask_fingerprint = info.name, info.fingerprint
            lane.live[slot] = True
            lane.cache_len[slot] = L
            lane.tok[slot] = first
            lane.reqs[slot] = req
            if lane.slo.max_new_tokens <= 1:
                self._finish(lane, slot)

    def _decode_lane(self, lane: _Lane) -> None:
        if not lane.live.any():
            return
        masks = self.store.select(lane.slo.mask_set)
        tok = jnp.asarray(lane.tok[:, None])
        cl = jnp.asarray(lane.cache_len)
        nxt, lane.cache = self._decode(self.params, masks, tok,
                                       lane.cache, cl)
        nxt = np.asarray(jax.block_until_ready(nxt)).reshape(-1)
        for slot in np.flatnonzero(lane.live):
            req = lane.reqs[slot]
            req.tokens.append(int(nxt[slot]))
            lane.tok[slot] = nxt[slot]
            lane.cache_len[slot] += 1
            done = len(req.tokens) >= lane.slo.max_new_tokens
            if done or lane.cache_len[slot] + 1 >= self.max_len:
                self._finish(lane, slot)

    def _finish(self, lane: _Lane, slot: int) -> None:
        req = lane.reqs[slot]
        req.t_done = time.perf_counter()
        info = self.store.info(lane.slo.mask_set)
        req.bill = pi_cost.bill_request(
            info.relu_cost, len(self.store.site_shapes),
            tokens=len(req.prompt) + len(req.tokens))
        lane.live[slot] = False
        lane.reqs[slot] = None
        self.completed.append(req)

    # ------------------------------------------------------------ reporting

    def stats(self) -> dict:
        """Per-SLO-class latency/throughput/billing aggregates (JSON-ready).

        ``decode_tok_s`` is per-slot decode rate (generated tokens over
        in-slot decode seconds, summed per class); percentiles are
        milliseconds over completed requests.
        """
        out: dict = {"classes": {}}
        for name, lane in self.lanes.items():
            reqs = [r for r in self.completed if r.slo == name]
            info = self.store.info(lane.slo.mask_set)
            per_tok = self.store.pi_cost_per_token(lane.slo.mask_set)
            cls = {"mask_set": lane.slo.mask_set,
                   "relu_cost": info.relu_cost,
                   "mask_fingerprint": info.fingerprint,
                   "pi_online_s_per_tok": per_tok.online_latency_s,
                   "requests": len(reqs)}
            if reqs:
                gen = sum(len(r.tokens) - 1 for r in reqs)
                dec = sum(r.decode_s for r in reqs)
                cls["decode_tok_s"] = gen / dec if dec > 0 else 0.0
                for key, get in (("queue", lambda r: r.queue_s),
                                 ("prefill", lambda r: r.prefill_s),
                                 ("decode", lambda r: r.decode_s),
                                 ("total", lambda r: r.total_s)):
                    vals = np.array([get(r) for r in reqs]) * 1e3
                    cls[f"{key}_ms_p50"] = float(np.percentile(vals, 50))
                    cls[f"{key}_ms_p95"] = float(np.percentile(vals, 95))
                cls["relus_billed"] = sum(r.bill["relus_billed"]
                                          for r in reqs)
                cls["pi_online_s"] = sum(r.bill["pi_online_s"]
                                         for r in reqs)
            out["classes"][name] = cls
        out["completed"] = len(self.completed)
        out["pending"] = self.pending()
        return out


class _NullCtx:
    """No-op context manager (single-device loops have no mesh scope)."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def _make_last_logit_prefill(model: LM):
    """B=1 prefill: argmax logits at the prompt's true last position.

    Prompts arrive right-padded to a bucket length; ``last_idx`` (traced)
    picks the real final position so one compiled shape serves every
    prompt length in the bucket.
    """
    def prefill(params, masks, tokens, cache, last_idx):
        logits, cache = model.forward(params, masks, tokens, cache=cache,
                                      cache_len=0)
        last = jax.lax.dynamic_index_in_dim(logits, last_idx, axis=1,
                                            keepdims=False)
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache
    return prefill


def threshold_mask_sets(model: LM, fracs: Sequence[float],
                        seed: int = 0) -> serve_lib.MaskSetStore:
    """Synthetic named budgets: one random-priority threshold per keep-frac.

    Serving smoke tests and the load generator use this when no sweep run
    directory is available; real deployments load checkpointed masks via
    :meth:`repro.training.serve.MaskSetStore.from_run_dir`.
    """
    shapes = {k: s.shape for k, s in model.mask_sites().items()}
    full = M.full_masks(shapes)
    total = M.count(full)
    rng = np.random.default_rng(seed)
    soft = {k: rng.random(v.shape).astype(np.float32)
            for k, v in full.items()}
    sets = {f"kf{int(round(f * 100)):03d}": M.threshold(soft,
                                                        int(total * f))
            for f in fracs}
    return serve_lib.MaskSetStore(shapes, sets)


def default_classes(store: serve_lib.MaskSetStore,
                    max_new_tokens: int = 8) -> List[SLOClass]:
    """One SLO class per stored budget, named after its mask set."""
    return [SLOClass(name=n, mask_set=n, max_new_tokens=max_new_tokens)
            for n in store.names]


def main(argv=None):
    """CLI demo: serve random prompts at ≥2 synthetic budgets and print
    the per-class stats JSON."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--budget-fracs", default="1.0,0.5",
                    help="comma list of keep-fracs -> synthetic mask sets")
    ap.add_argument("--masks-from", default=None, metavar="RUN_DIR",
                    help="load checkpointed mask sets from a launch.sweep "
                         "run dir instead of synthetic thresholds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shapes = {k: s.shape for k, s in model.mask_sites().items()}
    if args.masks_from:
        store = serve_lib.MaskSetStore.from_run_dir(args.masks_from, shapes)
    else:
        fracs = [float(x) for x in args.budget_fracs.split(",")]
        store = threshold_mask_sets(model, fracs, seed=args.seed)
    loop = ServeLoop(model, params, store,
                     default_classes(store, args.max_new),
                     slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        slo = store.names[i % len(store.names)]
        plen = int(rng.integers(4, args.max_len - args.max_new))
        loop.submit(rng.integers(0, cfg.vocab, plen), slo)
    loop.shutdown(drain=True)
    print(json.dumps(loop.stats(), indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
