"""Process-role coordination for multi-host runs and sweeps.

A cluster run executes the same deterministic BCD loop on every process
(candidate evaluation shards over the mesh; mask updates are host-side and
replicated), but exactly ONE process may own the checkpoint directory —
concurrent writers would interleave two checkpoint lineages and break the
bit-identical-resume contract.  A :class:`Coordinator` names that owner and
gives every rank the three primitives the runner/sweep layers need:

    rank / world_size    this process's position in the job
    is_writer            rank 0 — the only rank allowed to commit checkpoints
    barrier(tag)         all ranks reach the same named point
    broadcast(tag, x)    writer publishes a small JSON payload; all ranks
                         return it (e.g. the resume step + manifest
                         fingerprint, so every rank restores the SAME
                         checkpoint and can prove it)

Two backends:

- :class:`LocalCoordinator` — the in-process default: rank 0 of 1, barriers
  and broadcasts are no-ops.  Single-process runs pay nothing.
- :class:`FileCoordinator` — ranks rendezvous through a shared filesystem
  directory (the same substrate the checkpoints already require).  Works
  across processes and hosts, and is testable with plain ``subprocess``
  workers, mirroring the forced-device drills in
  ``tests/test_bcd_parallel.py``.

Every barrier/broadcast *tag* is namespaced by a per-tag use counter, so the
same tag may be reused (e.g. one barrier per sweep stage in a loop) as long
as all ranks issue the same sequence of calls — which the deterministic
run/sweep loops guarantee.  A *session* string namespaces one launch attempt:
after a crash, the relauncher starts all ranks with a fresh session so
leftover rendezvous files from the dead attempt cannot satisfy (or deadlock)
the new one.  Checkpoint directories deliberately live OUTSIDE the session
namespace — they are the state that survives attempts.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

# Environment contract for subprocess/cluster launchers (torchrun-style):
# the launcher exports these for every worker it spawns and `from_env()`
# rebuilds the coordinator from them.
ENV_RANK = "REPRO_COORD_RANK"
ENV_WORLD = "REPRO_COORD_WORLD"
ENV_DIR = "REPRO_COORD_DIR"
ENV_SESSION = "REPRO_COORD_SESSION"
ENV_TIMEOUT = "REPRO_COORD_TIMEOUT_S"   # optional: default rendezvous
#                                         timeout (raise it when slow
#                                         per-stage work keeps one rank
#                                         away from a barrier for minutes)


class CoordinatorError(RuntimeError):
    """A rendezvous failed: a barrier/broadcast timed out (dead or wedged
    peer rank) or the coordinator was constructed inconsistently."""


class LocalCoordinator:
    """Single-process coordinator: rank 0 of 1, all primitives trivial.

    This is the implicit default everywhere a ``coordinator=None`` argument
    is accepted — single-process runs never touch the filesystem or block.
    """

    rank = 0
    world_size = 1

    @property
    def is_writer(self) -> bool:
        """True — a world of one is its own writer."""
        return True

    def barrier(self, tag: str, timeout_s: Optional[float] = None) -> None:
        """No-op: every rank (of one) is already here."""

    def broadcast(self, tag: str, payload=None):
        """Return ``payload`` unchanged (the writer is the only reader)."""
        return payload

    def describe(self) -> dict:
        """JSON-able identity of this coordinator (for checkpoint meta)."""
        return {"backend": "local", "rank": 0, "world_size": 1}

    def close(self) -> None:
        """No-op (kept for interface symmetry with FileCoordinator)."""


class FileCoordinator:
    """File-based rendezvous over a shared directory.

    ``root`` must be visible to every rank (shared filesystem — the same
    requirement the checkpoint directory already imposes).  All rendezvous
    state lives under ``root/<session>/``; relaunch with a fresh ``session``
    after a crash so stale files from the dead attempt are inert.

    Rendezvous files are written atomically (tmp + rename), so a reader
    never sees a partial payload; barriers poll for the arrival files of all
    ``world_size`` ranks and report exactly which ranks are missing when the
    timeout expires — a SIGKILLed peer surfaces as a named
    :class:`CoordinatorError`, not a silent hang.

    **Liveness**: every rank refreshes a per-rank lease file
    (``lease_rank_<r>``, every ``lease_interval_s``) while it waits inside
    ``barrier``/``broadcast``.  When a wait times out, each missing rank's
    lease distinguishes *dead* (lease expired — the process was SIGKILLed
    or the host vanished) from *wedged* (lease fresh — alive but stuck
    elsewhere, e.g. a divergent call sequence) from *never started* (no
    lease at all).  Lease age uses the shared filesystem's mtime, so
    cross-host clock skew cannot mis-declare a peer dead.
    """

    def __init__(self, root: str, rank: int, world_size: int, *,
                 session: str = "s0", poll_s: float = 0.02,
                 timeout_s: float = 300.0, lease_interval_s: float = 1.0,
                 lease_ttl_s: float = 5.0):
        """Join rendezvous directory ``root/<session>`` as ``rank``.

        ``timeout_s`` bounds every barrier/broadcast wait (overridable per
        call); ``poll_s`` is the filesystem polling interval.
        ``lease_interval_s`` is the heartbeat refresh period while waiting;
        a peer whose lease is older than ``lease_ttl_s`` at timeout is
        reported dead (keep ttl comfortably above the interval — a slow
        shared filesystem delays renames).
        """
        if not (0 <= rank < world_size):
            raise CoordinatorError(
                f"rank {rank} outside world of size {world_size}")
        if lease_ttl_s <= lease_interval_s:
            raise CoordinatorError(
                f"lease_ttl_s {lease_ttl_s} must exceed lease_interval_s "
                f"{lease_interval_s} or every slow heartbeat reads as a "
                "death")
        self.rank = int(rank)
        self.world_size = int(world_size)
        self.session = str(session)
        self._dir = os.path.join(root, self.session)
        self._poll_s = float(poll_s)
        self._timeout_s = float(timeout_s)
        self._lease_interval_s = float(lease_interval_s)
        self._lease_ttl_s = float(lease_ttl_s)
        self._lease_at = -float("inf")
        self._seq: dict = {}
        os.makedirs(self._dir, exist_ok=True)
        self._refresh_lease()

    # ------------------------------------------------------------- leases

    def _lease_path(self, rank: int) -> str:
        return os.path.join(self._dir, f"lease_rank_{rank:05d}")

    def _refresh_lease(self) -> None:
        """Touch this rank's lease (atomic, at most once per interval)."""
        now = time.monotonic()
        if now - self._lease_at < self._lease_interval_s:
            return
        mine = self._lease_path(self.rank)
        with open(mine + ".tmp", "w") as f:
            f.write(str(time.time()))
        os.replace(mine + ".tmp", mine)
        self._lease_at = now

    def _peer_status(self, rank: int) -> str:
        """Human-readable liveness verdict for one missing rank."""
        try:
            age = time.time() - os.path.getmtime(self._lease_path(rank))
        except OSError:
            return f"rank {rank} never started (no lease)"
        if age > self._lease_ttl_s:
            return (f"rank {rank} dead (lease expired "
                    f"{age - self._lease_ttl_s:.1f}s ago)")
        return (f"rank {rank} alive (lease {age:.1f}s old) but not here — "
                "wedged or on a divergent call sequence?")

    @property
    def is_writer(self) -> bool:
        """True on rank 0 — the single rank allowed to commit checkpoints."""
        return self.rank == 0

    def _next(self, kind: str, tag: str) -> str:
        key = (kind, tag)
        n = self._seq.get(key, 0)
        self._seq[key] = n + 1
        return f"{kind}_{tag}.{n:04d}"

    def barrier(self, tag: str, timeout_s: Optional[float] = None) -> None:
        """Block until all ``world_size`` ranks reach this barrier.

        Ranks must issue the same sequence of ``barrier``/``broadcast``
        calls (tags are use-counted).  Raises :class:`CoordinatorError`
        naming the missing ranks if the wait exceeds the timeout.
        """
        d = os.path.join(self._dir, self._next("barrier", tag))
        os.makedirs(d, exist_ok=True)
        mine = os.path.join(d, f"rank_{self.rank:05d}")
        with open(mine + ".tmp", "w") as f:
            f.write(str(time.time()))
        os.replace(mine + ".tmp", mine)
        deadline = time.monotonic() + (self._timeout_s if timeout_s is None
                                       else timeout_s)
        want = {f"rank_{r:05d}" for r in range(self.world_size)}
        while True:
            self._refresh_lease()
            have = {p for p in os.listdir(d) if not p.endswith(".tmp")}
            if want <= have:
                return
            if time.monotonic() > deadline:
                missing = sorted(int(p.split("_")[1]) for p in want - have)
                verdicts = "; ".join(self._peer_status(r) for r in missing)
                raise CoordinatorError(
                    f"barrier {tag!r} (session {self.session}) timed out "
                    f"waiting for rank(s) {missing}: {verdicts} — "
                    "relaunch all ranks with a fresh session")
            time.sleep(self._poll_s)

    def broadcast(self, tag: str, payload=None,
                  timeout_s: Optional[float] = None):
        """Writer publishes ``payload`` (JSON-able); every rank returns it.

        Non-writer ranks ignore their ``payload`` argument and block until
        the writer's file lands (atomic rename, so a read never sees a
        partial payload).  Raises :class:`CoordinatorError` on timeout.
        """
        path = os.path.join(self._dir, self._next("bcast", tag) + ".json")
        if self.is_writer:
            with open(path + ".tmp", "w") as f:
                json.dump({"payload": payload}, f)
            os.replace(path + ".tmp", path)
            return payload
        deadline = time.monotonic() + (self._timeout_s if timeout_s is None
                                       else timeout_s)
        while not os.path.exists(path):
            self._refresh_lease()
            if time.monotonic() > deadline:
                raise CoordinatorError(
                    f"broadcast {tag!r} (session {self.session}): rank "
                    f"{self.rank} timed out waiting for the writer — "
                    f"{self._peer_status(0)}; relaunch with a fresh "
                    "session")
            time.sleep(self._poll_s)
        with open(path) as f:
            return json.load(f)["payload"]

    def describe(self) -> dict:
        """JSON-able identity of this coordinator (for checkpoint meta)."""
        return {"backend": "file", "rank": self.rank,
                "world_size": self.world_size, "session": self.session}

    def close(self) -> None:
        """Release nothing actively; rendezvous files are left for the
        launcher to clean (they are inert once the session ends)."""


def from_env(default_root: Optional[str] = None):
    """Build a coordinator from the launcher's environment.

    Reads ``REPRO_COORD_RANK`` / ``REPRO_COORD_WORLD`` /
    ``REPRO_COORD_DIR`` / ``REPRO_COORD_SESSION``; with the world env var
    absent (or world 1), a :class:`LocalCoordinator` is returned, so
    single-process invocations of multi-host-capable entry points need no
    configuration.  For a real multi-rank job, rank AND a fresh-per-attempt
    session are mandatory; ``default_root`` supplies the rendezvous
    directory when the launcher set the rank/world but no
    ``REPRO_COORD_DIR`` (e.g. an out-dir-relative default).
    """
    def _int_env(var: str, value: str) -> int:
        try:
            return int(value)
        except ValueError as e:
            raise CoordinatorError(
                f"{var}={value!r} is not an integer") from e

    world = _int_env(ENV_WORLD, os.environ.get(ENV_WORLD, "1"))
    if world <= 1:
        return LocalCoordinator()
    rank = os.environ.get(ENV_RANK)
    if rank is None:
        raise CoordinatorError(
            f"{ENV_WORLD}={world} but {ENV_RANK} is unset — the launcher "
            "must export a rank for every worker")
    root = os.environ.get(ENV_DIR, default_root)
    if root is None:
        raise CoordinatorError(
            f"{ENV_WORLD}={world} but no rendezvous directory: set "
            f"{ENV_DIR} (a shared filesystem path) or pass default_root")
    session = os.environ.get(ENV_SESSION)
    if session is None:
        # a silent constant default would let a relaunch rendezvous against
        # a dead attempt's leftover files — the exact failure sessions exist
        # to prevent.  The launcher must mint a fresh value per attempt
        # (and the SAME value on every rank of that attempt).
        raise CoordinatorError(
            f"{ENV_WORLD}={world} but {ENV_SESSION} is unset — the launcher "
            "must export a fresh session id per launch attempt, identical "
            "across ranks (e.g. a timestamp or scheduler attempt id)")
    try:
        timeout_s = float(os.environ.get(ENV_TIMEOUT, "300"))
    except ValueError as e:
        raise CoordinatorError(
            f"{ENV_TIMEOUT}={os.environ[ENV_TIMEOUT]!r} is not a "
            "number") from e
    return FileCoordinator(root, _int_env(ENV_RANK, rank), world,
                           session=session, timeout_s=timeout_s)
