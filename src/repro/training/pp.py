"""Optional pipeline parallelism: GPipe-style microbatch schedule.

The canonical skew schedule expressed as a scan over clock ticks with a
(n_stages, microbatch, ...) rolling buffer:

    tick t: shift microbatch t into stage 0, run ALL stages in parallel
            (vmap over the stacked stage axis), emit stage S-1's output.

``jax.vmap(body)`` over the stage axis is exactly what a 'stage' mesh axis
shards: placing the leading stage dimension of ``stage_params`` / the state
buffer on a mesh axis turns the vmap into per-device stage execution and the
roll into a ``collective_permute`` — the standard JAX pipelining recipe.
Bubble fraction is (S-1)/(M+S-1); the dry-run meshes use DP×TP×FSDP instead
because ≤32 B params on 512 chips needs no PP (DESIGN §5) — this module is
the substrate for when depth × scale does.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def gpipe_forward(body: Callable, stage_params, micro_inputs):
    """Run ``micro_inputs`` through a pipeline of homogeneous stages.

    body:          (stage_param_tree, x) -> y   (one stage's forward)
    stage_params:  pytree with leading stage axis S on every leaf
    micro_inputs:  (M, micro_batch, ...) — M microbatches
    Returns (M, micro_batch, ...) outputs, equivalent to applying the S
    stages sequentially to each microbatch.
    """
    S = jax.tree.leaves(stage_params)[0].shape[0]
    M = micro_inputs.shape[0]
    state = jnp.zeros((S,) + micro_inputs.shape[1:], micro_inputs.dtype)

    def tick(state, t):
        inp = micro_inputs[jnp.clip(t, 0, M - 1)]
        inp = jnp.where(t < M, inp, jnp.zeros_like(inp))
        shifted = jnp.concatenate([inp[None], state[:-1]], axis=0)
        new_state = jax.vmap(body)(stage_params, shifted)
        return new_state, new_state[-1]

    _, ys = jax.lax.scan(tick, state, jnp.arange(M + S - 1))
    return ys[S - 1:]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule — the classic (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
