"""Fault-tolerant checkpointing: atomic, shard-wise, mesh-agnostic restore.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/ ... -> atomic rename -> ckpt_dir/step_000123/
        manifest.json      {step, leaf paths, global shapes/dtypes, meta}
        p0_<leaf>.npy      per-process shard files (process 0 here)

Arrays are saved as *host-local shards* with their global layout recorded in
the manifest, so restore can (a) reassemble the global array and (b) re-shard
it onto ANY mesh — elastic restart across different topologies (DESIGN §5).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _key_part(p) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(_key_part(p) for p in path)] = leaf
    return flat


def save(state, ckpt_dir: str, step: int, *, meta: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomic checkpoint write.  Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    treedef = jax.tree_util.tree_structure(state)
    manifest["treedef"] = str(treedef)
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomicity point
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(state_template, ckpt_dir: str, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``state_template``; optionally place
    leaves with ``shardings`` (same tree) — elastic re-shard onto any mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _flatten(state_template)
    shard_flat = _flatten(shardings) if shardings is not None else None
    out = {}
    for key, tmpl in flat_t.items():
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(d, info["file"]))
        if shard_flat is not None and key in shard_flat and \
                shard_flat[key] is not None:
            out[key] = jax.device_put(arr, shard_flat[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild tree in template structure
    leaves_t, treedef = jax.tree_util.tree_flatten(state_template)
    keys = list(_flatten(state_template).keys())
    # _flatten sorted ordering must match tree_flatten ordering:
    ordered = [out[k] for k in _flatten_keys_in_order(state_template)]
    return jax.tree_util.tree_unflatten(treedef, ordered), step


def _flatten_keys_in_order(tree):
    return [_SEP.join(_key_part(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def validate(ckpt_dir: str, step: int) -> bool:
    """A checkpoint is valid iff its manifest and all leaf files exist."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    mf = os.path.join(d, "manifest.json")
    if not os.path.exists(mf):
        return False
    try:
        with open(mf) as f:
            manifest = json.load(f)
        return all(os.path.exists(os.path.join(d, v["file"]))
                   for v in manifest["leaves"].values())
    except (json.JSONDecodeError, KeyError):
        return False
