"""Fault-tolerant checkpointing: atomic, shard-wise, mesh-agnostic restore.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/ ... -> atomic rename -> ckpt_dir/step_000123/
        manifest.json      {step, leaf paths, global shapes/dtypes, meta}
        p0_<leaf>.npy      per-process shard files (process 0 here)

Arrays are saved as *host-local shards* with their global layout recorded in
the manifest, so restore can (a) reassemble the global array and (b) re-shard
it onto ANY mesh — elastic restart across different topologies (DESIGN §5).

Multi-host policy: a checkpoint directory has exactly ONE writer (rank 0 of
the job's :mod:`repro.launch.coordinator`).  :func:`save` enforces this when
handed a coordinator; reader ranks follow the writer's lineage with
:func:`wait_for_step` and prove they restored the same checkpoint by
comparing :func:`manifest_fingerprint` values — two ranks that ever disagree
on a manifest byte are on divergent lineages and must abort, not average.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot be trusted: missing leaf files,
    unreadable/mismatched manifest, or a leaf whose bytes fail the
    manifest's sha256 — the restore path refuses partial state rather than
    resuming a run from silently corrupted arrays."""


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _key_part(p) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(_key_part(p) for p in path)] = leaf
    return flat


def save(state, ckpt_dir: str, step: int, *, meta: Optional[dict] = None,
         keep: int = 3, coordinator=None) -> str:
    """Atomic checkpoint write.  Returns the final directory.

    ``state`` is any pytree; every leaf lands as one ``.npy`` with its
    sha256 recorded in the manifest, and the whole step directory becomes
    visible in a single rename (readers never observe a partial step).
    ``keep`` garbage-collects the oldest step directories past that count.

    ``coordinator`` (optional, a :mod:`repro.launch.coordinator` object)
    enforces the single-writer policy: a non-writer rank calling this is a
    logic error in the calling layer and raises :class:`CheckpointError`
    before any bytes are written — reader ranks must
    :func:`wait_for_step` instead.
    """
    if coordinator is not None and not coordinator.is_writer:
        raise CheckpointError(
            f"rank {coordinator.rank} is not the writer (rank 0 of "
            f"{coordinator.world_size}): only the writer commits "
            f"checkpoints to {ckpt_dir}; readers wait_for_step()")
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    treedef = jax.tree_util.tree_structure(state)
    manifest["treedef"] = str(treedef)
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": _file_sha256(fpath)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomicity point
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step directory present (no validity check) or None.

    Prefer :func:`latest_valid_step` for resume decisions — a crash can
    leave the newest step present but unusable.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def manifest_fingerprint(ckpt_dir: str, step: int) -> str:
    """sha256 over a checkpoint's canonicalized manifest.

    The manifest already pins every leaf's bytes (per-leaf sha256), shapes,
    dtypes, and the run meta — so two checkpoints with equal fingerprints
    describe bit-identical state.  This is what multi-host restores compare
    across ranks: the writer broadcasts its fingerprint and every reader
    verifies it resumed the SAME lineage, not merely the same step number.
    Canonicalized (sorted keys, tight separators) so the fingerprint is a
    property of the content, not of json.dump's formatting.
    """
    manifest = read_manifest(ckpt_dir, step)
    blob = json.dumps(manifest, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def wait_for_step(ckpt_dir: str, step: int, *, timeout_s: float = 300.0,
                  poll_s: float = 0.05) -> int:
    """Block until a valid checkpoint at ``>= step`` exists; return its step.

    The reader side of the single-writer policy: non-writer ranks call this
    where the writer calls :func:`save`, so every rank proceeds only once
    the step is durably committed (the atomic rename makes a visible step
    directory complete).  Polls :func:`latest_valid_step` shallowly —
    content trust comes from the restore path's hash verification.  Raises
    :class:`CheckpointError` when the timeout expires (dead writer).
    """
    deadline = time.monotonic() + timeout_s
    while True:
        got = latest_valid_step(ckpt_dir, deep=False)
        if got is not None and got >= step:
            return got
        if time.monotonic() > deadline:
            raise CheckpointError(
                f"timed out after {timeout_s:.0f}s waiting for checkpoint "
                f"step >= {step} in {ckpt_dir} (newest valid: {got}) — "
                "writer rank dead or stalled")
        time.sleep(poll_s)


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """Load + sanity-check a checkpoint's manifest (incl. its ``meta``)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    mf = os.path.join(d, "manifest.json")
    if not os.path.exists(mf):
        raise CheckpointError(f"no manifest at {mf}")
    try:
        with open(mf) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointError(f"unreadable manifest {mf}: {e}") from e
    if "leaves" not in manifest:
        raise CheckpointError(f"manifest {mf} has no leaves table")
    return manifest


def restore(state_template, ckpt_dir: str, step: Optional[int] = None,
            shardings=None, *, verify: bool = True):
    """Restore into the structure of ``state_template``; optionally place
    leaves with ``shardings`` (same tree) — elastic re-shard onto any mesh.

    ``verify`` checks each leaf file against the manifest's sha256 before
    use (checkpoints written before checksums existed skip silently);
    corruption raises :class:`CheckpointError` instead of handing the caller
    partial state.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = read_manifest(ckpt_dir, step)
    flat_t = _flatten(state_template)
    shard_flat = _flatten(shardings) if shardings is not None else None
    out = {}
    for key in flat_t:
        if key not in manifest["leaves"]:
            raise CheckpointError(
                f"checkpoint {d} is missing leaf {key!r} required by the "
                "restore template")
        info = manifest["leaves"][key]
        fpath = os.path.join(d, info["file"])
        if not os.path.exists(fpath):
            raise CheckpointError(f"checkpoint {d}: leaf file {info['file']} "
                                  "is missing (partial write?)")
        if verify and info.get("sha256") and \
                _file_sha256(fpath) != info["sha256"]:
            raise CheckpointError(
                f"checkpoint {d}: leaf {key!r} ({info['file']}) fails its "
                "manifest sha256 — corrupted on disk")
        try:
            arr = np.load(fpath)
        except (ValueError, OSError, EOFError) as e:
            raise CheckpointError(
                f"checkpoint {d}: leaf {key!r} unreadable: {e}") from e
        if shard_flat is not None and key in shard_flat and \
                shard_flat[key] is not None:
            out[key] = jax.device_put(arr, shard_flat[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild tree in template structure
    treedef = jax.tree_util.tree_structure(state_template)
    # _flatten sorted ordering must match tree_flatten ordering:
    ordered = [out[k] for k in _flatten_keys_in_order(state_template)]
    return jax.tree_util.tree_unflatten(treedef, ordered), step


def _flatten_keys_in_order(tree):
    return [_SEP.join(_key_part(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def validate(ckpt_dir: str, step: int, *, deep: bool = False) -> bool:
    """A checkpoint is valid iff its manifest and all leaf files exist;
    ``deep`` additionally re-hashes every leaf against the manifest's
    sha256 (catches truncated/bit-rotted files, not just missing ones)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        manifest = read_manifest(ckpt_dir, step)
    except CheckpointError:
        return False
    try:
        for v in manifest["leaves"].values():
            fpath = os.path.join(d, v["file"])
            if not os.path.exists(fpath):
                return False
            if deep and v.get("sha256") and \
                    _file_sha256(fpath) != v["sha256"]:
                return False
    except (KeyError, TypeError):
        return False
    return True


def latest_valid_step(ckpt_dir: str, *, deep: bool = True) -> Optional[int]:
    """Newest step that passes :func:`validate` — the resume point.  Scans
    descending so a crash that corrupted only the newest checkpoint falls
    back to the one before it."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted((int(m.group(1)) for d in os.listdir(ckpt_dir)
                    if (m := re.fullmatch(r"step_(\d+)", d))), reverse=True)
    for s in steps:
        if validate(ckpt_dir, s, deep=deep):
            return s
    return None
