"""Distributed train-step factory: pjit shardings, remat, loss, metrics.

``make_train_step`` builds the jitted step with explicit in/out shardings
(params/opt-state: FSDP×TP via models.lm.param_specs; batch: DP over
('pod','data'); masks: replicated).  The same factory serves the dry-run
(lower + compile on the 512-device mesh) and real training (CPU smoke runs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm as lm_lib
from . import optimizer as opt_lib


def cross_entropy(logits, labels, valid=None):
    """Mean CE over valid positions.  logits (..., V) any dtype; labels int.

    SPMD-friendly: the gold logit is picked with a fused one-hot reduce
    (sharded-vocab safe — a take_along_axis gather would make GSPMD all-gather
    the logits), and logsumexp reduces partial max/sum per vocab shard.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    onehot = (iota == labels[..., None]).astype(lf.dtype)
    gold = jnp.sum(lf * onehot, axis=-1)
    nll = lse - gold
    if valid is None:
        return jnp.mean(nll)
    v = valid.astype(jnp.float32)
    return jnp.sum(nll * v) / jnp.maximum(jnp.sum(v), 1.0)


def quantize_grads_int8(grads):
    """Per-tensor symmetric int8 quantize→dequantize (gradient compression:
    models an 8-bit gradient all-reduce; numerics match what a compressed
    collective would deliver)."""
    def q(g):
        if g.ndim == 0 or g.size < 1024:
            return g
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        return (jnp.round(g / scale).astype(jnp.int8).astype(g.dtype) * scale)
    return jax.tree.map(q, grads)


@dataclasses.dataclass
class TrainStepCfg:
    """Train-step lowering knobs (remat, sharding axes, memory levers)."""

    remat: bool = True
    compress_grads: bool = False
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp: bool = True
    model_axis: str = "model"      # logits vocab-sharding constraint
    loss_chunk: int = 0            # seq-chunked CE (0 = whole-sequence);
    # bounds live logits to (B, loss_chunk, V) — §Perf memory lever
    seq_shard_acts: bool = False   # shard the scan-carry (saved activation
    # stack) over 'model' along sequence — Megatron-SP-style memory lever


def make_state(model: lm_lib.LM, opt: opt_lib.Optimizer, key):
    """Fresh train state: params + optimizer moments + step counter."""
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(model: lm_lib.LM, opt: opt_lib.Optimizer, data: int,
                model_ax: int, fsdp: bool = True):
    """PartitionSpec tree for the train state (opt moments follow params)."""
    pshapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    pspec = lm_lib.param_specs(pshapes, data, model_ax, fsdp)
    sstruct = jax.eval_shape(lambda: opt.init(pshapes))
    # mu mirrors params; nu mirrors params for AdamW, scalar for SGD
    same = (jax.tree_util.tree_structure(sstruct.nu)
            == jax.tree_util.tree_structure(pshapes))
    return {"params": pspec,
            "opt": opt_lib.OptState(P(), pspec, pspec if same else P()),
            "step": P()}


def make_train_step(model: lm_lib.LM, opt: opt_lib.Optimizer,
                    cfg: TrainStepCfg = TrainStepCfg()):
    """Returns train_step(state, batch, masks) -> (state, metrics)."""
    dp = cfg.dp_axes

    def loss_fn(params, masks, batch):
        tokens = batch["tokens"]
        pe = batch.get("prefix_embeds")
        S_text = tokens.shape[1]
        if cfg.loss_chunk and S_text % cfg.loss_chunk == 0:
            hidden, _ = model.forward(params, masks, tokens,
                                      prefix_embeds=pe, remat=cfg.remat,
                                      return_hidden=True)
            if pe is not None:
                hidden = hidden[:, pe.shape[1]:]
            B = hidden.shape[0]
            nch = S_text // cfg.loss_chunk
            hc = hidden.reshape(B, nch, cfg.loss_chunk, -1).swapaxes(0, 1)
            lc = batch["labels"].reshape(B, nch, cfg.loss_chunk).swapaxes(
                0, 1)
            embed_t = params["embed"].T

            def body(tot, xs):
                h, lb = xs
                logits = h @ embed_t.astype(h.dtype)
                if cfg.dp_axes:
                    logits = jax.lax.with_sharding_constraint(
                        logits, P(cfg.dp_axes, None, cfg.model_axis))
                lf = logits.astype(jnp.float32)
                m = jax.lax.stop_gradient(jnp.max(lf, -1, keepdims=True))
                lse = jnp.log(jnp.sum(jnp.exp(lf - m), -1)) + m[..., 0]
                iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, 2)
                gold = jnp.sum(lf * (iota == lb[..., None]).astype(lf.dtype),
                               -1)
                return tot + jnp.sum(lse - gold), None

            total, _ = jax.lax.scan(jax.checkpoint(body),
                                    jnp.zeros((), jnp.float32), (hc, lc))
            return total / (B * S_text), None
        logits, _ = model.forward(params, masks, tokens, prefix_embeds=pe,
                                  remat=cfg.remat)
        if cfg.dp_axes:
            logits = jax.lax.with_sharding_constraint(
                logits, P(cfg.dp_axes, None, cfg.model_axis))
        if pe is not None:
            logits = logits[:, pe.shape[1]:]   # loss only on text positions
        loss = cross_entropy(logits, batch["labels"])
        return loss, logits

    def train_step(state, batch, masks):
        if dp:
            batch = {k: jax.lax.with_sharding_constraint(
                         v, P(dp, *([None] * (v.ndim - 1))))
                     for k, v in batch.items()}
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], masks, batch)
        if cfg.compress_grads:
            grads = quantize_grads_int8(grads)
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        params = opt_lib.apply_updates(state["params"], updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def jit_train_step(model, opt, mesh: Mesh, cfg: TrainStepCfg):
    """pjit'd train step with explicit shardings (used by dryrun + launch)."""
    data = mesh.shape["data"]
    model_ax = mesh.shape["model"]
    model.activation_spec = P(cfg.dp_axes,
                              cfg.model_axis if cfg.seq_shard_acts else None,
                              None)
    sspec = state_specs(model, opt, data, model_ax, cfg.fsdp)
    step = make_train_step(model, opt, cfg)
    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                     is_leaf=lambda x: isinstance(x, P)),
        None,                            # batch: constrained inside
        NamedSharding(mesh, P()),        # masks: replicated
    )
    out_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                     is_leaf=lambda x: isinstance(x, P)),
        None,
    )
    return jax.jit(step, in_shardings=in_shardings,
                   out_shardings=out_shardings, donate_argnums=(0,))


# ------------------------------------------------------------- CNN path


def make_cnn_train_step(model, opt):
    """Single-host CNN train step (the paper's reproduction scale)."""
    def loss_fn(params, masks, batch, soft=False):
        logits = model.forward(params, masks, batch["images"], soft=soft)
        loss = cross_entropy(logits, batch["labels"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]
                        ).astype(jnp.float32)) * 100.0
        return loss, acc

    @jax.jit
    def step(params, opt_state, masks, batch):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: loss_fn(p, masks, batch), has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return opt_lib.apply_updates(params, updates), opt_state, loss, acc

    return step, loss_fn


def make_eval_acc(forward: Callable, eval_batch: Dict):
    """jitted masks->accuracy[%] closure for BCD (masks are jit inputs:
    candidate evaluation never recompiles)."""
    @jax.jit
    def acc(params, masks):
        logits = forward(params, masks)
        return jnp.mean((jnp.argmax(logits, -1) == eval_batch["labels"]
                         ).astype(jnp.float32)) * 100.0
    return acc
