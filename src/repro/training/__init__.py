"""Training substrate: steps, optimizers, serving, fault tolerance,
checkpointing, and pipeline-parallel scheduling."""
from . import optimizer, train, serve, checkpoint, ft, pp  # noqa: F401
