from . import optimizer, train, serve, checkpoint, ft, pp  # noqa: F401
