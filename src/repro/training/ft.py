"""Fault tolerance: restart supervisor, straggler watchdog, elastic restart.

On a real cluster the supervisor wraps the per-host training loop; node
failures surface as exceptions (or missing heartbeats) and the loop restarts
from the newest *valid* checkpoint.  Here failures are injected
(``FailureInjector``) so the whole recovery path is exercised in tests:

  run_supervised(...)   — restart-from-checkpoint loop (bounded failures)
  StragglerWatchdog     — per-step wall-time EWMA; flags slow steps/hosts
  elastic restore       — checkpoint.restore(shardings=new_mesh_shardings)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from . import checkpoint


class SimulatedNodeFailure(RuntimeError):
    """Injected stand-in for a node failure (tests / drills only)."""


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedNodeFailure at the given global steps (once each)."""
    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        """Raise SimulatedNodeFailure when ``step`` is scheduled to fail."""
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedNodeFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA straggler detection.  On TPU pods the same logic runs per-host on
    step barriers; a flagged host is reported for preemption/replacement.
    ``slow_factor`` follows the usual 1.5-2x practice."""
    alpha: float = 0.1
    slow_factor: float = 2.0
    warmup: int = 3
    ewma: Optional[float] = None
    n: int = 0
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Record one step's wall time; True iff it was flagged as slow."""
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_slow = self.n > self.warmup and dt > self.slow_factor * self.ewma
        if is_slow:
            self.flagged.append(step)
        else:
            # stragglers do not poison the EWMA
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_slow


def run_supervised(
    init_state_fn: Callable[[], object],
    step_fn: Callable[[object, int], object],
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_failures: int = 10,
    injector: Optional[FailureInjector] = None,
    watchdog: Optional[StragglerWatchdog] = None,
    state_shardings=None,
) -> Dict:
    """Training loop with checkpoint/restart.  step_fn(state, step)->state.

    Returns {state, restarts, flagged_steps, completed_steps}.
    """
    restarts = 0
    while True:
        # ---- (re)start: newest valid checkpoint, else fresh init
        start = 0
        state = None
        latest = checkpoint.latest_step(ckpt_dir)
        if latest is not None and checkpoint.validate(ckpt_dir, latest):
            template = init_state_fn()
            state, start = checkpoint.restore(template, ckpt_dir, latest,
                                              shardings=state_shardings)
        if state is None:
            state = init_state_fn()
        try:
            for step in range(start, n_steps):
                t0 = time.perf_counter()
                if injector is not None:
                    injector.check(step)
                state = step_fn(state, step)
                if watchdog is not None:
                    watchdog.observe(step, time.perf_counter() - t0)
                if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
                    checkpoint.save(state, ckpt_dir, step + 1)
            return {"state": state, "restarts": restarts,
                    "flagged_steps": (watchdog.flagged if watchdog else []),
                    "completed_steps": n_steps}
        except SimulatedNodeFailure:
            restarts += 1
            if restarts > max_failures:
                raise
