"""Serving: batched prefill + single-token decode with sharded caches.

``decode_32k`` / ``long_500k`` cells lower ``serve_step`` — one new token
against a KV cache (or SSM state) of the cell's seq_len.  Caches are jit
inputs AND outputs with identical shardings (state-passing style), batch over
DP axes; for long_500k (B=1) the KV-cache *sequence* axis shards over 'data'
(sequence parallelism — DESIGN §5).
"""
from __future__ import annotations

import dataclasses
import glob
import os
import re
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import masks as M, pi_cost
from repro.models import lm as lm_lib


@dataclasses.dataclass
class ServeCfg:
    """Serving shape/placement knobs (batch, cache length, DP axes)."""

    dp_axes: Tuple[str, ...] = ("data",)
    max_len: int = 32768
    batch: int = 128
    greedy: bool = True


def make_prefill(model: lm_lib.LM):
    """Prefill closure: (params, masks, tokens, cache) -> (last logits, cache)."""
    def prefill(params, masks, tokens, cache, prefix_embeds=None):
        logits, cache = model.forward(params, masks, tokens,
                                      prefix_embeds=prefix_embeds,
                                      cache=cache, cache_len=0)
        return logits[:, -1], cache
    return prefill


def make_decode_step(model: lm_lib.LM):
    """Greedy single-token decode closure over a running cache."""
    def decode_step(params, masks, token, cache, cache_len):
        """token (B,1) -> (next_token (B,1), cache)."""
        logits, cache = model.forward(params, masks, token, cache=cache,
                                      cache_len=cache_len)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache
    return decode_step


def serve_shardings(model: lm_lib.LM, mesh: Mesh, cfg: ServeCfg):
    """(param_shardings, cache_shardings) for jit in/out_shardings."""
    data = mesh.shape["data"]
    model_ax = mesh.shape["model"]
    dp_size = 1
    for a in cfg.dp_axes:
        dp_size *= mesh.shape[a]
    pshapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    pspec = lm_lib.param_specs(pshapes, data, model_ax, fsdp=False)
    cshapes = jax.eval_shape(
        lambda: model.init_cache(cfg.batch, cfg.max_len))
    cspec = _cache_specs(cshapes, cfg.dp_axes, dp_size, cfg.batch, data,
                         model_ax)
    to_sh = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    return to_sh(pspec), to_sh(cspec)


def _cache_specs(cache_shape, dp_axes, dp_size: int, B: int, data: int,
                 model_ax: int):
    """KV (B,S,KV,hd): batch over dp if divisible, else seq over 'data'
    (B==1 long-context); heads (or head_dim) over 'model' when divisible.
    SSM/RWKV states: batch over dp, heads over 'model'."""
    batch_ok = B % dp_size == 0 and B >= dp_size

    def f(path, leaf):
        # stack entries carry a leading repeats dim — spec it None
        stacked = any(getattr(p, "key", None) == "stack" for p in path)
        shape = leaf.shape[1:] if stacked else leaf.shape
        nd = len(shape)
        bspec = dp_axes if batch_ok else None
        if nd == 4 and shape[1] >= 1024:               # KV cache (B,S,KV,hd)
            seq = None if batch_ok else "data"
            kv_ok = shape[2] % model_ax == 0
            sp = P(bspec, seq, "model" if kv_ok else None,
                   "model" if (not kv_ok and shape[3] % model_ax == 0)
                   else None)
        elif nd == 4:                                  # ssm/rwkv state
            sp = P(bspec, "model" if shape[1] % model_ax == 0 else None,
                   None, None)
        elif nd == 3:                                  # conv state (B,dc-1,di)
            sp = P(bspec, None,
                   "model" if shape[2] % model_ax == 0 else None)
        elif nd == 2:                                  # prev-token (B,d)
            sp = P(bspec, "model" if shape[1] % model_ax == 0 else None)
        else:
            sp = P()
        return P(None, *sp) if stacked else sp
    return jax.tree_util.tree_map_with_path(f, cache_shape)


def _set_act_spec(model, mesh, cfg):
    dp = _dp(mesh, cfg.dp_axes)
    b = cfg.dp_axes if (cfg.batch % dp == 0 and cfg.batch >= dp) else None
    model.activation_spec = P(b, None, None)
    return b


def jit_prefill(model: lm_lib.LM, mesh: Mesh, cfg: ServeCfg,
                with_prefix: bool = False):
    """Jit the prefill step with production shardings (cache donated)."""
    _set_act_spec(model, mesh, cfg)
    psh, csh = serve_shardings(model, mesh, cfg)
    prefill = make_prefill(model)
    bsp = cfg.dp_axes if (cfg.batch % _dp(mesh, cfg.dp_axes) == 0
                          and cfg.batch >= _dp(mesh, cfg.dp_axes)) else None
    tok_sh = NamedSharding(mesh, P(bsp, None))
    ins = [psh, NamedSharding(mesh, P()), tok_sh, csh]
    if with_prefix:
        ins.append(tok_sh)          # (B, P, D): batch-sharded prefix
    return jax.jit(prefill, in_shardings=tuple(ins),
                   out_shardings=(tok_sh, csh), donate_argnums=(3,))


def jit_decode_step(model: lm_lib.LM, mesh: Mesh, cfg: ServeCfg):
    """Jit the one-token decode step with state-passing cache shardings."""
    _set_act_spec(model, mesh, cfg)
    psh, csh = serve_shardings(model, mesh, cfg)
    step = make_decode_step(model)
    tok_sh = NamedSharding(
        mesh, P(cfg.dp_axes if cfg.batch % max(
            1, _dp(mesh, cfg.dp_axes)) == 0 and cfg.batch >= _dp(
                mesh, cfg.dp_axes) else None, None))
    return jax.jit(
        step,
        in_shardings=(psh, NamedSharding(mesh, P()), tok_sh, csh, None),
        out_shardings=(tok_sh, csh),
        donate_argnums=(3,))


def _dp(mesh, dp_axes):
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------- mask sets
#
# Serving multiple ReLU budgets from ONE resident parameter set: every named
# mask set is stacked site-wise into a single device-resident array
# {site: (n_sets, *site_shape)}, and `select` hands back device slices with
# the exact shapes the jitted decode step was traced with.  Swapping budgets
# between decode steps is therefore a pure argument substitution — no
# re-jit, no host->device transfer, params untouched.


class MaskSetError(ValueError):
    """A mask set cannot be served: its site layout (names/shapes) does not
    match the model, or a checkpointed set failed fingerprint validation."""


@dataclasses.dataclass(frozen=True)
class MaskSetInfo:
    """Provenance + billing identity of one loaded mask set."""

    name: str
    relu_cost: int
    fingerprint: str
    source: str = "inline"


class MaskSetStore:
    """Named, device-resident mask sets over one model's site layout.

    Built from host mask trees (validated against ``site_shapes``), the
    store stacks every site across sets and keeps the stack on device;
    :meth:`select` returns per-set device views shaped exactly like a
    single mask tree, so the serving loop hot-swaps ReLU budgets between
    jitted decode steps without recompiling.
    """

    def __init__(self, site_shapes: Dict[str, Tuple[int, ...]],
                 sets: Dict[str, M.MaskTree],
                 sources: Optional[Dict[str, str]] = None):
        """Validate each set's layout against ``site_shapes`` and stack.

        ``site_shapes``: the model's mask-site layout (e.g. ``{k: s.shape
        for k, s in model.mask_sites().items()}``).  ``sets``: name -> host
        mask tree.  Raises :class:`MaskSetError` naming every missing /
        extra / mis-shaped site, so a checkpoint from a different model
        fails loudly instead of serving garbage.
        """
        if not sets:
            raise MaskSetError("MaskSetStore needs at least one mask set")
        self.site_shapes = dict(site_shapes)
        self._names = list(sets.keys())
        self._index = {n: i for i, n in enumerate(self._names)}
        self._infos: Dict[str, MaskSetInfo] = {}
        self._host: Dict[str, M.MaskTree] = {}
        sources = sources or {}
        for name, tree in sets.items():
            problems = validate_site_layout(site_shapes, tree)
            if problems:
                raise MaskSetError(
                    f"mask set {name!r} does not match the model's site "
                    f"layout: " + "; ".join(problems))
            host = {k: np.asarray(v, dtype=np.float32)
                    for k, v in tree.items()}
            self._host[name] = host
            self._infos[name] = MaskSetInfo(
                name=name, relu_cost=M.relu_cost(host),
                fingerprint=M.fingerprint(host),
                source=sources.get(name, "inline"))
        self._stacked = {
            k: jnp.asarray(np.stack([self._host[n][k]
                                     for n in self._names]))
            for k in sorted(site_shapes)}

    @property
    def names(self) -> Tuple[str, ...]:
        """Set names in insertion order."""
        return tuple(self._names)

    def select(self, name: str) -> Dict[str, jnp.ndarray]:
        """Device mask tree for ``name`` — slices of the resident stack."""
        i = self._index[name]
        return {k: v[i] for k, v in self._stacked.items()}

    def host(self, name: str) -> M.MaskTree:
        """Host (numpy) copy of the named set, for billing/inspection."""
        return {k: v.copy() for k, v in self._host[name].items()}

    def info(self, name: str) -> MaskSetInfo:
        """Provenance + billing identity of the named set."""
        return self._infos[name]

    def verify(self, name: str, observed: Optional[str] = None) -> str:
        """Re-fingerprint the named set against its load-time provenance.

        Recomputes the host tree's sha256 and compares it to the
        fingerprint recorded when the set entered the store; returns the
        verified fingerprint or raises :class:`MaskSetError` on mismatch
        (bit rot, device/host divergence — refuse to serve and bill a set
        whose identity cannot be proven).  ``observed`` substitutes the
        recomputed value — the serving tier's fault-injection surface
        (``launch.faults`` corrupts it to drill the retry/degrade path).
        """
        want = self._infos[name].fingerprint
        got = observed if observed is not None \
            else M.fingerprint(self._host[name])
        if got != want:
            raise MaskSetError(
                f"mask set {name!r} fails fingerprint verification: "
                f"provenance says {want[:12]}…, observed {got[:12]}… — "
                "refusing to serve it")
        return want

    def cheaper_sets(self, name: str) -> Tuple[str, ...]:
        """Stored set names strictly cheaper (fewer billable ReLUs) than
        ``name``, most expensive first — the natural degradation order."""
        cost = self._infos[name].relu_cost
        below = [n for n in self._names if self._infos[n].relu_cost < cost]
        return tuple(sorted(below, key=lambda n: -self._infos[n].relu_cost))

    def pi_cost_per_token(self, name: str,
                          proto: pi_cost.PIProtocol = pi_cost.PIProtocol()
                          ) -> pi_cost.PICost:
        """PI protocol cost of ONE token's forward under the named set."""
        return pi_cost.cost_of_masks(self._host[name],
                                     len(self.site_shapes), proto)

    @classmethod
    def from_run_dir(cls, run_dir: str,
                     site_shapes: Dict[str, Tuple[int, ...]],
                     names: Optional[Sequence[str]] = None
                     ) -> "MaskSetStore":
        """Load every completed sweep stage's ``final/`` masks as a set.

        ``run_dir`` is a :mod:`repro.launch.sweep` output directory; each
        ``stage_*_b<B>/final`` stage-init checkpoint becomes the set
        ``"b<B>"``.  Every loaded tree is re-fingerprinted and compared to
        the fingerprint recorded in the checkpoint manifest at save time —
        a mismatch (bit rot, wrong model, hand-edited files) raises
        :class:`MaskSetError` instead of silently serving the wrong budget.
        ``names`` optionally restricts which sets load.
        """
        from repro.core import runner as runner_lib
        stage_dirs = sorted(
            d for d in glob.glob(os.path.join(run_dir, "stage_*_b*"))
            if os.path.isdir(os.path.join(d, "final")))
        if not stage_dirs:
            raise MaskSetError(
                f"no completed sweep stages (stage_*_b*/final) under "
                f"{run_dir!r} — run launch.sweep first, or pass explicit "
                "mask sets")
        template = M.full_masks(site_shapes)
        sets: Dict[str, M.MaskTree] = {}
        sources: Dict[str, str] = {}
        for d in stage_dirs:
            m = re.search(r"_b(\d+)$", os.path.basename(d))
            name = f"b{m.group(1)}" if m else os.path.basename(d)
            if names is not None and name not in names:
                continue
            final = os.path.join(d, "final")
            try:
                init = runner_lib.load_stage_init(final, template,
                                                  masks_only=True)
            except runner_lib.CheckpointError as e:
                raise MaskSetError(
                    f"stage checkpoint {final!r} cannot be loaded as a "
                    f"mask set (its site layout likely mismatches this "
                    f"model's {sorted(site_shapes)}): {e}") from e
            masks = init["masks"]
            problems = validate_site_layout(site_shapes, masks)
            if problems:
                raise MaskSetError(
                    f"stage checkpoint {final!r} was saved for a different "
                    f"site layout than this model: " + "; ".join(problems))
            want = init.get("meta", {}).get("mask_fingerprint")
            got = M.fingerprint(masks)
            if want and got != want:
                raise MaskSetError(
                    f"mask set {name!r} from {final!r} fails fingerprint "
                    f"validation: manifest says {want[:12]}…, loaded tree "
                    f"hashes {got[:12]}… — refusing to serve it")
            sets[name] = masks
            sources[name] = final
        if names is not None:
            missing = [n for n in names if n not in sets]
            if missing:
                raise MaskSetError(
                    f"requested mask set(s) {missing} not found under "
                    f"{run_dir!r} (have: {sorted(sets)})")
        return cls(site_shapes, sets, sources)


def validate_site_layout(site_shapes: Dict[str, Tuple[int, ...]],
                         tree: M.MaskTree) -> list:
    """Human-readable mismatches between a mask tree and a site layout.

    Returns one string per problem (missing site, extra site, wrong shape)
    — empty list means the tree is servable on this model.
    """
    problems = []
    for k in sorted(set(site_shapes) - set(tree)):
        problems.append(f"missing site {k!r}")
    for k in sorted(set(tree) - set(site_shapes)):
        problems.append(f"unknown site {k!r}")
    for k in sorted(set(site_shapes) & set(tree)):
        want, got = tuple(site_shapes[k]), tuple(np.shape(tree[k]))
        if want != got:
            problems.append(f"site {k!r}: model wants {want}, set has {got}")
    return problems


# ------------------------------------------------------ slot cache surgery
#
# Prefill/decode disaggregation: prefill runs on a (1, P) batch with its own
# B=1 cache, then the result is scattered into one slot of the resident
# decode cache.  Stack-level cache leaves carry a leading repeats dim, so
# the batch axis is 1 there and 0 everywhere else (same rule as
# `_cache_specs`).


def _batch_axis(path) -> int:
    return 1 if any(getattr(p, "key", None) == "stack" for p in path) else 0


def make_insert_slot(model: lm_lib.LM):
    """Closure scattering a B=1 prefill cache into slot ``i`` of a decode
    cache: ``insert(big, small, i) -> big'``.  ``i`` is a traced argument,
    so one jit serves every slot."""
    del model   # the tree structure alone decides the batch axis

    def insert(big, small, i):
        def f(path, b, s):
            ax = _batch_axis(path)
            return jax.lax.dynamic_update_index_in_dim(
                b, jnp.take(s, 0, axis=ax).astype(b.dtype), i, ax)
        return jax.tree_util.tree_map_with_path(f, big, small)
    return insert


def read_slot_tokens(tokens, live: np.ndarray) -> np.ndarray:
    """Host view of a (B, 1) device token batch, ``-1`` where not live."""
    out = np.asarray(tokens).reshape(-1).copy()
    out[~live] = -1
    return out
