"""Serving: batched prefill + single-token decode with sharded caches.

``decode_32k`` / ``long_500k`` cells lower ``serve_step`` — one new token
against a KV cache (or SSM state) of the cell's seq_len.  Caches are jit
inputs AND outputs with identical shardings (state-passing style), batch over
DP axes; for long_500k (B=1) the KV-cache *sequence* axis shards over 'data'
(sequence parallelism — DESIGN §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm as lm_lib


@dataclasses.dataclass
class ServeCfg:
    """Serving shape/placement knobs (batch, cache length, DP axes)."""

    dp_axes: Tuple[str, ...] = ("data",)
    max_len: int = 32768
    batch: int = 128
    greedy: bool = True


def make_prefill(model: lm_lib.LM):
    """Prefill closure: (params, masks, tokens, cache) -> (last logits, cache)."""
    def prefill(params, masks, tokens, cache, prefix_embeds=None):
        logits, cache = model.forward(params, masks, tokens,
                                      prefix_embeds=prefix_embeds,
                                      cache=cache, cache_len=0)
        return logits[:, -1], cache
    return prefill


def make_decode_step(model: lm_lib.LM):
    """Greedy single-token decode closure over a running cache."""
    def decode_step(params, masks, token, cache, cache_len):
        """token (B,1) -> (next_token (B,1), cache)."""
        logits, cache = model.forward(params, masks, token, cache=cache,
                                      cache_len=cache_len)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache
    return decode_step


def serve_shardings(model: lm_lib.LM, mesh: Mesh, cfg: ServeCfg):
    """(param_shardings, cache_shardings) for jit in/out_shardings."""
    data = mesh.shape["data"]
    model_ax = mesh.shape["model"]
    dp_size = 1
    for a in cfg.dp_axes:
        dp_size *= mesh.shape[a]
    pshapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    pspec = lm_lib.param_specs(pshapes, data, model_ax, fsdp=False)
    cshapes = jax.eval_shape(
        lambda: model.init_cache(cfg.batch, cfg.max_len))
    cspec = _cache_specs(cshapes, cfg.dp_axes, dp_size, cfg.batch, data,
                         model_ax)
    to_sh = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    return to_sh(pspec), to_sh(cspec)


def _cache_specs(cache_shape, dp_axes, dp_size: int, B: int, data: int,
                 model_ax: int):
    """KV (B,S,KV,hd): batch over dp if divisible, else seq over 'data'
    (B==1 long-context); heads (or head_dim) over 'model' when divisible.
    SSM/RWKV states: batch over dp, heads over 'model'."""
    batch_ok = B % dp_size == 0 and B >= dp_size

    def f(path, leaf):
        # stack entries carry a leading repeats dim — spec it None
        stacked = any(getattr(p, "key", None) == "stack" for p in path)
        shape = leaf.shape[1:] if stacked else leaf.shape
        nd = len(shape)
        bspec = dp_axes if batch_ok else None
        if nd == 4 and shape[1] >= 1024:               # KV cache (B,S,KV,hd)
            seq = None if batch_ok else "data"
            kv_ok = shape[2] % model_ax == 0
            sp = P(bspec, seq, "model" if kv_ok else None,
                   "model" if (not kv_ok and shape[3] % model_ax == 0)
                   else None)
        elif nd == 4:                                  # ssm/rwkv state
            sp = P(bspec, "model" if shape[1] % model_ax == 0 else None,
                   None, None)
        elif nd == 3:                                  # conv state (B,dc-1,di)
            sp = P(bspec, None,
                   "model" if shape[2] % model_ax == 0 else None)
        elif nd == 2:                                  # prev-token (B,d)
            sp = P(bspec, "model" if shape[1] % model_ax == 0 else None)
        else:
            sp = P()
        return P(None, *sp) if stacked else sp
    return jax.tree_util.tree_map_with_path(f, cache_shape)


def _set_act_spec(model, mesh, cfg):
    dp = _dp(mesh, cfg.dp_axes)
    b = cfg.dp_axes if (cfg.batch % dp == 0 and cfg.batch >= dp) else None
    model.activation_spec = P(b, None, None)
    return b


def jit_prefill(model: lm_lib.LM, mesh: Mesh, cfg: ServeCfg,
                with_prefix: bool = False):
    """Jit the prefill step with production shardings (cache donated)."""
    _set_act_spec(model, mesh, cfg)
    psh, csh = serve_shardings(model, mesh, cfg)
    prefill = make_prefill(model)
    bsp = cfg.dp_axes if (cfg.batch % _dp(mesh, cfg.dp_axes) == 0
                          and cfg.batch >= _dp(mesh, cfg.dp_axes)) else None
    tok_sh = NamedSharding(mesh, P(bsp, None))
    ins = [psh, NamedSharding(mesh, P()), tok_sh, csh]
    if with_prefix:
        ins.append(tok_sh)          # (B, P, D): batch-sharded prefix
    return jax.jit(prefill, in_shardings=tuple(ins),
                   out_shardings=(tok_sh, csh), donate_argnums=(3,))


def jit_decode_step(model: lm_lib.LM, mesh: Mesh, cfg: ServeCfg):
    """Jit the one-token decode step with state-passing cache shardings."""
    _set_act_spec(model, mesh, cfg)
    psh, csh = serve_shardings(model, mesh, cfg)
    step = make_decode_step(model)
    tok_sh = NamedSharding(
        mesh, P(cfg.dp_axes if cfg.batch % max(
            1, _dp(mesh, cfg.dp_axes)) == 0 and cfg.batch >= _dp(
                mesh, cfg.dp_axes) else None, None))
    return jax.jit(
        step,
        in_shardings=(psh, NamedSharding(mesh, P()), tok_sh, csh, None),
        out_shardings=(tok_sh, csh),
        donate_argnums=(3,))


def _dp(mesh, dp_axes):
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n
