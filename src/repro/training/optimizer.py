"""Minimal optax-free optimizers: SGD(+momentum) and AdamW, cosine schedule.

API (optax-like):
    opt = sgd(lr=1e-3, momentum=0.9, schedule=cosine(1e-3, steps))
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def cosine(base_lr: float, total_steps: int, min_lr: float = 0.0):
    """Cosine annealing (Loshchilov & Hutter) — the paper's finetune schedule."""
    def sched(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        return min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
    return sched


def constant(lr: float):
    """Constant learning-rate schedule."""
    return lambda step: jnp.asarray(lr)


class OptState(NamedTuple):
    """Shared optimizer state (AdamW uses both moments, SGD only mu)."""

    step: jnp.ndarray
    mu: object        # momentum / first moment (pytree or None-like zeros)
    nu: object        # second moment (AdamW only; zeros tree for SGD)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """An (init, update) pair — the optax-style contract."""

    init: Callable
    update: Callable   # (grads, state, params) -> (updates, new_state)


def _zeros_like_tree(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(lr: float = 1e-3, momentum: float = 0.9,
        schedule: Optional[Callable] = None,
        weight_decay: float = 0.0, grad_clip: Optional[float] = None):
    """SGD with momentum, optional decoupled weight decay and grad clip."""
    sched = schedule or constant(lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_tree(params),
                        jnp.zeros(()))

    def update(grads, state, params):
        grads = _clip(grads, grad_clip)
        lr_t = sched(state.step)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        def upd(m, p):
            u = -lr_t * m
            if weight_decay:
                u = u - lr_t * weight_decay * p
            return u.astype(p.dtype)
        updates = jax.tree.map(upd, mu, params)
        return updates, OptState(state.step + 1, mu, state.nu)

    return Optimizer(init, update)


def adamw(lr: float = 3.5e-5, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          schedule: Optional[Callable] = None,
          grad_clip: Optional[float] = None):
    """AdamW (decoupled weight decay) with bias correction."""
    sched = schedule or constant(lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_tree(params),
                        _zeros_like_tree(params))

    def update(grads, state, params):
        grads = _clip(grads, grad_clip)
        step = state.step + 1
        lr_t = sched(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) *
                          jnp.square(g.astype(n.dtype)), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        def upd(m, n, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p
            return u.astype(p.dtype)
        updates = jax.tree.map(upd, mu, nu, params)
        return updates, OptState(step, mu, nu)

    return Optimizer(init, update)


def _clip(grads, max_norm):
    if not max_norm:
        return grads
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def apply_updates(params, updates):
    """Apply additive updates leaf-wise (optax-style)."""
    return jax.tree.map(lambda p, u: p + u, params, updates)
