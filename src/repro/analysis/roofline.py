"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:
  compute    = HLO_FLOPs_global / (chips × peak_FLOP/s)
  memory     = HLO_bytes_global / (chips × HBM_bw)
  collective = collective_bytes_global / (chips × link_bw)

``cost_analysis()`` yields per-device FLOPs/bytes of the partitioned module
(global = ×chips).  collective_bytes is parsed from the partitioned HLO text:
for each all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we take the op's local tensor bytes, apply the standard
ring-model factor, and multiply by participants to get global bytes moved.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e per-chip constants (per assignment)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

def xla_cost(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` normalized across jax versions.

    Newer jax returns the flat properties dict directly; older versions wrap
    it in a one-element list (one dict per partition).  Returns {} when the
    backend offers no cost analysis.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<ty>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _bytes_of(ty: str, shape: str) -> int:
    n = 1
    for d in shape.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    bytes_moved_global: float = 0.0
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    in_loop_count: int = 0


_WHILE_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COMP_DEF_RE = re.compile(r"^([\w.\-]+)\s*[(]")


def _while_body_names(hlo_text: str) -> set:
    return set(_WHILE_BODY_RE.findall(hlo_text))


def parse_collectives(hlo_text: str, n_devices: int,
                      loop_trip_count: int = 1) -> CollectiveStats:
    """Sum collective bytes.  XLA cost/HLO text counts a while-loop body ONCE;
    collectives that live inside a while body (the layer scan, fwd and bwd)
    are multiplied by ``loop_trip_count`` (= n_repeats of the scanned stack).
    """
    bodies = _while_body_names(hlo_text)
    stats = CollectiveStats()
    current_comp = None
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") and ("{" in ls) and ("->" in ls) \
                and not ls.startswith("%param"):
            m = _COMP_DEF_RE.match(ls.lstrip("%"))
            if m:
                current_comp = m.group(1)
        elif ls.startswith(("ENTRY", "HloModule")):
            current_comp = None
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        mult = loop_trip_count if (current_comp in bodies) else 1
        if mult > 1:
            stats.in_loop_count += 1
        if m.group("ty"):
            local = _bytes_of(m.group("ty"), m.group("shape"))
        else:  # tuple result: sum elements
            paren = line.split("=", 1)[1]
            local = sum(_bytes_of(t, s)
                        for t, s in _TUPLE_ELEM_RE.findall(
                            paren.split("(", 1)[0]))
        n = max(2, _group_size(line, n_devices))
        ring = (n - 1) / n
        if op == "all-reduce":
            moved = 2 * local * ring          # reduce-scatter + all-gather
        elif op == "collective-permute":
            moved = local
        else:                                  # ag / rs / a2a
            moved = local * ring
        stats.bytes_moved_global += moved * n * mult
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) \
            + moved * n * mult
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float        # raw XLA cost_analysis (body-once!)
    bytes_per_device: float        # raw XLA cost_analysis (body-once!)
    collective_bytes_global: float
    model_flops_global: float
    analytic_flops_global: float = 0.0   # loop-corrected (preferred)
    analytic_bytes_global: float = 0.0
    bytes_per_device_peak: Optional[float] = None   # memory_analysis

    @property
    def t_compute(self):
        if self.analytic_flops_global:
            return self.analytic_flops_global / (self.chips * PEAK_FLOPS)
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self):
        if self.analytic_bytes_global:
            return self.analytic_bytes_global / (self.chips * HBM_BW)
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self):
        return self.collective_bytes_global / (self.chips * LINK_BW)

    @property
    def bottleneck(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self):
        return self.model_flops_global / max(self.hlo_flops_global, 1.0)

    @property
    def roofline_fraction(self):
        """Fraction of the hardware roof actually doing model math:
        (MODEL_FLOPS / chips / peak) / max(term) — 1.0 = perfect."""
        t_model = self.model_flops_global / (self.chips * PEAK_FLOPS)
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / max(t_dom, 1e-30)

    @property
    def hlo_flops_global(self):
        return self.analytic_flops_global or \
            self.flops_per_device * self.chips

    def row(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_global,
            "hlo_flops_global": self.hlo_flops_global,
            "xla_flops_global_raw": self.flops_per_device * self.chips,
            "xla_bytes_global_raw": self.bytes_per_device * self.chips,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, mode: str) -> float:
    """6·N_active·D (train: ×3 fwd+bwd via the standard 6ND; inference: 2ND)."""
    n_active = active_params(cfg)
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def block_fwd_flops(cfg, blk, new_tokens: float, ctx: float,
                    mode: str = "prefill"):
    """Analytic forward cost of ONE block: (flops, weight_bytes,
    decode_cache_bytes).

    The per-block term :func:`analytic_cell` sums over the whole stack;
    exposed separately so per-layer *fractions* (the suffix cost model's
    prefix_fraction — models' ``site_prefix_fractions``) share the same
    arithmetic.  ``new_tokens`` is batch×new positions, ``ctx`` the
    attention context length.
    """
    d = cfg.d_model
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    k = blk.kind
    cache_bytes = 0.0
    if k in ("dense", "moe", "attn_only"):
        f_attn_proj = 2 * new_tokens * d * (H + 2 * KV) * hd \
            + 2 * new_tokens * H * hd * d
        kv_len = min(ctx, blk.window or ctx)
        if mode == "decode":
            f_sc = 2 * new_tokens * H * hd * kv_len * 2
        else:
            # causal: average key span ~ kv_len/2 (full) or window
            span = (ctx / 2) if blk.window is None else \
                min(blk.window, ctx / 2)
            f_sc = 2 * new_tokens * H * hd * span * 2
        f = f_attn_proj + f_sc
        wb = (d * (H + 2 * KV) * hd + H * hd * d) * 2
        if mode == "decode":
            cache_bytes += new_tokens * kv_len * KV * hd * 2 * 2
        if k == "dense":
            nf = 3 if cfg.gated_ffn else 2
            f += 2 * new_tokens * d * cfg.d_ff * nf
            wb += d * cfg.d_ff * nf * 2
        elif k == "moe":
            cap = cfg.top_k * cfg.capacity_factor
            f += 2 * new_tokens * d * cfg.n_experts          # router
            f += 2 * new_tokens * cap * 3 * d * cfg.d_ff_expert
            wb += 3 * cfg.n_experts * d * cfg.d_ff_expert * 2
            if cfg.n_shared_experts:
                f += 2 * new_tokens * 3 * d * cfg.d_ff_shared
                wb += 3 * d * cfg.d_ff_shared * 2
    elif k == "mamba":
        di = cfg.d_inner
        nh = di // cfg.mamba_head_dim
        N, mh = cfg.ssm_state, cfg.mamba_head_dim
        chunk = 64 if mode != "decode" else 1
        f = 2 * new_tokens * d * 2 * di \
            + 2 * new_tokens * d * (2 * N + nh) \
            + 2 * new_tokens * di * d \
            + 4 * new_tokens * di  # conv
        # chunked SSD: scores (chunk·N) + y (chunk·mh) + state (2·N·mh)
        f += 2 * new_tokens * nh * (chunk * N + chunk * mh + 2 * N * mh)
        wb = (d * 2 * di + d * (2 * N + nh) + di * d) * 2
        if mode == "decode":
            cache_bytes += new_tokens * nh * N * mh * 4
    elif k == "rwkv":
        f_ff = cfg.d_ff
        rh = cfg.rwkv_head_dim
        Hr = d // rh
        chunk = 32 if mode != "decode" else 1
        f = 2 * new_tokens * d * d * 6 \
            + 2 * new_tokens * d * f_ff * 2 + 2 * new_tokens * d * d
        f += 2 * new_tokens * Hr * (chunk * rh * 2 + 2 * rh * rh)
        wb = (7 * d * d + 2 * d * f_ff) * 2
        if mode == "decode":
            cache_bytes += new_tokens * Hr * rh * rh * 4
    else:
        raise ValueError(k)
    return f, wb, cache_bytes


def moe_capacity_slots(cfg, seq: int) -> int:
    """Per-expert slot count of the sort-based MoE dispatch.

    Mirrors ``models.moe._capacity``: decode (seq == 1) is exact — one slot
    per expert — and everything else rounds up to a multiple of 8 with a
    floor of 8.  The expert einsums compute ALL ``E·C`` slots whether or
    not tokens fill them, so segment-level costing must use this padded
    figure, not the analytic ``top_k·capacity_factor`` per-token average.
    """
    if seq == 1:
        return 1
    cap = int(seq * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-cap // 8) * 8)


def lm_segment_fwd_flops(cfg, *, seq_len: int) -> list:
    """Per-segment forward FLOPs of the unified LM (per-sample, prefill):
    ``[embed, head…, stack repeat 0 … R-1, tail…, logits]``.

    The scanned stack contributes one entry PER REPEAT — the per-repeat
    prefix cuts in ``models.lm`` need per-repeat fractions, and every
    repeat runs the identical pattern so the entries are equal.  MoE
    blocks are corrected from :func:`block_fwd_flops`'s analytic
    ``top_k·capacity_factor`` average to the dispatch's true padded slot
    capacity (:func:`moe_capacity_slots`): the expert einsums pay for
    every ``E·C`` slot, filled or not.
    """
    def f(blk):
        fl = block_fwd_flops(cfg, blk, seq_len, seq_len, "prefill")[0]
        if blk.kind == "moe":
            analytic = seq_len * cfg.top_k * cfg.capacity_factor
            slots = cfg.n_experts * moe_capacity_slots(cfg, seq_len)
            fl += 2 * max(slots - analytic, 0.0) * 3 * cfg.d_model \
                * cfg.d_ff_expert
        return fl
    rep = sum(f(b) for b in cfg.pattern)
    return ([0.0] + [f(b) for b in cfg.head_blocks]
            + [rep] * cfg.n_repeats
            + [f(b) for b in cfg.tail]
            + [2.0 * seq_len * cfg.d_model * cfg.vocab])


def _iter_bench_history(path):
    """Yield parsed BENCH_history.jsonl entries, skipping malformed lines
    (the file is append-only across heterogeneous tool versions)."""
    import json
    import os
    if not os.path.exists(path):
        return
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(entry, dict):
                yield entry


@dataclasses.dataclass(frozen=True)
class SuffixCostModel:
    """Per-site decision: suffix-mode (prefix once + vmapped suffix) vs the
    full-forward backends, for a chunk of ``n`` candidates cutting at a
    site with ``prefix_fraction`` f of forward FLOPs above it.

    Per-chunk cost ratio:  suffix / full = ((f - c) + (1 - f)·n) / n, where
    ``c`` is the prefix fraction already resident in the evaluator's trie
    (``covered``) — always <1 for n > 1 even cold, so the analytic *model*
    says "always suffix"; the thresholds price what it can't see: a shallow
    cut's win (f·(n-1) forwards) is smaller than its fixed overheads (one
    extra jit per segment, the cached-acts residency, per-chunk plan/slice
    work), so those sites fall back to the full path (``use_suffix() ==
    False`` -> the evaluator's inner batched/sharded/pipelined backend
    evaluates the chunk).

    ``measured`` switches the decision from the analytic threshold to
    observed hardware behavior: a tuple of ``(prefix_fraction, speedup,
    chunk)`` points calibrated from ``BENCH_history.jsonl``
    (:meth:`calibrated` — EWMA per site over matching config fingerprints,
    with the analytic ratio as the cold-start prior via an implicit
    ``(0.0, 1.0)`` anchor).  Suffix mode then runs wherever the
    interpolated measured speedup clears ``min_speedup``; the 5% margin
    absorbs dispatch overheads the FLOPs ratio can't see.
    """

    min_prefix_fraction: float = 0.05   # below this the reuse is noise
    min_chunk: int = 2                  # n=1 reuses nothing
    min_speedup: float = 1.05           # measured-mode margin over full path
    measured: Optional[Tuple[Tuple[float, float, int], ...]] = None

    def speedup(self, prefix_fraction: float, n: int,
                covered: float = 0.0) -> float:
        """Predicted candidates/sec gain of suffix mode for one chunk;
        ``covered`` discounts prefix work already cached in the trie."""
        f = min(max(prefix_fraction, 0.0), 1.0)
        c = min(max(covered, 0.0), f)
        return n / max((f - c) + (1.0 - f) * n, 1e-9)

    def predicted_speedup(self, prefix_fraction: float, n: int,
                          covered: float = 0.0) -> float:
        """Measured-mode estimate: linear interpolation over the calibrated
        ``(frac, speedup)`` points — anchored at (0, 1): zero prefix means
        zero reuse — rescaled by the analytic ratio to the requested chunk
        size and trie coverage (measurements are cold-trie, per-config
        chunk)."""
        if not self.measured:
            return self.speedup(prefix_fraction, n, covered)
        f = min(max(prefix_fraction, 0.0), 1.0)
        pts = sorted(((0.0, 1.0, n),) + tuple(self.measured))
        hi = next((p for p in pts if p[0] >= f), None)
        lo = next((p for p in reversed(pts) if p[0] <= f), pts[0])
        if hi is None:
            base = lo
        elif hi[0] == lo[0]:
            base = hi
        else:
            w = (f - lo[0]) / (hi[0] - lo[0])
            base = (f, lo[1] + w * (hi[1] - lo[1]),
                    int(round(lo[2] + w * (hi[2] - lo[2]))) or n)
        n0 = max(int(base[2]), 1)
        scale = self.speedup(f, n, covered) / max(self.speedup(f, n0), 1e-9)
        return base[1] * scale

    def use_suffix(self, prefix_fraction: float, n: int,
                   covered: float = 0.0) -> bool:
        if n < self.min_chunk:
            return False
        if self.measured:
            return (self.predicted_speedup(prefix_fraction, n, covered)
                    >= self.min_speedup)
        return prefix_fraction >= self.min_prefix_fraction

    @classmethod
    def calibrated(cls, history_path, *, fingerprint: Optional[dict] = None,
                   alpha: float = 0.5, **kwargs) -> "SuffixCostModel":
        """Calibrate from ``BENCH_history.jsonl``'s per-depth measurements.

        Walks the history oldest-first, EWMA-folding (weight ``alpha`` on
        the newer sample) each site's measured suffix-vs-batched speedup —
        only rows the evaluator actually ran in suffix mode (``mode ==
        "suffix"``), and only entries whose config matches ``fingerprint``
        on every key the entry carries (model / device / eval-batch changes
        must not pollute each other's rates).  Legacy history lines without
        ``per_site_depth`` are skipped, so an empty or pre-measurement file
        degrades to the pure analytic model (``measured=None``)."""
        ewma: dict = {}
        for entry in _iter_bench_history(history_path):
            cfg = entry.get("config") or {}
            if fingerprint and any(k in cfg and cfg[k] != v
                                   for k, v in fingerprint.items()):
                continue
            rows = entry.get("per_site_depth")
            if not isinstance(rows, dict):
                continue
            chunk = int(cfg.get("chunk_size") or 0)
            for row in rows.values():
                if not isinstance(row, dict) or row.get("mode") != "suffix":
                    continue
                try:
                    site = row["site"]
                    frac = float(row["prefix_fraction"])
                    sp = float(row["speedup_suffix_vs_batched"])
                except (KeyError, TypeError, ValueError):
                    continue
                prev = ewma.get(site)
                if prev is not None:
                    sp = (1 - alpha) * prev[1] + alpha * sp
                    chunk = chunk or prev[2]
                ewma[site] = (frac, sp, chunk)
        measured = tuple(sorted((f, s, max(c, 1)) for f, s, c in
                                ewma.values())) or None
        return cls(measured=measured, **kwargs)


def analytic_cell(cfg, shape, mode: str, *, remat: bool = True):
    """Analytic (HLO-faithful) FLOPs and HBM bytes for one cell, GLOBAL.

    Needed because XLA's cost_analysis counts a while-loop (layer-scan) body
    ONCE — it undercounts scanned stacks by ~n_repeats× (validated against an
    unrolled small model in tests/test_roofline.py).  Counts matmul FLOPs as
    2mnk, attention with the causal 1/2 factor, MoE at capacity (the real
    dispatched compute incl. padding waste), and the chunked linear-attention
    intra-chunk matmuls for mamba/rwkv (block_fwd_flops owns the per-block
    arithmetic).

    Bytes model (per step, global): weights read (fwd + bwd + remat re-fwd for
    train) + optimizer state r/w (train) + activation stream traffic
    (c·tokens·d per layer) + logits/CE traffic + cache reads (decode).
    """
    B, S = shape.global_batch, shape.seq_len
    d, V = cfg.d_model, cfg.vocab
    if mode == "decode":
        new_tokens, ctx = B * 1, S
    else:
        new_tokens, ctx = B * S, S
    kinds = ([b for b in cfg.head_blocks]
             + [b for b in cfg.pattern] * cfg.n_repeats
             + list(cfg.tail))

    f_layer = 0.0       # forward flops for all layers, per step (global)
    w_bytes = 0.0       # weight bytes (bf16), all layers
    cache_bytes = 0.0   # decode-state bytes read per step
    for blk in kinds:
        f, wb, cb = block_fwd_flops(cfg, blk, new_tokens, ctx, mode)
        f_layer += f
        w_bytes += wb
        cache_bytes += cb

    f_logits = 2 * new_tokens * d * V
    w_bytes += V * d * 2
    fwd = f_layer + f_logits

    if mode == "train":
        flops = fwd * (4 if remat else 3)          # fwd + re-fwd + 2×bwd
        # bytes: weights ×(2 fwd reads incl remat + 2 bwd) + grads + adam f32
        nparams = w_bytes / 2
        opt_bytes = nparams * (4 + 8 + 8 + 4 + 4)  # grad w + m/v rw + p rw
        act_bytes = 8 * new_tokens * d * len(kinds) * 2
        logit_bytes = 3 * new_tokens * V * 4   # f32 logits + CE fwd/bwd
        hbm = w_bytes * 3 + opt_bytes + act_bytes + logit_bytes
    else:
        flops = fwd
        act_bytes = 4 * new_tokens * d * len(kinds) * 2
        hbm = w_bytes + act_bytes + cache_bytes \
            + new_tokens * V * 2
    return flops, hbm


def active_params(cfg) -> float:
    """Parameter count with only top_k routed experts counted (MoE)."""
    d, f, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    total = V * d  # embed (tied head)
    kinds = ([b.kind for b in cfg.head_blocks]
             + [b.kind for b in cfg.pattern] * cfg.n_repeats
             + [b.kind for b in cfg.tail])
    attn_p = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d
    ffn_p = d * f * (3 if cfg.gated_ffn else 2)
    moe_p = (cfg.top_k * 3 * d * cfg.d_ff_expert + d * cfg.n_experts
             + (3 * d * cfg.d_ff_shared if cfg.n_shared_experts else 0))
    di = cfg.d_inner
    mamba_p = d * 2 * di + d * (2 * cfg.ssm_state) + di * d
    rwkv_p = 6 * d * d + 2 * d * f
    per = {"dense": attn_p + ffn_p, "moe": attn_p + moe_p,
           "attn_only": attn_p, "mamba": mamba_p, "rwkv": rwkv_p}
    total += sum(per[k] for k in kinds)
    return float(total)
