"""Data pipeline: deterministic synthetic datasets + sharded host loading.

CIFAR-10/100/TinyImageNet are not available offline, so the image pipeline
generates *class-conditional* synthetic images (fixed per-class pattern +
noise) with the exact shapes/cardinalities of the real datasets — learnable,
deterministic, and dependency-free (DESIGN §7).  The token pipeline emits a
second-order Markov stream so LM training loss demonstrably decreases.

All loaders are process-sharded: ``host_slice`` cuts the global batch by
(process_index, process_count), the standard multi-host JAX pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import numpy as np


def host_slice(global_batch: int, process_index=None, process_count=None):
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    assert global_batch % pc == 0, (global_batch, pc)
    per = global_batch // pc
    return slice(pi * per, (pi + 1) * per)


# ------------------------------------------------------------ images


@dataclasses.dataclass(frozen=True)
class ImageDatasetCfg:
    n_classes: int = 10
    image_size: int = 32
    n_train: int = 2048            # synthetic stand-in sizes (fast CPU loops)
    n_test: int = 512
    noise: float = 0.35
    seed: int = 0

    @staticmethod
    def cifar10(**kw):
        return ImageDatasetCfg(n_classes=10, image_size=32, **kw)

    @staticmethod
    def cifar100(**kw):
        return ImageDatasetCfg(n_classes=100, image_size=32, **kw)

    @staticmethod
    def tiny_imagenet(**kw):
        return ImageDatasetCfg(n_classes=200, image_size=64, **kw)


class SyntheticImages:
    """Class-conditional synthetic images: per-class low-frequency pattern
    + per-sample noise.  Deterministic in (cfg.seed, split)."""

    def __init__(self, cfg: ImageDatasetCfg):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        s = cfg.image_size
        # per-class pattern: smooth random field (sum of a few sinusoids)
        xx, yy = np.meshgrid(np.linspace(0, 1, s), np.linspace(0, 1, s))
        pats = []
        for c in range(cfg.n_classes):
            f = rng.uniform(1, 4, size=(3, 2))
            ph = rng.uniform(0, 2 * np.pi, size=(3, 2))
            a = rng.normal(size=(3,))
            pat = sum(a[i] * np.sin(2 * np.pi * (f[i, 0] * xx + f[i, 1] * yy)
                                    + ph[i, 0]) for i in range(3))
            pats.append(np.stack([pat, np.roll(pat, s // 3, 0),
                                  np.roll(pat, s // 3, 1)], -1))
        self.patterns = np.stack(pats).astype(np.float32)  # (C, s, s, 3)
        self.train = self._split(cfg.n_train, 1)
        self.test = self._split(cfg.n_test, 2)

    def _split(self, n, salt):
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1000 + salt)
        labels = rng.integers(0, cfg.n_classes, size=n)
        imgs = self.patterns[labels] + \
            rng.normal(size=(n, cfg.image_size, cfg.image_size, 3)
                       ).astype(np.float32) * cfg.noise
        return imgs.astype(np.float32), labels.astype(np.int32)

    def batches(self, split: str, batch: int, seed: int = 0):
        """step -> dict(images, labels); deterministic per step."""
        imgs, labels = self.train if split == "train" else self.test
        n = len(labels)

        def get(step: int) -> Dict[str, np.ndarray]:
            rng = np.random.default_rng(seed * 100003 + step)
            idx = rng.integers(0, n, size=batch)
            return {"images": imgs[idx], "labels": labels[idx]}
        return get

    def eval_set(self, max_n: int = 512):
        imgs, labels = self.test
        return {"images": imgs[:max_n], "labels": labels[:max_n]}

    def train_eval_set(self, max_n: int = 512):
        """The paper evaluates BCD candidates on D_train (a fixed subsample
        here — DESIGN §7)."""
        imgs, labels = self.train
        return {"images": imgs[:max_n], "labels": labels[:max_n]}


# ------------------------------------------------------------ tokens


class MarkovTokens:
    """Second-order Markov token stream (learnable synthetic LM data)."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branching = min(branching, vocab)
        # each (prev token) maps to a small set of likely successors
        self.table = rng.integers(0, vocab, size=(vocab, self.branching))

    def batch(self, batch: int, seq: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(step * 7919 + 13)
        toks = np.empty((batch, seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            choice = rng.integers(0, self.branching, size=batch)
            nxt = self.table[toks[:, t], choice]
            flip = rng.random(batch) < 0.05      # 5% noise
            nxt = np.where(flip, rng.integers(0, self.vocab, batch), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
