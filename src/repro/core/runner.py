"""Resumable BCD run orchestration (crash-safe Alg. 2).

``run_bcd`` is fire-and-forget: a multi-hour descent that dies mid-run loses
everything.  :class:`BCDRunner` drives the same step-granular loop
(:func:`core.bcd.bcd_steps`) but persists the full run state through
``training.checkpoint`` after every accepted block:

    masks          the current iterate (the only thing Alg. 2 mutates)
    params         the caller's finetuned model params (via ``params_io``)
    rng state      the numpy bit-generator state, so the candidate stream
                   continues exactly where it stopped
    step / logs    outer-step index + full history (JSON, in manifest meta)

Checkpoints are atomic (tmp dir + rename) and checksummed; restore takes the
*newest valid* checkpoint, skipping a partially-written or corrupted one from
the crash itself.  Because ``bcd_steps`` carries no hidden state beyond
``BCDState``, a resumed run replays bit-identically against an uninterrupted
one — same selected blocks, same logs (``wall_s`` excepted).

The same checkpoint layout doubles as the *stage-init* warm-start format
(:func:`save_stage_init` / :func:`load_stage_init`) shared by
``SNLResult.stage_init()`` / ``AutoRepResult.stage_init()`` and by completed
sweep stages — the glue ``launch.sweep`` uses to descend a budget schedule
from an SNL or AutoReP reference checkpoint.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
from typing import Callable, Optional, Tuple

import numpy as np

from repro.training import checkpoint
from . import bcd as bcd_lib
from . import masks as M

CheckpointError = checkpoint.CheckpointError

# Testing/CI hook: SIGKILL this process after N accepted blocks have been
# checkpointed (process-wide count, across sweep stages).  A real kill -9 —
# no atexit, no flushing — so the resume path is exercised against the same
# failure mode a preempted node produces.
KILL_ENV = "REPRO_KILL_AFTER_STEPS"
_accepted_in_process = 0


def _maybe_kill_for_test() -> None:
    global _accepted_in_process
    limit = os.environ.get(KILL_ENV)
    if not limit:
        return
    _accepted_in_process += 1
    if _accepted_in_process >= int(limit):
        os.kill(os.getpid(), signal.SIGKILL)


# ------------------------------------------------------------ rng round-trip


def rng_state_to_jsonable(rng: np.random.Generator) -> dict:
    """A numpy Generator's full position as JSON-able data (Python ints are
    arbitrary precision, so the 128-bit PCG64 state serializes losslessly)."""
    return rng.bit_generator.state


def rng_from_state(state: dict) -> np.random.Generator:
    """Inverse of :func:`rng_state_to_jsonable`: a Generator that continues
    the stream bit-identically from the recorded position."""
    rng = np.random.default_rng(0)
    if state["bit_generator"] != type(rng.bit_generator).__name__:
        raise CheckpointError(
            f"checkpointed rng is a {state['bit_generator']}, this numpy "
            f"builds {type(rng.bit_generator).__name__} — refusing a "
            "stream that cannot replay bit-identically")
    rng.bit_generator.state = state
    return rng


# ------------------------------------------------------------ run persistence


def _cfg_meta(cfg: bcd_lib.BCDConfig) -> dict:
    # normalize through JSON so the saved manifest (which stores JSON) and
    # the live config compare equal — e.g. cfg.moves is a tuple in memory
    # but a list on disk
    return json.loads(json.dumps(dataclasses.asdict(cfg)))


def save_run_state(state: bcd_lib.BCDState, cfg: bcd_lib.BCDConfig,
                   ckpt_dir: str, *, params=None, keep: int = 3,
                   coordinator=None) -> str:
    """Checkpoint a run after ``state.step`` accepted blocks (atomic).

    The full step history rides in every manifest (cumulative write cost
    O(steps²) over a run) — a deliberate trade for single-checkpoint
    restores: at ~150 bytes/entry the manifest stays well under a megabyte
    for thousand-step runs, dwarfed by the params leaves.  Revisit with an
    append-only sidecar if manifests ever dominate checkpoint I/O.

    ``coordinator`` stamps the writer's identity into the manifest meta
    (audit trail for the single-lineage invariant) and makes
    ``checkpoint.save`` refuse a non-writer caller outright.
    """
    tree = {"masks": state.masks}
    if params is not None:
        tree["params"] = params
    meta = {
        "algo": "bcd",
        "step": state.step,
        "b_ref": state.b_ref,
        "rng": rng_state_to_jsonable(state.rng),
        "history": [dataclasses.asdict(h) for h in state.history],
        "cfg": _cfg_meta(cfg),
        "move_stats": state.move_stats,
        "has_params": params is not None,
    }
    if coordinator is not None:
        meta["writer"] = coordinator.describe()
    return checkpoint.save(tree, ckpt_dir, state.step, meta=meta, keep=keep,
                           coordinator=coordinator)


def restore_run_state(
    ckpt_dir: str,
    cfg: bcd_lib.BCDConfig,
    masks_template: M.MaskTree,
    *,
    params_template=None,
    step: Optional[int] = None,
    verify: Optional[bool] = None,
) -> Tuple[bcd_lib.BCDState, object]:
    """Rebuild a :class:`BCDState` (+ params) from the newest valid
    checkpoint.  Refuses a checkpoint written under a different BCD config:
    resuming a run under a changed schedule/seed cannot replay
    bit-identically, which is the whole contract.

    ``verify`` defaults to hashing every leaf when ``step`` is explicit and
    skipping the re-hash when this function picked the step itself (in that
    case ``latest_valid_step`` just deep-validated it); callers that already
    deep-validated an explicit step pass ``verify=False``.
    """
    if verify is None:
        verify = step is not None
    if step is None:
        step = checkpoint.latest_valid_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoints in {ckpt_dir}")
    meta = checkpoint.read_manifest(ckpt_dir, step).get("meta", {})
    if meta.get("algo") != "bcd":
        raise CheckpointError(
            f"checkpoint step {step} in {ckpt_dir} is not a BCD run state "
            f"(algo={meta.get('algo')!r})")
    saved_cfg = meta.get("cfg", {})
    now_cfg = _cfg_meta(cfg)
    diffs = {k: (saved_cfg.get(k), now_cfg[k]) for k in now_cfg
             if saved_cfg.get(k) != now_cfg[k]}
    if diffs:
        raise CheckpointError(
            "refusing to resume under a different BCDConfig (bit-identical "
            f"replay impossible); changed fields: {diffs}")
    template = {"masks": masks_template}
    if meta.get("has_params"):
        if params_template is None:
            raise CheckpointError(
                "checkpoint carries params but no params_template was "
                "given for the restore")
        template["params"] = params_template
    tree, _ = checkpoint.restore(template, ckpt_dir, step, verify=verify)
    masks = {k: np.asarray(v, dtype=np.float32)
             for k, v in tree["masks"].items()}
    history = [bcd_lib.BCDStepLog(**h) for h in meta.get("history", [])]
    state = bcd_lib.BCDState(
        masks=masks, rng=rng_from_state(meta["rng"]),
        step=int(meta["step"]), b_ref=int(meta["b_ref"]),
        history=history, snapshots=[],
        move_stats=meta.get("move_stats", {}))
    return state, tree.get("params")


# ------------------------------------------------------------ stage-init I/O

_STAGE_INIT_STEP = 0


def save_stage_init(path: str, init: dict, *, meta: Optional[dict] = None
                    ) -> str:
    """Persist a warm-start checkpoint in the shared stage-init layout.

    ``init`` is ``{kind, masks, params, aux}`` — what
    ``SNLResult.stage_init()`` / ``AutoRepResult.stage_init()`` return, and
    what every completed sweep stage writes for its successor.  ``aux``
    (soft alphas, poly coefficients, ...) is persisted but optional on load:
    restore reads only the leaves its template asks for.
    """
    tree = {"masks": init["masks"]}
    if init.get("params") is not None:
        tree["params"] = init["params"]
    if init.get("aux"):
        tree["aux"] = init["aux"]
    info = {
        "stage_init": True,
        "kind": init.get("kind", "unknown"),
        "budget": M.relu_cost(init["masks"]),
        "mask_fingerprint": M.fingerprint(init["masks"]),
        "has_params": init.get("params") is not None,
    }
    info.update(meta or {})
    return checkpoint.save(tree, path, _STAGE_INIT_STEP, meta=info, keep=1)


def load_stage_init(path: str, masks_template: M.MaskTree, *,
                    params_template=None, aux_template=None,
                    masks_only: bool = False) -> dict:
    """Load a stage-init checkpoint back into ``{kind, masks, params, aux}``.
    Raises :class:`CheckpointError` when absent/corrupted — callers decide
    whether that means "first run" or "fatal".  ``masks_only=True`` restores
    just the mask leaves even when the checkpoint carries params (the
    serving tier loads budgets, not weights)."""
    if not checkpoint.validate(path, _STAGE_INIT_STEP, deep=True):
        raise CheckpointError(f"no valid stage-init checkpoint at {path}")
    meta = checkpoint.read_manifest(path, _STAGE_INIT_STEP).get("meta", {})
    if not meta.get("stage_init"):
        raise CheckpointError(f"checkpoint at {path} is not a stage init")
    template = {"masks": masks_template}
    if meta.get("has_params") and not masks_only:
        if params_template is None:
            raise CheckpointError(
                f"stage init at {path} carries params but no "
                "params_template was given")
        template["params"] = params_template
    if aux_template is not None:
        template["aux"] = aux_template
    # validate(deep=True) above already hashed every leaf
    tree, _ = checkpoint.restore(template, path, _STAGE_INIT_STEP,
                                 verify=False)
    masks = {k: np.asarray(v, dtype=np.float32)
             for k, v in tree["masks"].items()}
    return {"kind": meta.get("kind", "unknown"), "masks": masks,
            "params": tree.get("params"), "aux": tree.get("aux"),
            "meta": meta}


def stage_init_exists(path: str) -> bool:
    return checkpoint.validate(path, _STAGE_INIT_STEP, deep=True)


# ------------------------------------------------------------------ runner


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    checkpoint_every: int = 1     # accepted blocks between checkpoints
    keep: int = 3                 # retained checkpoints (gc'd oldest-first)
    max_steps: Optional[int] = None   # stop (not fail) after N accepted
    #                                   blocks this invocation — preemption
    #                                   drills and budgeted partial runs
    wait_timeout_s: float = 300.0     # reader ranks: max wait for the
    #                                   writer's checkpoint before declaring
    #                                   the writer dead (multi-host only)
    verbose: bool = False


class BCDRunner:
    """Checkpointed, resumable ``run_bcd``.

    ``params_io`` is an optional ``(get_params, set_params)`` pair: when the
    run finetunes between steps, the current params are part of the resume
    state, and the runner snapshots them with every checkpoint and pushes
    restored params back through ``set_params`` before the loop restarts
    (the caller's ``set_params`` must also refresh any evaluator context —
    exactly like its finetune callback does).

    ``run()`` resumes automatically from the newest valid checkpoint in
    ``cfg.ckpt_dir``; a corrupted newest checkpoint falls back to the one
    before it (the replayed steps re-select the same blocks, so the result
    is unchanged — crash-consistency by determinism, not by fsync).

    ``coordinator`` (a :mod:`repro.launch.coordinator` object; None means
    single-process) makes the runner multi-host safe: every rank executes
    the same deterministic loop, but only the writer rank commits
    checkpoints — reader ranks block on ``checkpoint.wait_for_step`` at each
    checkpoint point, so no rank runs ahead of durable state.  On restore,
    all ranks barrier, the writer picks the resume step and broadcasts it
    with the checkpoint's manifest fingerprint, and every rank restores that
    exact step and verifies the fingerprint — a rank on a divergent
    checkpoint lineage fails loudly instead of silently descending a
    different trajectory.
    """

    def __init__(
        self,
        bcd_cfg: bcd_lib.BCDConfig,
        run_cfg: RunnerConfig,
        eval_acc: Callable[[M.MaskTree], float],
        finetune: Optional[Callable[[M.MaskTree], None]] = None,
        *,
        evaluator=None,
        params_io: Optional[Tuple[Callable[[], object],
                                  Callable[[object], None]]] = None,
        coordinator=None,
    ):
        bcd_cfg.validate()
        self.bcd_cfg = bcd_cfg
        self.run_cfg = run_cfg
        self._eval_acc = eval_acc
        self._finetune = finetune
        self._evaluator = evaluator
        self._params_io = params_io
        self._coord = coordinator
        self.resumed_from: Optional[int] = None   # step, for observability
        self.stopped_early = False                # hit run_cfg.max_steps

    @property
    def _is_writer(self) -> bool:
        return self._coord is None or self._coord.is_writer

    def _resume_point(self) -> Optional[dict]:
        """Agree on the resume step across ranks (single-process: local).

        Returns ``{"step", "fingerprint"}`` or None for a fresh start.  All
        ranks barrier first so nobody inspects the directory while a
        previous attempt's writer could still be mid-commit.
        """
        coord = self._coord
        if coord is None or coord.world_size == 1:
            step = checkpoint.latest_valid_step(self.run_cfg.ckpt_dir)
            if step is None:
                return None
            return {"step": step, "fingerprint": None}
        coord.barrier("bcd_restore")
        if coord.is_writer:
            step = checkpoint.latest_valid_step(self.run_cfg.ckpt_dir)
            fp = (checkpoint.manifest_fingerprint(self.run_cfg.ckpt_dir,
                                                  step)
                  if step is not None else None)
            return coord.broadcast("bcd_resume_point",
                                   {"step": step, "fingerprint": fp})
        return coord.broadcast("bcd_resume_point")

    def _restore_or_init(self, init_masks: M.MaskTree) -> bcd_lib.BCDState:
        point = self._resume_point()
        if point is None or point["step"] is None:
            return bcd_lib.init_state(init_masks, self.bcd_cfg)
        step = point["step"]
        if point["fingerprint"] is not None:
            mine = checkpoint.manifest_fingerprint(self.run_cfg.ckpt_dir,
                                                   step)
            if mine != point["fingerprint"]:
                rank = self._coord.rank if self._coord else 0
                raise CheckpointError(
                    f"rank {rank} sees manifest fingerprint {mine[:12]} at "
                    f"step {step}, writer broadcast "
                    f"{point['fingerprint'][:12]} — divergent checkpoint "
                    "lineages; refusing to resume")
        params_template = self._params_io[0]() if self._params_io else None
        # reader ranks must hash what they read (they did not run the
        # writer's latest_valid_step validation); the rank that picked the
        # step — single-process or the writer — just deep-validated it
        picked_locally = (self._coord is None
                          or self._coord.world_size == 1
                          or self._coord.is_writer)
        state, params = restore_run_state(
            self.run_cfg.ckpt_dir, self.bcd_cfg, init_masks,
            params_template=params_template, step=step,
            verify=not picked_locally)
        if params is not None and self._params_io is not None:
            self._params_io[1](params)
        if self._coord is not None and self._coord.world_size > 1:
            # nobody advances (and the writer commits nothing — its keep=N
            # GC could delete the very step a slower reader is still
            # reading) until every rank finished restoring
            self._coord.barrier("bcd_restored")
        self.resumed_from = state.step
        if self.run_cfg.verbose:
            print(f"[runner] resumed {self.run_cfg.ckpt_dir} at step "
                  f"{state.step} (budget {M.relu_cost(state.masks)})")
        return state

    def _checkpoint(self, state: bcd_lib.BCDState) -> None:
        if self._is_writer:
            params = self._params_io[0]() if self._params_io else None
            save_run_state(state, self.bcd_cfg, self.run_cfg.ckpt_dir,
                           params=params, keep=self.run_cfg.keep,
                           coordinator=self._coord)
        else:
            # readers advance only once the writer's commit is durable —
            # no rank ever runs ahead of restorable state
            checkpoint.wait_for_step(self.run_cfg.ckpt_dir, state.step,
                                     timeout_s=self.run_cfg.wait_timeout_s)
        _maybe_kill_for_test()

    def run(self, init_masks: M.MaskTree) -> bcd_lib.BCDResult:
        """Run (or resume) to completion; returns the usual BCDResult.

        With ``max_steps`` set, the loop may stop before reaching b_target:
        ``stopped_early`` is True and the returned result holds the partial
        state (budget check is skipped — the next invocation picks up the
        checkpoint).
        """
        state = self._restore_or_init(init_masks)
        self.stopped_early = False
        if self.bcd_cfg.b_target >= state.b_ref:
            return bcd_lib.BCDResult(state.masks, state.history, [],
                                     state.move_stats)
        done_now = 0
        since_ckpt = 0
        for _log in bcd_lib.bcd_steps(
                state, self.bcd_cfg, self._eval_acc, self._finetune,
                evaluator=self._evaluator, verbose=self.run_cfg.verbose):
            done_now += 1
            since_ckpt += 1
            if since_ckpt >= self.run_cfg.checkpoint_every:
                self._checkpoint(state)
                since_ckpt = 0
            if self.run_cfg.max_steps is not None and \
                    done_now >= self.run_cfg.max_steps and \
                    M.relu_cost(state.masks) > self.bcd_cfg.b_target:
                self.stopped_early = True
                break
        if since_ckpt:
            self._checkpoint(state)
        if not self.stopped_early:
            bcd_lib.check_reached_target(state, self.bcd_cfg)
        return bcd_lib.BCDResult(state.masks, state.history, state.snapshots,
                                 state.move_stats)
