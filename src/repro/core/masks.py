"""ReLU/activation mask pytrees.

A *mask tree* is a dict mapping a mask-site name (e.g. ``"layer3.relu2"`` for
CNNs or ``"blocks.ffn"`` for a scanned transformer stack) to a float32 array of
zeros/ones.  ``1.0`` keeps the nonlinearity at that coordinate, ``0.0``
linearizes it (identity or poly2 replacement — see core.linearize).

Masks are deliberately small (one scalar per activation *site*, shared across
the batch, matching the paper's per-pixel masks) so they are replicated across
the mesh and updated host-side between jitted evaluations.  All sampling /
counting helpers here are numpy-based host code: BCD mutates masks a few times
per outer iteration, never inside a jitted step.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re

import numpy as np
import jax.numpy as jnp
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

MaskTree = Dict[str, np.ndarray]

# Mask coordinate values.  Binary masks use {0.0, 1.0}; `share` moves
# introduce a third *tied* state: the coordinate keeps its nonlinearity but
# reuses the sign decision of its driver (the previous coordinate along the
# site's last axis — see core.linearize.apply_masked_act), so it does not
# pay for its own garbled-circuit comparison.  TIE sits strictly inside
# (0.5, 0.9): `count` (> 0.5) still sees it as nonlinear, `relu_cost`
# (> 0.9) does not bill it.
TIE = 0.75


def as_device(masks: MaskTree) -> Dict[str, jnp.ndarray]:
    """Move a host mask tree onto device as float32 jnp arrays."""
    return {k: jnp.asarray(v, dtype=jnp.float32) for k, v in masks.items()}


def full_masks(shapes: Dict[str, Tuple[int, ...]]) -> MaskTree:
    """All-ones masks (every nonlinearity kept) for the given site shapes."""
    return {k: np.ones(s, dtype=np.float32) for k, s in shapes.items()}


def count(masks: MaskTree) -> int:
    """||m||_0 — coordinates that keep *a* nonlinearity (full or tied)."""
    return int(sum(int(np.sum(v > 0.5)) for v in masks.values()))


def relu_cost(masks: MaskTree) -> int:
    """Billable ReLU count: coordinates that pay for their own comparison.

    This is the budget metric Alg. 2 descends (core.bcd) and the quantity
    the PI cost model charges for (core.pi_cost): share-tied coordinates
    (value :data:`TIE`) keep their gate but reuse the driver's comparison,
    so they are excluded.  Equal to :func:`count` on binary trees."""
    return int(sum(int(np.sum(v > 0.9)) for v in masks.values()))


def tied_count(masks: MaskTree) -> int:
    """Coordinates in the share-tied state (``0.5 < m <= 0.9``)."""
    return count(masks) - relu_cost(masks)


def total_size(masks: MaskTree) -> int:
    return int(sum(v.size for v in masks.values()))


def _flatten(masks: MaskTree) -> Tuple[np.ndarray, list]:
    """Concatenate all masks into one flat vector + per-site layout info."""
    keys = sorted(masks.keys())
    flat = np.concatenate([masks[k].reshape(-1) for k in keys])
    layout = []
    off = 0
    for k in keys:
        n = masks[k].size
        layout.append((k, off, n, masks[k].shape))
        off += n
    return flat, layout


def _unflatten(flat: np.ndarray, layout: list) -> MaskTree:
    out = {}
    for k, off, n, shape in layout:
        out[k] = flat[off:off + n].reshape(shape).astype(np.float32)
    return out


def active_indices(masks: MaskTree) -> Tuple[np.ndarray, list]:
    flat, layout = _flatten(masks)
    return np.nonzero(flat > 0.5)[0], layout


def sample_removal_block(
    rng: np.random.Generator, masks: MaskTree, drc: int
) -> MaskTree:
    """Sample a block of ``drc`` currently-active coordinates (Alg. 2 line 8).

    Returns a *candidate* mask tree: ``masks`` with the sampled block zeroed.
    If fewer than ``drc`` coordinates are active, zeroes all of them.
    """
    flat, layout = _flatten(masks)
    active = np.nonzero(flat > 0.5)[0]
    k = min(drc, active.size)
    chosen = rng.choice(active, size=k, replace=False)
    new_flat = flat.copy()
    new_flat[chosen] = 0.0
    return _unflatten(new_flat, layout)


# ------------------------------------------------------------ stacked trees
#
# A *stacked* mask tree carries ``n`` candidate trees along a leading axis:
# ``{site: (n, *site_shape)}``.  The batched/sharded evaluators (core.engine)
# consume stacked trees whole — one jitted vmap call evaluates all n
# candidates — so every helper here must index/slice consistently across
# sites.  Sampling is split into *index* sampling (tiny: (n, drc) ints) and
# *materialization* (per-chunk, so RT full-size candidate trees never live in
# host memory at once).


def sample_removal_indices(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int
) -> np.ndarray:
    """Sample ``n`` independent removal blocks as flat-coordinate indices.

    Row ``i`` is bit-identical to the ``rng.choice`` draw the ``i``-th
    sequential :func:`sample_removal_block` call would make from the same
    generator state — the engine relies on this for backend equivalence.
    Returns an (n, k) int array, k = min(drc, #active).
    """
    active, _ = active_indices(masks)
    k = min(drc, active.size)
    return np.stack([rng.choice(active, size=k, replace=False)
                     for _ in range(n)]) if n else \
        np.zeros((0, k), dtype=np.int64)


def materialize_from_flat(flat: np.ndarray, layout: list,
                          indices: np.ndarray) -> MaskTree:
    """Stacked candidate tree from a pre-flattened base mask.

    The hot path: BCD flattens the base tree once per outer step and
    materializes each chunk from (flat, layout) without re-concatenating
    the whole tree per chunk."""
    n = indices.shape[0]
    stacked = np.broadcast_to(flat, (n, flat.size)).copy()
    np.put_along_axis(stacked, indices, 0.0, axis=1)
    return unflatten_stacked(stacked, layout)


def materialize_candidates(masks: MaskTree, indices: np.ndarray) -> MaskTree:
    """Build the stacked candidate tree for (n, k) removal ``indices``."""
    flat, layout = _flatten(masks)
    return materialize_from_flat(flat, layout, indices)


def chunk_bounds(n: int, chunk_size: int) -> list:
    """[(start, stop)] chunk boundaries covering ``n`` candidates."""
    return [(s, min(s + chunk_size, n)) for s in range(0, n, chunk_size)]


def coalesce_fallback_chunks(chunks: list, chunk_size: int) -> list:
    """Merge runs of adjacent fallback chunks in a sited plan.

    ``chunks``: ``[(site | None, start, stop)]`` with contiguous ascending
    bounds (``plan_sited_chunks`` raw output).  Sited chunks pass through
    untouched — they must never straddle a prefix group.  Consecutive
    ``site is None`` chunks carry no shared-prefix constraint (the inner
    pipeline runs each candidate's full forward), so their spans are merged
    and re-split at ``chunk_size``: a depth mix that fragments into many
    small per-group fallback tails then costs ceil(total/chunk) dispatches
    instead of one ragged dispatch per group."""
    out: list = []
    run_start = run_stop = None
    for site, s, e in chunks:
        if site is None:
            if run_stop == s:
                run_stop = e
            else:
                if run_start is not None:
                    out.extend((None, run_start + cs, run_start + ce)
                               for cs, ce in chunk_bounds(
                                   run_stop - run_start, chunk_size))
                run_start, run_stop = s, e
            continue
        if run_start is not None:
            out.extend((None, run_start + cs, run_start + ce)
                       for cs, ce in chunk_bounds(run_stop - run_start,
                                                  chunk_size))
            run_start = run_stop = None
        out.append((site, s, e))
    if run_start is not None:
        out.extend((None, run_start + cs, run_start + ce)
                   for cs, ce in chunk_bounds(run_stop - run_start,
                                              chunk_size))
    return out


def materialize_chunks(flat: np.ndarray, layout: list, indices: np.ndarray,
                       chunk_size: int):
    """Lazy chunk producer for the trial loop: yields one stacked candidate
    tree per :func:`chunk_bounds` chunk of ``indices``.

    Laziness is load-bearing twice over — the prefetch pipeline
    (core.engine.evaluate_prefetched) pulls chunk k+1's materialization
    while chunk k computes on device, and an ADT early exit closes the
    generator so chunks past the staging horizon are never built."""
    for start, stop in chunk_bounds(indices.shape[0], chunk_size):
        yield materialize_from_flat(flat, layout, indices[start:stop])


def _repeat_row_sizes(layout: list,
                      repeat_sites: Optional[Dict[str, int]]) -> np.ndarray:
    """Per-layout-entry repeat-row size for repeat-aware rank resolution.

    A site in ``repeat_sites`` spans R consecutive per-repeat ranks from its
    base rank, with its flat coordinates laid out repeat-major — so a
    coordinate's rank offset is ``local_offset // (size // R)``.  Sites not
    listed have one repeat: row size = site size, offset always 0."""
    return np.array([sz // int((repeat_sites or {}).get(k, 1))
                     for k, _, sz, _ in layout], dtype=np.int64)


def group_blocks_by_site(indices: np.ndarray, layout: list,
                         rank_of_site: Dict[str, int],
                         repeat_sites: Optional[Dict[str, int]] = None):
    """Group candidate removal blocks by their *earliest* touched site rank.

    ``indices``: (n, k) flat removal coordinates (``sample_removal_indices``
    output); ``layout``: the matching ``_flatten`` layout; ``rank_of_site``:
    site name -> group rank — pass the model's segment indices so candidates
    that share a forward prefix land in the same group (the prefix-reuse
    engine's chunking contract: chunks never straddle a group).

    ``repeat_sites`` (site -> R) marks scanned-stack sites whose (R, ·)
    mask spans R consecutive per-repeat segments starting at the site's
    base rank: a coordinate's effective rank is then
    ``rank_of_site[site] + local_offset // (size // R)``, so candidates
    editing only deep repeats group at their true (deeper) cut instead of
    the whole-stack one.

    Returns ``(order, groups)``: ``order`` is an (n,) permutation of
    candidate positions sorted by group rank (stable, so sampling order
    survives within a group), and ``groups`` is ``[(rank, start, stop)]``
    bounds into ``order``.
    """
    n = indices.shape[0]
    if n == 0 or indices.size == 0:
        return np.arange(n, dtype=np.int64), \
            ([] if n == 0 else [(0, 0, n)])
    offs = np.array([off for _, off, _, _ in layout], dtype=np.int64)
    ranks = np.array([rank_of_site[k] for k, _, _, _ in layout],
                     dtype=np.int64)
    flat = indices.reshape(-1)
    site_of = np.searchsorted(offs, flat, side="right") - 1
    coord_rank = ranks[site_of]
    if repeat_sites:
        row_sz = _repeat_row_sizes(layout, repeat_sites)
        coord_rank = coord_rank + (flat - offs[site_of]) // row_sz[site_of]
    cand_rank = coord_rank.reshape(indices.shape).min(axis=1)
    return _group_by_rank(cand_rank)


def _group_by_rank(cand_rank: np.ndarray):
    """Stable-sort candidate positions by rank -> (order, groups) in the
    :func:`group_blocks_by_site` contract (shared with the move-aware
    grouping :func:`group_moves_by_site`)."""
    n = cand_rank.shape[0]
    order = np.argsort(cand_rank, kind="stable").astype(np.int64)
    sorted_ranks = cand_rank[order]
    cuts = np.flatnonzero(np.diff(sorted_ranks)) + 1
    bounds = [0, *cuts.tolist(), n]
    groups = [(int(sorted_ranks[s]), s, e)
              for s, e in zip(bounds[:-1], bounds[1:])]
    return order, groups


# ------------------------------------------------------------ typed moves
#
# The paper's Alg. 2 samples one move type only — "zero a block of drc
# active coordinates".  The move vocabulary below generalizes a candidate to
# a typed edit of the flat mask vector while keeping the engine's contracts
# intact: every sampled move changes the *billable* budget (`relu_cost`) by
# exactly -drc, so the outer schedule (core.bcd.total_steps /
# check_reached_target) is untouched, and all sampling happens up front so
# the rng burns a deterministic number of draws per candidate regardless of
# evaluation order or early exit.

MOVE_KINDS = ("remove", "add_back", "swap", "stage_drop", "share")
PROPOSALS = ("uniform", "sensitivity")


def _as_coords(a) -> np.ndarray:
    return np.asarray(a, dtype=np.int64).reshape(-1)


@dataclasses.dataclass(frozen=True)
class Move:
    """One typed candidate edit over flat mask coordinates.

    ``off`` coordinates are set to 0.0 (linearized), ``on`` to 1.0
    (re-activated), ``tie`` to :data:`TIE` (share-tied to the previous
    coordinate on the site's last axis).  The three sets must be disjoint;
    application order is irrelevant.  ``kind`` is a label for stats /
    logging — semantics live entirely in the coordinate sets, which is what
    makes the move algebra checkable: ``swap(off, on)`` applies identically
    to ``add_back(on) ∘ remove(off)``.
    """
    kind: str
    off: np.ndarray = dataclasses.field(default_factory=lambda: _as_coords([]))
    on: np.ndarray = dataclasses.field(default_factory=lambda: _as_coords([]))
    tie: np.ndarray = dataclasses.field(default_factory=lambda: _as_coords([]))

    def __post_init__(self):
        object.__setattr__(self, "off", _as_coords(self.off))
        object.__setattr__(self, "on", _as_coords(self.on))
        object.__setattr__(self, "tie", _as_coords(self.tie))
        sets = [set(self.off.tolist()), set(self.on.tolist()),
                set(self.tie.tolist())]
        total = len(sets[0]) + len(sets[1]) + len(sets[2])
        if len(sets[0] | sets[1] | sets[2]) != total:
            raise ValueError(
                f"move coordinate sets must be disjoint (kind={self.kind}, "
                f"off={self.off}, on={self.on}, tie={self.tie})")

    # ---- constructors (the algebra the property tests exercise)

    @staticmethod
    def remove(off) -> "Move":
        return Move("remove", off=off)

    @staticmethod
    def add_back(on, off=()) -> "Move":
        return Move("add_back", off=off, on=on)

    @staticmethod
    def swap(off, on) -> "Move":
        return Move("swap", off=off, on=on)

    @staticmethod
    def stage_drop(off) -> "Move":
        return Move("stage_drop", off=off)

    @staticmethod
    def share(tie, off=()) -> "Move":
        return Move("share", off=off, tie=tie)

    def touched(self) -> np.ndarray:
        """All flat coordinates this move edits (off ∪ on ∪ tie)."""
        return np.concatenate([self.off, self.on, self.tie])

    def apply_flat(self, flat: np.ndarray) -> np.ndarray:
        out = flat.copy()
        out[self.off] = 0.0
        out[self.on] = 1.0
        out[self.tie] = TIE
        return out

    def billable_delta(self, flat: np.ndarray) -> int:
        """Change in :func:`relu_cost` if applied to ``flat``."""
        before = int(np.sum(flat > 0.9))
        return int(np.sum(self.apply_flat(flat) > 0.9)) - before


def apply_move(masks: MaskTree, move: Move) -> MaskTree:
    """``masks`` with ``move`` applied (input untouched)."""
    flat, layout = _flatten(masks)
    return _unflatten(move.apply_flat(flat), layout)


def move_sites(move: Move, layout: list) -> Tuple[str, ...]:
    """Sorted site names a move touches (for per-site acceptance stats)."""
    coords = move.touched()
    if coords.size == 0:
        return ()
    offs = np.array([off for _, off, _, _ in layout], dtype=np.int64)
    keys = [k for k, _, _, _ in layout]
    site_of = np.searchsorted(offs, coords, side="right") - 1
    return tuple(sorted({keys[int(i)] for i in site_of}))


_STAGE_RE = re.compile(r"^(g\d+)b\d+")


def default_stage_of(site: str) -> str:
    """Model-agnostic site -> stage key for ``stage_drop`` macro-moves.

    ResNet block sites (``g{stage}b{block}.relu{i}``, models.resnet) map to
    their stage (``g0b1.relu2 -> g0``); everything else maps to its
    top-level prefix (``stem.relu -> stem``, ``blocks.ffn -> blocks``).
    Pass an explicit ``stage_of`` to :func:`sample_moves` to override."""
    m = _STAGE_RE.match(site)
    return m.group(1) if m else site.split(".", 1)[0]


def _kind_weights(kinds: Sequence[str], proposal: str,
                  move_stats: Optional[dict]) -> np.ndarray:
    """Proposal distribution over move kinds.

    ``uniform``: equal mass.  ``sensitivity``: Laplace-smoothed acceptance
    rate per kind from the run's history (Learning-to-Linearize-style
    guidance) — a pure function of ``move_stats``, which round-trips
    through checkpoints, so resumed runs replay the same draws."""
    if proposal != "sensitivity" or not move_stats:
        return np.full(len(kinds), 1.0 / len(kinds))
    ks = move_stats.get("kinds", {})
    w = np.array([(ks.get(k, {}).get("accepted", 0) + 1.0)
                  / (ks.get(k, {}).get("proposed", 0) + 2.0) for k in kinds],
                 dtype=np.float64)
    return w / w.sum()


def _site_coord_weights(flat: np.ndarray, layout: list, coords: np.ndarray,
                        move_stats: Optional[dict]) -> Optional[np.ndarray]:
    """Per-coordinate sampling weights from per-site acceptance history
    (``sensitivity`` proposal): a coordinate inherits its site's smoothed
    acceptance rate.  None -> uniform (no history yet)."""
    site_stats = (move_stats or {}).get("sites", {})
    if not site_stats or coords.size == 0:
        return None
    offs = np.array([off for _, off, _, _ in layout], dtype=np.int64)
    keys = [k for k, _, _, _ in layout]
    w_site = np.array(
        [(site_stats.get(k, {}).get("accepted", 0) + 1.0)
         / (site_stats.get(k, {}).get("proposed", 0) + 2.0) for k in keys],
        dtype=np.float64)
    p = w_site[np.searchsorted(offs, coords, side="right") - 1]
    return p / p.sum()


def _choice(rng, pool: np.ndarray, k: int, p=None) -> np.ndarray:
    if k <= 0:
        return _as_coords([])
    return _as_coords(rng.choice(pool, size=k, replace=False, p=p)) \
        if p is not None else \
        _as_coords(rng.choice(pool, size=k, replace=False))


def _sample_one_move(rng, flat, layout, drc, kind, proposal, move_stats,
                     stage_of, max_remove) -> Move:
    """Sample one candidate of the given kind (net billable change -drc).

    Kinds that cannot be realized in the current mask state (no inactive
    coordinate to add back, no share-eligible coordinate, ...) degrade to a
    plain removal so the candidate still advances the schedule.  ``remove``
    with the default uniform proposal burns exactly the legacy
    ``rng.choice(active, size=k, replace=False)`` draw — bit-identical to
    :func:`sample_removal_indices` — so ``moves=("remove",)`` configs
    replay historical runs unchanged."""
    active = np.nonzero(flat > 0.9)[0]
    k = min(drc, active.size)

    if kind == "remove":
        p = _site_coord_weights(flat, layout, active, move_stats) \
            if proposal == "sensitivity" else None
        return Move.remove(_choice(rng, active, k, p))

    if kind == "add_back":
        inactive = np.nonzero(flat <= 0.5)[0]
        # re-activate `a`, remove k + a: net -k.  Shrink a when there is
        # nothing to revive or too few actives to pay for the revival.
        a = min(1, inactive.size, max(0, active.size - k))
        if a == 0:
            return Move.remove(_choice(rng, active, k))
        on = _choice(rng, inactive, a)
        return Move.add_back(on, off=_choice(rng, active, k + a))

    if kind == "swap":
        # exchange one (off, on) pair inside a single site, plus k rider
        # removals that keep the step's budget schedule
        offs_l = {key: (off, n) for key, off, n, _ in layout}
        eligible = [key for key, off, n, _ in layout
                    if np.any(flat[off:off + n] > 0.9)
                    and np.any(flat[off:off + n] <= 0.5)]
        if not eligible or active.size <= k:
            return Move.remove(_choice(rng, active, k))
        site = eligible[int(rng.integers(len(eligible)))]
        off0, n0 = offs_l[site]
        local = flat[off0:off0 + n0]
        on = _choice(rng, np.nonzero(local <= 0.5)[0] + off0, 1)
        off_sw = _choice(rng, np.nonzero(local > 0.9)[0] + off0, 1)
        rest = np.setdiff1d(active, off_sw, assume_unique=True)
        return Move.swap(np.concatenate([off_sw, _choice(rng, rest, k)]), on)

    if kind == "stage_drop":
        # DeepReDuce-style macro-move: remove a whole stage's remaining
        # actives (never overshooting b_target, never under drc)
        stage_of = stage_of or default_stage_of
        cap = k if max_remove is None else max(k, min(int(max_remove),
                                                      active.size))
        stages: Dict[str, list] = {}
        for key, off, n, _ in layout:
            hot = np.nonzero(flat[off:off + n] > 0.9)[0] + off
            if hot.size:
                stages.setdefault(stage_of(key), []).append(hot)
        names = sorted(stages)
        if not names:
            return Move.remove(_choice(rng, active, k))
        st = names[int(rng.integers(len(names)))]
        pool = np.concatenate(stages[st])
        take = min(pool.size, cap)
        off = pool if take == pool.size else _choice(rng, pool, take)
        if take < k:            # tiny stage: top up to the schedule's drc
            rest = np.setdiff1d(active, off, assume_unique=True)
            off = np.concatenate([off, _choice(rng, rest, k - take)])
        return Move.stage_drop(off)

    if kind == "share":
        eligible = share_eligible(flat, layout)
        perm = _as_coords(rng.permutation(eligible)) if eligible.size \
            else eligible
        chosen: list = []
        taken = set()
        for idx in perm.tolist():
            if len(chosen) >= k:
                break
            if idx - 1 in taken or idx + 1 in taken:
                continue        # the driver must stay a full ReLU
            chosen.append(idx)
            taken.add(idx)
        tie = _as_coords(chosen)
        if tie.size < k:        # not enough tie sites: top up with removals
            drivers = tie - 1
            pool = np.setdiff1d(active, np.concatenate([tie, drivers]),
                                assume_unique=False)
            if k - tie.size > pool.size:
                # cannot reach -drc with ties + removals (end-of-schedule
                # corner): a plain removal always can
                return Move.remove(_choice(rng, active, k))
            return Move.share(tie, off=_choice(rng, pool, k - tie.size))
        return Move.share(tie)

    raise ValueError(f"unknown move kind {kind!r}; expected one of "
                     f"{MOVE_KINDS}")


def share_eligible(flat: np.ndarray, layout: list) -> np.ndarray:
    """Flat coordinates a ``share`` move may tie: billable actives whose
    driver — the previous coordinate along the site's last axis — exists
    (no wraparound) and is itself a billable active."""
    out = []
    for _, off, n, shape in layout:
        local = flat[off:off + n] > 0.9
        last = shape[-1] if shape else 1
        pos = np.arange(n) % last
        ok = local & (pos > 0)
        ok[1:] &= local[:-1]
        ok[:1] = False
        out.append(np.nonzero(ok)[0] + off)
    return np.concatenate(out) if out else _as_coords([])


def sample_moves(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int, *,
    kinds: Sequence[str] = ("remove",), proposal: str = "uniform",
    move_stats: Optional[dict] = None, stage_of=None,
    max_remove: Optional[int] = None,
) -> List[Move]:
    """Sample ``n`` independent typed candidates (Alg. 2 line 8, typed).

    Every candidate nets exactly ``-drc`` billable ReLUs (``stage_drop``
    may remove more, capped by ``max_remove`` — pass ``budget - b_target``
    so macro-moves never overshoot the schedule).  With the default
    ``kinds=("remove",)`` and ``proposal="uniform"`` the rng stream is
    bit-identical to :func:`sample_removal_indices`: no kind draw is made
    and each candidate burns one ``rng.choice`` over the active set.
    """
    for kind in kinds:
        if kind not in MOVE_KINDS:
            raise ValueError(f"unknown move kind {kind!r}; expected a "
                             f"subset of {MOVE_KINDS}")
    if proposal not in PROPOSALS:
        raise ValueError(f"unknown proposal {proposal!r}; expected one of "
                         f"{PROPOSALS}")
    flat, layout = _flatten(masks)
    weights = _kind_weights(kinds, proposal, move_stats) \
        if len(kinds) > 1 else None
    moves = []
    for _ in range(n):
        kind = kinds[0] if weights is None else \
            kinds[int(rng.choice(len(kinds), p=weights))]
        moves.append(_sample_one_move(rng, flat, layout, drc, kind,
                                      proposal, move_stats, stage_of,
                                      max_remove))
    return moves


def materialize_moves_from_flat(flat: np.ndarray, layout: list,
                                moves: Sequence[Move]) -> MaskTree:
    """Stacked candidate tree for typed moves (the move-aware counterpart
    of :func:`materialize_from_flat` — candidate ``i`` is
    ``moves[i].apply_flat(flat)``)."""
    n = len(moves)
    stacked = np.broadcast_to(flat, (n, flat.size)).copy()
    for i, mv in enumerate(moves):
        stacked[i, mv.off] = 0.0
        stacked[i, mv.on] = 1.0
        stacked[i, mv.tie] = TIE
    return unflatten_stacked(stacked, layout)


def materialize_move_chunks(flat: np.ndarray, layout: list,
                            moves: Sequence[Move], chunk_size: int):
    """Lazy chunk producer over typed moves (same laziness contract as
    :func:`materialize_chunks`: the prefetch pipeline pulls it, an ADT
    early exit closes it)."""
    for start, stop in chunk_bounds(len(moves), chunk_size):
        yield materialize_moves_from_flat(flat, layout, moves[start:stop])


def move_site_ranks(moves: Sequence[Move], layout: list,
                    rank_of_site: Dict[str, int],
                    repeat_sites: Optional[Dict[str, int]] = None
                    ) -> np.ndarray:
    """Each move's earliest-touched-site rank over off ∪ on ∪ tie.

    Multi-site moves (swap/share/add_back) are grouped by the *shallowest*
    site they edit: a cached forward prefix is only valid if it reads no
    edited mask, so the cut must sit at or above every touched coordinate.
    ``repeat_sites`` resolves scanned-stack coordinates to their per-repeat
    rank (same contract as :func:`group_blocks_by_site`)."""
    offs = np.array([off for _, off, _, _ in layout], dtype=np.int64)
    ranks = np.array([rank_of_site[k] for k, _, _, _ in layout],
                     dtype=np.int64)
    row_sz = _repeat_row_sizes(layout, repeat_sites) if repeat_sites else None
    out = np.empty(len(moves), dtype=np.int64)
    for i, mv in enumerate(moves):
        coords = mv.touched()
        if not coords.size:
            out[i] = int(ranks.min())
            continue
        site_of = np.searchsorted(offs, coords, side="right") - 1
        r = ranks[site_of]
        if row_sz is not None:
            r = r + (coords - offs[site_of]) // row_sz[site_of]
        out[i] = int(r.min())
    return out


def group_moves_by_site(moves: Sequence[Move], layout: list,
                        rank_of_site: Dict[str, int],
                        repeat_sites: Optional[Dict[str, int]] = None):
    """:func:`group_blocks_by_site` for typed moves: group by the earliest
    touched site over off ∪ on ∪ tie (same ``(order, groups)`` and
    ``repeat_sites`` contract)."""
    n = len(moves)
    if n == 0:
        return np.arange(0, dtype=np.int64), []
    return _group_by_rank(
        move_site_ranks(moves, layout, rank_of_site, repeat_sites))


def sample_removal_indices_within(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int,
    sites: Iterable[str], repeat_sites: Optional[Dict[str, int]] = None
) -> np.ndarray:
    """:func:`sample_removal_indices` restricted to the given sites'
    coordinates — site-local candidate blocks for the per-site-depth
    benchmark.  NOT part of Alg. 2's rng discipline (the real sampler draws
    from the global active set); returns (n, min(drc, #active-in-sites)).

    Site names may be repeat-qualified (``"s0.ffn@1"`` — models.lm virtual
    stack sites) when ``repeat_sites`` maps the base mask name to its
    repeat count R: coordinates are then restricted to repeat r's row of
    the stacked (R, ·) mask, so the benchmark can build candidates that
    cut at one specific scan repeat.
    """
    flat, layout = _flatten(masks)
    wanted: Dict[str, set] = {}
    for s in sites:
        base, _, rtag = str(s).partition("@")
        wanted.setdefault(base, set()).add(int(rtag) if rtag else None)
    sel = np.zeros(flat.size, dtype=bool)
    for k, off, sz, _ in layout:
        rows = wanted.get(k)
        if rows is None:
            continue
        row = sz // int((repeat_sites or {}).get(k, 1))
        for r in rows:
            if r is None:
                sel[off:off + sz] = True
            else:
                sel[off + r * row:off + (r + 1) * row] = True
    if not sel.any():
        raise ValueError(f"no mask coordinates in sites {sorted(set(sites))}")
    active = np.nonzero((flat > 0.5) & sel)[0]
    k = min(drc, active.size)
    return np.stack([rng.choice(active, size=k, replace=False)
                     for _ in range(n)]) if n else \
        np.zeros((0, k), dtype=np.int64)


def sample_removal_blocks(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int
) -> MaskTree:
    """Vectorized :func:`sample_removal_block`: ``n`` candidates, stacked.

    Candidate ``i`` equals the tree ``i`` sequential calls would produce
    (same rng draw order), so backends that pre-sample match backends that
    sample lazily."""
    return materialize_candidates(
        masks, sample_removal_indices(rng, masks, drc, n))


def unflatten_stacked(stacked_flat: np.ndarray, layout: list) -> MaskTree:
    """(n, total) flat candidates -> stacked tree {site: (n, *shape)}."""
    n = stacked_flat.shape[0]
    out = {}
    for k, off, sz, shape in layout:
        out[k] = stacked_flat[:, off:off + sz].reshape((n,) + tuple(shape)) \
            .astype(np.float32)
    return out


def flatten_stacked(stacked: MaskTree) -> Tuple[np.ndarray, list]:
    """Inverse of :func:`unflatten_stacked` (layout shapes are per-site)."""
    keys = sorted(stacked.keys())
    n = next(iter(stacked.values())).shape[0]
    flat = np.concatenate([stacked[k].reshape(n, -1) for k in keys], axis=1)
    layout, off = [], 0
    for k in keys:
        sz = int(np.prod(stacked[k].shape[1:], dtype=np.int64))
        layout.append((k, off, sz, stacked[k].shape[1:]))
        off += sz
    return flat, layout


def stack_trees(trees: Iterable[MaskTree]) -> MaskTree:
    """Stack individual mask trees along a new leading candidate axis."""
    trees = list(trees)
    return {k: np.stack([t[k] for t in trees]) for k in trees[0]}


def stacked_len(stacked: MaskTree) -> int:
    return int(next(iter(stacked.values())).shape[0])


def index_stacked(stacked: MaskTree, i: int) -> MaskTree:
    """Candidate ``i`` of a stacked tree, as an ordinary mask tree."""
    return {k: np.asarray(v[i], dtype=np.float32)
            for k, v in stacked.items()}


def slice_stacked(stacked: MaskTree, start: int, stop: int) -> MaskTree:
    return {k: v[start:stop] for k, v in stacked.items()}


def pad_stacked(stacked: MaskTree, n: int) -> MaskTree:
    """Pad the candidate axis to ``n`` by repeating the last candidate
    (keeps jit cache keys stable across ragged final chunks)."""
    have = stacked_len(stacked)
    if have >= n:
        return stacked
    return {k: np.concatenate(
        [v, np.broadcast_to(v[-1:], (n - have,) + v.shape[1:])])
        for k, v in stacked.items()}


def stacked_counts(stacked: MaskTree) -> np.ndarray:
    """Per-candidate ||m||_0 over a stacked tree — vectorized ``count``."""
    n = stacked_len(stacked)
    return sum(np.sum(v.reshape(n, -1) > 0.5, axis=1) for v in
               stacked.values()).astype(np.int64)


def stacked_relu_costs(stacked: MaskTree) -> np.ndarray:
    """Per-candidate billable ReLUs — vectorized :func:`relu_cost`."""
    n = stacked_len(stacked)
    return sum(np.sum(v.reshape(n, -1) > 0.9, axis=1) for v in
               stacked.values()).astype(np.int64)


def remove_random(rng: np.random.Generator, masks: MaskTree, n: int) -> MaskTree:
    """Uniform random removal (the naive baseline BCD is compared against)."""
    return sample_removal_block(rng, masks, n)


def intersection_over_union(m1: MaskTree, m2: MaskTree) -> float:
    """Paper Fig. 6 IoU: ||m1 ⊙ m2||_0 / ||m1||_0 (m1 = smaller budget)."""
    inter = sum(float(np.sum((a > 0.5) & (m2[k] > 0.5))) for k, a in m1.items())
    denom = float(count(m1))
    return inter / max(denom, 1.0)


def is_subset(m_small: MaskTree, m_big: MaskTree) -> bool:
    """True iff every active coordinate of m_small is active in m_big."""
    for k, a in m_small.items():
        if np.any((a > 0.5) & ~(m_big[k] > 0.5)):
            return False
    return True


def fingerprint(masks: MaskTree) -> str:
    """Content hash of a binary mask tree: sha256 over sorted site names,
    shapes, and packed mask bits.  Two trees fingerprint equal iff they
    keep/linearize exactly the same coordinates — the identity used by
    resume tests and the sweep curve artifact (float payloads are reduced
    to their >0.5 binarization, so dtype/storage differences don't leak
    into the identity).  Sites carrying share-tied coordinates additionally
    hash their >0.9 (driver) plane, so a tied tree and its fully-active
    binarization fingerprint differently; binary sites hash exactly as they
    always have."""
    h = hashlib.sha256()
    for k in sorted(masks.keys()):
        v = np.asarray(masks[k])
        h.update(k.encode())
        h.update(repr(tuple(v.shape)).encode())
        nz = v.reshape(-1) > 0.5
        h.update(np.packbits(nz).tobytes())
        full = v.reshape(-1) > 0.9
        if bool(np.any(nz & ~full)):
            h.update(b"tied")
            h.update(np.packbits(full).tobytes())
    return h.hexdigest()


def per_site_counts(masks: MaskTree) -> Dict[str, int]:
    """Paper Fig. 7 — ReLU distribution across layers/sites."""
    return {k: int(np.sum(v > 0.5)) for k, v in sorted(masks.items())}


def threshold(soft_masks: MaskTree, budget: int) -> MaskTree:
    """Hard-threshold soft (real-valued) masks to exactly ``budget`` ones.

    Keeps the ``budget`` largest coordinates globally — this is SNL's final
    binarization step (the step the paper identifies as the accuracy cliff).
    """
    flat, layout = _flatten(soft_masks)
    budget = min(budget, flat.size)
    out = np.zeros_like(flat)
    if budget > 0:
        keep = np.argpartition(flat, -budget)[-budget:]
        out[keep] = 1.0
    return _unflatten(out, layout)
