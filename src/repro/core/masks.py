"""ReLU/activation mask pytrees.

A *mask tree* is a dict mapping a mask-site name (e.g. ``"layer3.relu2"`` for
CNNs or ``"blocks.ffn"`` for a scanned transformer stack) to a float32 array of
zeros/ones.  ``1.0`` keeps the nonlinearity at that coordinate, ``0.0``
linearizes it (identity or poly2 replacement — see core.linearize).

Masks are deliberately small (one scalar per activation *site*, shared across
the batch, matching the paper's per-pixel masks) so they are replicated across
the mesh and updated host-side between jitted evaluations.  All sampling /
counting helpers here are numpy-based host code: BCD mutates masks a few times
per outer iteration, never inside a jitted step.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from typing import Dict, Iterable, Tuple

MaskTree = Dict[str, np.ndarray]


def as_device(masks: MaskTree) -> Dict[str, jnp.ndarray]:
    """Move a host mask tree onto device as float32 jnp arrays."""
    return {k: jnp.asarray(v, dtype=jnp.float32) for k, v in masks.items()}


def full_masks(shapes: Dict[str, Tuple[int, ...]]) -> MaskTree:
    """All-ones masks (every nonlinearity kept) for the given site shapes."""
    return {k: np.ones(s, dtype=np.float32) for k, s in shapes.items()}


def count(masks: MaskTree) -> int:
    """||m||_0 — the current ReLU budget."""
    return int(sum(int(np.sum(v > 0.5)) for v in masks.values()))


def total_size(masks: MaskTree) -> int:
    return int(sum(v.size for v in masks.values()))


def _flatten(masks: MaskTree) -> Tuple[np.ndarray, list]:
    """Concatenate all masks into one flat vector + per-site layout info."""
    keys = sorted(masks.keys())
    flat = np.concatenate([masks[k].reshape(-1) for k in keys])
    layout = []
    off = 0
    for k in keys:
        n = masks[k].size
        layout.append((k, off, n, masks[k].shape))
        off += n
    return flat, layout


def _unflatten(flat: np.ndarray, layout: list) -> MaskTree:
    out = {}
    for k, off, n, shape in layout:
        out[k] = flat[off:off + n].reshape(shape).astype(np.float32)
    return out


def active_indices(masks: MaskTree) -> Tuple[np.ndarray, list]:
    flat, layout = _flatten(masks)
    return np.nonzero(flat > 0.5)[0], layout


def sample_removal_block(
    rng: np.random.Generator, masks: MaskTree, drc: int
) -> MaskTree:
    """Sample a block of ``drc`` currently-active coordinates (Alg. 2 line 8).

    Returns a *candidate* mask tree: ``masks`` with the sampled block zeroed.
    If fewer than ``drc`` coordinates are active, zeroes all of them.
    """
    flat, layout = _flatten(masks)
    active = np.nonzero(flat > 0.5)[0]
    k = min(drc, active.size)
    chosen = rng.choice(active, size=k, replace=False)
    new_flat = flat.copy()
    new_flat[chosen] = 0.0
    return _unflatten(new_flat, layout)


def remove_random(rng: np.random.Generator, masks: MaskTree, n: int) -> MaskTree:
    """Uniform random removal (the naive baseline BCD is compared against)."""
    return sample_removal_block(rng, masks, n)


def intersection_over_union(m1: MaskTree, m2: MaskTree) -> float:
    """Paper Fig. 6 IoU: ||m1 ⊙ m2||_0 / ||m1||_0 (m1 = smaller budget)."""
    inter = sum(float(np.sum((a > 0.5) & (m2[k] > 0.5))) for k, a in m1.items())
    denom = float(count(m1))
    return inter / max(denom, 1.0)


def is_subset(m_small: MaskTree, m_big: MaskTree) -> bool:
    """True iff every active coordinate of m_small is active in m_big."""
    for k, a in m_small.items():
        if np.any((a > 0.5) & ~(m_big[k] > 0.5)):
            return False
    return True


def per_site_counts(masks: MaskTree) -> Dict[str, int]:
    """Paper Fig. 7 — ReLU distribution across layers/sites."""
    return {k: int(np.sum(v > 0.5)) for k, v in sorted(masks.items())}


def threshold(soft_masks: MaskTree, budget: int) -> MaskTree:
    """Hard-threshold soft (real-valued) masks to exactly ``budget`` ones.

    Keeps the ``budget`` largest coordinates globally — this is SNL's final
    binarization step (the step the paper identifies as the accuracy cliff).
    """
    flat, layout = _flatten(soft_masks)
    budget = min(budget, flat.size)
    out = np.zeros_like(flat)
    if budget > 0:
        keep = np.argpartition(flat, -budget)[-budget:]
        out[keep] = 1.0
    return _unflatten(out, layout)
