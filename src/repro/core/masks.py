"""ReLU/activation mask pytrees.

A *mask tree* is a dict mapping a mask-site name (e.g. ``"layer3.relu2"`` for
CNNs or ``"blocks.ffn"`` for a scanned transformer stack) to a float32 array of
zeros/ones.  ``1.0`` keeps the nonlinearity at that coordinate, ``0.0``
linearizes it (identity or poly2 replacement — see core.linearize).

Masks are deliberately small (one scalar per activation *site*, shared across
the batch, matching the paper's per-pixel masks) so they are replicated across
the mesh and updated host-side between jitted evaluations.  All sampling /
counting helpers here are numpy-based host code: BCD mutates masks a few times
per outer iteration, never inside a jitted step.
"""
from __future__ import annotations

import hashlib

import numpy as np
import jax.numpy as jnp
from typing import Dict, Iterable, Tuple

MaskTree = Dict[str, np.ndarray]


def as_device(masks: MaskTree) -> Dict[str, jnp.ndarray]:
    """Move a host mask tree onto device as float32 jnp arrays."""
    return {k: jnp.asarray(v, dtype=jnp.float32) for k, v in masks.items()}


def full_masks(shapes: Dict[str, Tuple[int, ...]]) -> MaskTree:
    """All-ones masks (every nonlinearity kept) for the given site shapes."""
    return {k: np.ones(s, dtype=np.float32) for k, s in shapes.items()}


def count(masks: MaskTree) -> int:
    """||m||_0 — the current ReLU budget."""
    return int(sum(int(np.sum(v > 0.5)) for v in masks.values()))


def total_size(masks: MaskTree) -> int:
    return int(sum(v.size for v in masks.values()))


def _flatten(masks: MaskTree) -> Tuple[np.ndarray, list]:
    """Concatenate all masks into one flat vector + per-site layout info."""
    keys = sorted(masks.keys())
    flat = np.concatenate([masks[k].reshape(-1) for k in keys])
    layout = []
    off = 0
    for k in keys:
        n = masks[k].size
        layout.append((k, off, n, masks[k].shape))
        off += n
    return flat, layout


def _unflatten(flat: np.ndarray, layout: list) -> MaskTree:
    out = {}
    for k, off, n, shape in layout:
        out[k] = flat[off:off + n].reshape(shape).astype(np.float32)
    return out


def active_indices(masks: MaskTree) -> Tuple[np.ndarray, list]:
    flat, layout = _flatten(masks)
    return np.nonzero(flat > 0.5)[0], layout


def sample_removal_block(
    rng: np.random.Generator, masks: MaskTree, drc: int
) -> MaskTree:
    """Sample a block of ``drc`` currently-active coordinates (Alg. 2 line 8).

    Returns a *candidate* mask tree: ``masks`` with the sampled block zeroed.
    If fewer than ``drc`` coordinates are active, zeroes all of them.
    """
    flat, layout = _flatten(masks)
    active = np.nonzero(flat > 0.5)[0]
    k = min(drc, active.size)
    chosen = rng.choice(active, size=k, replace=False)
    new_flat = flat.copy()
    new_flat[chosen] = 0.0
    return _unflatten(new_flat, layout)


# ------------------------------------------------------------ stacked trees
#
# A *stacked* mask tree carries ``n`` candidate trees along a leading axis:
# ``{site: (n, *site_shape)}``.  The batched/sharded evaluators (core.engine)
# consume stacked trees whole — one jitted vmap call evaluates all n
# candidates — so every helper here must index/slice consistently across
# sites.  Sampling is split into *index* sampling (tiny: (n, drc) ints) and
# *materialization* (per-chunk, so RT full-size candidate trees never live in
# host memory at once).


def sample_removal_indices(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int
) -> np.ndarray:
    """Sample ``n`` independent removal blocks as flat-coordinate indices.

    Row ``i`` is bit-identical to the ``rng.choice`` draw the ``i``-th
    sequential :func:`sample_removal_block` call would make from the same
    generator state — the engine relies on this for backend equivalence.
    Returns an (n, k) int array, k = min(drc, #active).
    """
    active, _ = active_indices(masks)
    k = min(drc, active.size)
    return np.stack([rng.choice(active, size=k, replace=False)
                     for _ in range(n)]) if n else \
        np.zeros((0, k), dtype=np.int64)


def materialize_from_flat(flat: np.ndarray, layout: list,
                          indices: np.ndarray) -> MaskTree:
    """Stacked candidate tree from a pre-flattened base mask.

    The hot path: BCD flattens the base tree once per outer step and
    materializes each chunk from (flat, layout) without re-concatenating
    the whole tree per chunk."""
    n = indices.shape[0]
    stacked = np.broadcast_to(flat, (n, flat.size)).copy()
    np.put_along_axis(stacked, indices, 0.0, axis=1)
    return unflatten_stacked(stacked, layout)


def materialize_candidates(masks: MaskTree, indices: np.ndarray) -> MaskTree:
    """Build the stacked candidate tree for (n, k) removal ``indices``."""
    flat, layout = _flatten(masks)
    return materialize_from_flat(flat, layout, indices)


def chunk_bounds(n: int, chunk_size: int) -> list:
    """[(start, stop)] chunk boundaries covering ``n`` candidates."""
    return [(s, min(s + chunk_size, n)) for s in range(0, n, chunk_size)]


def coalesce_fallback_chunks(chunks: list, chunk_size: int) -> list:
    """Merge runs of adjacent fallback chunks in a sited plan.

    ``chunks``: ``[(site | None, start, stop)]`` with contiguous ascending
    bounds (``plan_sited_chunks`` raw output).  Sited chunks pass through
    untouched — they must never straddle a prefix group.  Consecutive
    ``site is None`` chunks carry no shared-prefix constraint (the inner
    pipeline runs each candidate's full forward), so their spans are merged
    and re-split at ``chunk_size``: a depth mix that fragments into many
    small per-group fallback tails then costs ceil(total/chunk) dispatches
    instead of one ragged dispatch per group."""
    out: list = []
    run_start = run_stop = None
    for site, s, e in chunks:
        if site is None:
            if run_stop == s:
                run_stop = e
            else:
                if run_start is not None:
                    out.extend((None, run_start + cs, run_start + ce)
                               for cs, ce in chunk_bounds(
                                   run_stop - run_start, chunk_size))
                run_start, run_stop = s, e
            continue
        if run_start is not None:
            out.extend((None, run_start + cs, run_start + ce)
                       for cs, ce in chunk_bounds(run_stop - run_start,
                                                  chunk_size))
            run_start = run_stop = None
        out.append((site, s, e))
    if run_start is not None:
        out.extend((None, run_start + cs, run_start + ce)
                   for cs, ce in chunk_bounds(run_stop - run_start,
                                              chunk_size))
    return out


def materialize_chunks(flat: np.ndarray, layout: list, indices: np.ndarray,
                       chunk_size: int):
    """Lazy chunk producer for the trial loop: yields one stacked candidate
    tree per :func:`chunk_bounds` chunk of ``indices``.

    Laziness is load-bearing twice over — the prefetch pipeline
    (core.engine.evaluate_prefetched) pulls chunk k+1's materialization
    while chunk k computes on device, and an ADT early exit closes the
    generator so chunks past the staging horizon are never built."""
    for start, stop in chunk_bounds(indices.shape[0], chunk_size):
        yield materialize_from_flat(flat, layout, indices[start:stop])


def group_blocks_by_site(indices: np.ndarray, layout: list,
                         rank_of_site: Dict[str, int]):
    """Group candidate removal blocks by their *earliest* touched site rank.

    ``indices``: (n, k) flat removal coordinates (``sample_removal_indices``
    output); ``layout``: the matching ``_flatten`` layout; ``rank_of_site``:
    site name -> group rank — pass the model's segment indices so candidates
    that share a forward prefix land in the same group (the prefix-reuse
    engine's chunking contract: chunks never straddle a group).

    Returns ``(order, groups)``: ``order`` is an (n,) permutation of
    candidate positions sorted by group rank (stable, so sampling order
    survives within a group), and ``groups`` is ``[(rank, start, stop)]``
    bounds into ``order``.
    """
    n = indices.shape[0]
    if n == 0 or indices.size == 0:
        return np.arange(n, dtype=np.int64), \
            ([] if n == 0 else [(0, 0, n)])
    offs = np.array([off for _, off, _, _ in layout], dtype=np.int64)
    ranks = np.array([rank_of_site[k] for k, _, _, _ in layout],
                     dtype=np.int64)
    site_of = np.searchsorted(offs, indices.reshape(-1), side="right") - 1
    cand_rank = ranks[site_of].reshape(indices.shape).min(axis=1)
    order = np.argsort(cand_rank, kind="stable").astype(np.int64)
    sorted_ranks = cand_rank[order]
    cuts = np.flatnonzero(np.diff(sorted_ranks)) + 1
    bounds = [0, *cuts.tolist(), n]
    groups = [(int(sorted_ranks[s]), s, e)
              for s, e in zip(bounds[:-1], bounds[1:])]
    return order, groups


def sample_removal_indices_within(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int,
    sites: Iterable[str]
) -> np.ndarray:
    """:func:`sample_removal_indices` restricted to the given sites'
    coordinates — site-local candidate blocks for the per-site-depth
    benchmark.  NOT part of Alg. 2's rng discipline (the real sampler draws
    from the global active set); returns (n, min(drc, #active-in-sites)).
    """
    sites = set(sites)
    flat, layout = _flatten(masks)
    sel = np.zeros(flat.size, dtype=bool)
    for k, off, sz, _ in layout:
        if k in sites:
            sel[off:off + sz] = True
    if not sel.any():
        raise ValueError(f"no mask coordinates in sites {sorted(sites)}")
    active = np.nonzero((flat > 0.5) & sel)[0]
    k = min(drc, active.size)
    return np.stack([rng.choice(active, size=k, replace=False)
                     for _ in range(n)]) if n else \
        np.zeros((0, k), dtype=np.int64)


def sample_removal_blocks(
    rng: np.random.Generator, masks: MaskTree, drc: int, n: int
) -> MaskTree:
    """Vectorized :func:`sample_removal_block`: ``n`` candidates, stacked.

    Candidate ``i`` equals the tree ``i`` sequential calls would produce
    (same rng draw order), so backends that pre-sample match backends that
    sample lazily."""
    return materialize_candidates(
        masks, sample_removal_indices(rng, masks, drc, n))


def unflatten_stacked(stacked_flat: np.ndarray, layout: list) -> MaskTree:
    """(n, total) flat candidates -> stacked tree {site: (n, *shape)}."""
    n = stacked_flat.shape[0]
    out = {}
    for k, off, sz, shape in layout:
        out[k] = stacked_flat[:, off:off + sz].reshape((n,) + tuple(shape)) \
            .astype(np.float32)
    return out


def flatten_stacked(stacked: MaskTree) -> Tuple[np.ndarray, list]:
    """Inverse of :func:`unflatten_stacked` (layout shapes are per-site)."""
    keys = sorted(stacked.keys())
    n = next(iter(stacked.values())).shape[0]
    flat = np.concatenate([stacked[k].reshape(n, -1) for k in keys], axis=1)
    layout, off = [], 0
    for k in keys:
        sz = int(np.prod(stacked[k].shape[1:], dtype=np.int64))
        layout.append((k, off, sz, stacked[k].shape[1:]))
        off += sz
    return flat, layout


def stack_trees(trees: Iterable[MaskTree]) -> MaskTree:
    """Stack individual mask trees along a new leading candidate axis."""
    trees = list(trees)
    return {k: np.stack([t[k] for t in trees]) for k in trees[0]}


def stacked_len(stacked: MaskTree) -> int:
    return int(next(iter(stacked.values())).shape[0])


def index_stacked(stacked: MaskTree, i: int) -> MaskTree:
    """Candidate ``i`` of a stacked tree, as an ordinary mask tree."""
    return {k: np.asarray(v[i], dtype=np.float32)
            for k, v in stacked.items()}


def slice_stacked(stacked: MaskTree, start: int, stop: int) -> MaskTree:
    return {k: v[start:stop] for k, v in stacked.items()}


def pad_stacked(stacked: MaskTree, n: int) -> MaskTree:
    """Pad the candidate axis to ``n`` by repeating the last candidate
    (keeps jit cache keys stable across ragged final chunks)."""
    have = stacked_len(stacked)
    if have >= n:
        return stacked
    return {k: np.concatenate(
        [v, np.broadcast_to(v[-1:], (n - have,) + v.shape[1:])])
        for k, v in stacked.items()}


def stacked_counts(stacked: MaskTree) -> np.ndarray:
    """Per-candidate ||m||_0 over a stacked tree — vectorized ``count``."""
    n = stacked_len(stacked)
    return sum(np.sum(v.reshape(n, -1) > 0.5, axis=1) for v in
               stacked.values()).astype(np.int64)


def remove_random(rng: np.random.Generator, masks: MaskTree, n: int) -> MaskTree:
    """Uniform random removal (the naive baseline BCD is compared against)."""
    return sample_removal_block(rng, masks, n)


def intersection_over_union(m1: MaskTree, m2: MaskTree) -> float:
    """Paper Fig. 6 IoU: ||m1 ⊙ m2||_0 / ||m1||_0 (m1 = smaller budget)."""
    inter = sum(float(np.sum((a > 0.5) & (m2[k] > 0.5))) for k, a in m1.items())
    denom = float(count(m1))
    return inter / max(denom, 1.0)


def is_subset(m_small: MaskTree, m_big: MaskTree) -> bool:
    """True iff every active coordinate of m_small is active in m_big."""
    for k, a in m_small.items():
        if np.any((a > 0.5) & ~(m_big[k] > 0.5)):
            return False
    return True


def fingerprint(masks: MaskTree) -> str:
    """Content hash of a binary mask tree: sha256 over sorted site names,
    shapes, and packed mask bits.  Two trees fingerprint equal iff they
    keep/linearize exactly the same coordinates — the identity used by
    resume tests and the sweep curve artifact (float payloads are reduced
    to their >0.5 binarization, so dtype/storage differences don't leak
    into the identity)."""
    h = hashlib.sha256()
    for k in sorted(masks.keys()):
        v = np.asarray(masks[k])
        h.update(k.encode())
        h.update(repr(tuple(v.shape)).encode())
        h.update(np.packbits(v.reshape(-1) > 0.5).tobytes())
    return h.hexdigest()


def per_site_counts(masks: MaskTree) -> Dict[str, int]:
    """Paper Fig. 7 — ReLU distribution across layers/sites."""
    return {k: int(np.sum(v > 0.5)) for k, v in sorted(masks.items())}


def threshold(soft_masks: MaskTree, budget: int) -> MaskTree:
    """Hard-threshold soft (real-valued) masks to exactly ``budget`` ones.

    Keeps the ``budget`` largest coordinates globally — this is SNL's final
    binarization step (the step the paper identifies as the accuracy cliff).
    """
    flat, layout = _flatten(soft_masks)
    budget = min(budget, flat.size)
    out = np.zeros_like(flat)
    if budget > 0:
        keep = np.argpartition(flat, -budget)[-budget:]
        out[keep] = 1.0
    return _unflatten(out, layout)
