"""Private-Inference cost model — why ReLU count is the latency bottleneck.

DELPHI-style hybrid protocol accounting (Srinivasan et al., USENIX Sec'20):
linear layers are evaluated under additive secret sharing with the heavy
lifting moved to an offline phase; each *online* ReLU requires a garbled-
circuit evaluation whose communication dominates.  Constants below follow the
published per-ReLU figures (order-of-magnitude; configurable):

  online  ≈ 2.0 KiB per ReLU  (GC evaluation + share reconstruction)
  offline ≈ 17.5 KiB per ReLU (garbling + OT)

Latency = comm / bandwidth + per-round RTTs + linear-layer share ops.
This module turns a mask budget into the latency/bandwidth savings the paper
claims PI gets from linearization.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PIProtocol:
    name: str = "delphi"
    online_bytes_per_relu: float = 2.0 * 1024
    offline_bytes_per_relu: float = 17.5 * 1024
    bandwidth_bytes_per_s: float = 1e9 / 8      # 1 Gb/s WAN-ish link
    rtt_s: float = 0.010
    rounds_per_layer: int = 2
    linear_online_bytes_per_param: float = 0.0  # linear layers ~free online


@dataclasses.dataclass(frozen=True)
class PICost:
    relus: int
    online_bytes: float
    offline_bytes: float
    online_latency_s: float
    total_bytes: float


def cost(relu_count: int, n_nonlinear_layers: int,
         proto: PIProtocol = PIProtocol(), linear_params: int = 0) -> PICost:
    online = relu_count * proto.online_bytes_per_relu \
        + linear_params * proto.linear_online_bytes_per_param
    offline = relu_count * proto.offline_bytes_per_relu
    latency = online / proto.bandwidth_bytes_per_s \
        + n_nonlinear_layers * proto.rounds_per_layer * proto.rtt_s
    return PICost(relu_count, online, offline, latency, online + offline)


def cost_of_masks(masks, n_nonlinear_layers: int,
                  proto: PIProtocol = PIProtocol(),
                  linear_params: int = 0) -> PICost:
    """:func:`cost` for a mask tree — bills *driver* ReLUs only.

    Before share moves, ``||m||_0 == billable ReLUs``; a share-tied
    coordinate (``masks.TIE``) keeps its gate but reuses its driver's
    garbled-circuit comparison, so the protocol is charged
    ``masks.relu_cost`` (coordinates > 0.9), not ``masks.count``.  The
    reconstruction share for a tied coordinate rides in the driver's
    existing message — no extra bytes, no extra rounds.
    """
    from . import masks as M
    return cost(M.relu_cost(masks), n_nonlinear_layers, proto,
                linear_params)


def bill_request(relu_count: int, n_nonlinear_layers: int, tokens: int,
                 proto: PIProtocol = PIProtocol(),
                 linear_params: int = 0, *,
                 mask_set: str | None = None,
                 fingerprint: str | None = None,
                 degraded_from: str | None = None) -> dict:
    """Per-request PI bill: one token-forward :func:`cost`, scaled by tokens.

    A served request runs ``tokens`` forwards (prompt positions during
    prefill + one per generated token) under one mask set; each forward
    pays the set's per-token protocol cost.  Returns a JSON-ready dict —
    this is the number a serving tier reports per request (the paper's
    ReLU-count ≈ PI-latency claim, priced).

    ``mask_set``/``fingerprint`` stamp the identity of the set the request
    was *actually served under*; ``degraded_from`` records the set its SLO
    class originally routed to when overload admission degraded it to a
    cheaper budget — the bill then prices the degraded set, auditable
    against its fingerprint.
    """
    per_tok = cost(relu_count, n_nonlinear_layers, proto, linear_params)
    return {
        "relu_cost": int(relu_count),
        "tokens": int(tokens),
        "relus_billed": int(relu_count) * int(tokens),
        "pi_online_bytes": per_tok.online_bytes * tokens,
        "pi_offline_bytes": per_tok.offline_bytes * tokens,
        "pi_online_s": per_tok.online_latency_s * tokens,
        "mask_set": mask_set,
        "fingerprint": fingerprint,
        "degraded_from": degraded_from,
    }


def estimate_request_s(relu_count: int, n_nonlinear_layers: int,
                       prompt_tokens: int, gen_tokens: int,
                       proto: PIProtocol = PIProtocol()) -> float:
    """Model-side end-to-end latency estimate for one served request.

    The admission controller's price of a candidate admission before any
    measurement exists: every prompt position and every generated token is
    one forward at the mask set's per-token protocol cost.  The serve
    loop seeds its per-mask-set prefill/decode EWMAs from this estimate
    and refines them with measured latencies as requests complete.
    """
    per_tok = cost(relu_count, n_nonlinear_layers, proto)
    return per_tok.online_latency_s * (int(prompt_tokens) + int(gen_tokens))


def saving(b_ref: int, b_target: int, n_layers: int,
           proto: PIProtocol = PIProtocol()):
    """(latency_ref, latency_target, speedup) for a linearization run."""
    a = cost(b_ref, n_layers, proto)
    b = cost(b_target, n_layers, proto)
    return a.online_latency_s, b.online_latency_s, \
        a.online_latency_s / max(b.online_latency_s, 1e-12)
