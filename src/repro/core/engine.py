"""Candidate-evaluation engine for BCD (Alg. 2's hot path).

One BCD outer step evaluates up to RT candidate mask trees; the engine decides
*how*.  All backends implement the :class:`CandidateEvaluator` protocol —
``evaluate(stacked_tree) -> (n,) accuracies`` — and are interchangeable from
``run_bcd``'s point of view:

``SequentialEvaluator``
    The reference: one jitted forward per candidate, host loop.  Exactly the
    seed repo's behavior, kept for equivalence testing and tiny configs where
    vmap compile time dominates.

``BatchedEvaluator``
    Stacks the candidate axis through ``jax.vmap`` and evaluates a whole chunk
    in a single jitted call.  Masks stay jit *inputs* (no recompile across
    chunks); ragged final chunks are padded to the chunk size so the jit cache
    holds exactly one entry per (chunk, shapes) signature.

``ShardedEvaluator``
    BatchedEvaluator plus ``jax.sharding``: the candidate axis is laid out
    across every device of a mesh (``launch.mesh``), so RT trials cost
    RT / n_devices forward passes of wall-clock.  Falls back gracefully to a
    1-device mesh (where it equals BatchedEvaluator).

Backends must rank candidates identically: ``run_bcd`` breaks ties by first
occurrence, and all backends evaluate candidates in sampling order, so for a
given seed/config every backend selects the same block (tested in
``tests/test_bcd_parallel.py``).
"""
from __future__ import annotations

from typing import Callable, Optional, Protocol, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp

from . import masks as M

# eval_fn: traceable (device mask tree) -> scalar accuracy in percent.
EvalFn = Callable[[dict], jnp.ndarray]


@runtime_checkable
class CandidateEvaluator(Protocol):
    """Evaluates a *stacked* candidate mask tree -> per-candidate accuracy."""

    name: str
    # Chunk size the backend wants from run_bcd's trial loop; None defers to
    # cfg.chunk_size.  Chunking never changes selection (rng burns RT draws
    # per step regardless), so this is a pure performance hint.
    preferred_chunk: Optional[int]

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        """stacked: {site: (n, *shape)} -> float64 (n,) accuracies [%]."""
        ...


class SequentialEvaluator:
    """Reference backend: unstack and evaluate one candidate at a time."""

    name = "sequential"
    # One candidate per chunk: evaluating a whole chunk before checking the
    # ADT exit would waste up to chunk-1 forwards on this host-loop backend.
    preferred_chunk = 1

    def __init__(self, eval_acc: Callable[[M.MaskTree], float]):
        self._eval_acc = eval_acc

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        n = M.stacked_len(stacked)
        return np.array([float(self._eval_acc(M.index_stacked(stacked, i)))
                         for i in range(n)], dtype=np.float64)


class BatchedEvaluator:
    """vmap-over-masks backend: one jitted call per chunk of candidates."""

    name = "batched"
    preferred_chunk = None

    def __init__(self, eval_fn: EvalFn, *, pad_to: Optional[int] = None,
                 context=None):
        """eval_fn: traceable single-tree accuracy (device arrays in/out).
        pad_to: pad ragged candidate axes up to this size (use the BCD
        chunk_size) so jit sees one leading-dim signature.
        context: optional pytree (e.g. model params) passed to eval_fn as a
        second argument and mapped over with in_axes=None.  It is a jit
        *input*, not a closure constant — callers that finetune params
        between outer steps update it via :meth:`set_context` and the
        compiled executable picks up the new values without retracing."""
        self._has_ctx = context is not None
        self.context = context
        if self._has_ctx:
            self._vmapped = jax.jit(jax.vmap(eval_fn, in_axes=(0, None)))
        else:
            self._vmapped = jax.jit(jax.vmap(eval_fn))
        self._pad_to = pad_to

    def set_context(self, context) -> None:
        """Swap the auxiliary context (same treedef/shapes: no recompile)."""
        if not self._has_ctx:
            raise ValueError("evaluator was built without a context")
        self.context = context

    def _device_batch(self, stacked: M.MaskTree):
        return {k: jnp.asarray(v, dtype=jnp.float32)
                for k, v in stacked.items()}

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        n = M.stacked_len(stacked)
        if self._pad_to is not None and n < self._pad_to:
            stacked = M.pad_stacked(stacked, self._pad_to)
        batch = self._device_batch(stacked)
        accs = self._vmapped(batch, self.context) if self._has_ctx \
            else self._vmapped(batch)
        return np.asarray(accs, dtype=np.float64)[:n]


class ShardedEvaluator(BatchedEvaluator):
    """Batched backend with the candidate axis sharded across a mesh.

    Every mesh axis contributes to the candidate sharding (a pure
    candidate-parallel layout); candidate counts are padded up to the device
    count so the leading axis always divides evenly.
    """

    name = "sharded"

    def __init__(self, eval_fn: EvalFn, mesh, *, pad_to: Optional[int] = None,
                 context=None):
        super().__init__(eval_fn, pad_to=pad_to, context=context)
        from jax.sharding import NamedSharding, PartitionSpec as P
        self._mesh = mesh
        self._n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        self._sharding = NamedSharding(mesh, P(tuple(mesh.axis_names)))

    def _device_batch(self, stacked: M.MaskTree):
        n = M.stacked_len(stacked)
        pad = -n % self._n_dev
        if pad:
            stacked = M.pad_stacked(stacked, n + pad)
        return {k: jax.device_put(np.asarray(v, dtype=np.float32),
                                  self._sharding)
                for k, v in stacked.items()}


def make_evaluator(
    backend: str,
    *,
    eval_acc: Optional[Callable[[M.MaskTree], float]] = None,
    eval_fn: Optional[EvalFn] = None,
    mesh=None,
    pad_to: Optional[int] = None,
    context=None,
) -> CandidateEvaluator:
    """Factory: ``backend`` in {'sequential', 'batched', 'sharded'}.

    sequential needs ``eval_acc`` (host callable); batched/sharded need
    ``eval_fn`` (traceable); sharded defaults to a mesh over all local
    devices when ``mesh`` is None.
    """
    if backend == "sequential":
        if eval_acc is None:
            raise ValueError("sequential backend needs eval_acc")
        return SequentialEvaluator(eval_acc)
    if backend == "batched":
        if eval_fn is None:
            raise ValueError("batched backend needs a traceable eval_fn")
        return BatchedEvaluator(eval_fn, pad_to=pad_to, context=context)
    if backend == "sharded":
        if eval_fn is None:
            raise ValueError("sharded backend needs a traceable eval_fn")
        if mesh is None:
            from repro.launch import mesh as mesh_lib
            mesh = mesh_lib.make_candidate_mesh()
        return ShardedEvaluator(eval_fn, mesh, pad_to=pad_to,
                                context=context)
    raise ValueError(f"unknown evaluator backend {backend!r}; expected "
                     "'sequential' | 'batched' | 'sharded'")
