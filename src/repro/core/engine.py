"""Candidate-evaluation engine for BCD (Alg. 2's hot path).

One BCD outer step evaluates up to RT candidate mask trees; the engine decides
*how*.  All backends implement the :class:`CandidateEvaluator` protocol —
``evaluate(stacked_tree) -> (n,) accuracies`` — and are interchangeable from
``run_bcd``'s point of view:

``SequentialEvaluator``
    The reference: one jitted forward per candidate, host loop.  Exactly the
    seed repo's behavior, kept for equivalence testing and tiny configs where
    vmap compile time dominates.

``BatchedEvaluator``
    Stacks the candidate axis through ``jax.vmap`` and evaluates a whole chunk
    in a single jitted call.  Masks stay jit *inputs* (no recompile across
    chunks); ragged final chunks are padded to the chunk size so the jit cache
    holds exactly one entry per (chunk, shapes) signature.

``ShardedEvaluator``
    BatchedEvaluator plus ``jax.sharding``: the candidate axis is laid out
    across the mesh (``launch.mesh``), so RT trials cost RT / n_devices
    forward passes of wall-clock.  On a 2-D ``("cand", "batch")`` mesh
    (``launch.mesh.make_cand_batch_mesh``) it picks a ``PartitionSpec`` per
    call: chunks with at least one candidate per device shard jointly over
    both axes; smaller chunks shard candidates over ``"cand"`` only and let a
    batch-sharded *context* split each forward over ``"batch"`` — no device
    idles when RT < n_devices.

``PipelinedEvaluator``
    Double-buffered staging on top of batched/sharded placement:
    :meth:`~BatchedEvaluator.stage` pads a chunk, starts its host→device
    transfer, and dispatches the vmapped computation (jax dispatch is async),
    so the trial loop (:func:`evaluate_prefetched`) materializes and stages
    chunk k+1 while the device still computes chunk k.

Backends must rank candidates identically: ``run_bcd`` breaks ties by first
occurrence, and all backends evaluate candidates in sampling order, so for a
given seed/config every backend selects the same block (tested in
``tests/test_bcd_parallel.py``).
"""
from __future__ import annotations

import collections
import functools
import statistics
import time
from typing import (Callable, Iterable, Iterator, NamedTuple, Optional,
                    Protocol, Union, runtime_checkable)

import numpy as np
import jax
import jax.numpy as jnp

from . import masks as M

# eval_fn: traceable (device mask tree) -> scalar accuracy in percent.
EvalFn = Callable[[dict], jnp.ndarray]


@runtime_checkable
class CandidateEvaluator(Protocol):
    """Evaluates a *stacked* candidate mask tree -> per-candidate accuracy."""

    name: str
    # Chunk size the backend wants from run_bcd's trial loop; None defers to
    # cfg.chunk_size.  Chunking never changes selection (rng burns RT draws
    # per step regardless), so this is a pure performance hint.
    preferred_chunk: Optional[int]
    # How many chunks evaluate_prefetched may stage (transfer + dispatch)
    # ahead of the one being consumed.  0 = strict materialize -> evaluate.
    prefetch_depth: int

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        """stacked: {site: (n, *shape)} -> float64 (n,) accuracies [%]."""
        ...


class StagedChunk(NamedTuple):
    """A chunk in flight: transfer + compute dispatched, result not read."""
    n: int                  # true candidate count (before padding)
    accs: jax.Array         # (n_padded,) device array, possibly not ready


class PrefetchAutoTuner:
    """Picks a prefetch depth from measured producer/consumer rates.

    The pipeline overlaps the *producer* (host mask materialization + pad +
    H2D transfer + dispatch) with the *consumer* (blocking on the device
    result, i.e. the remaining compute).  :func:`evaluate_prefetched` runs
    the first chunks of a run in strict alternation, timing both sides; the
    first sample is discarded (it pays jit compile), and once ``n_probe``
    clean samples exist the depth is fixed for the rest of the run:

        depth = clamp(floor(consumer / producer), 1, max_depth)

    — the number of chunks the producer can stage during one consumer
    block.  Depth 1 already reaches steady-state overlap (per-chunk cost
    max(p, c)); deeper staging only buys robustness to producer jitter when
    the producer is much faster, and is capped because every staged chunk
    is wasted work on an ADT early exit.
    """

    def __init__(self, n_probe: int = 2, max_depth: int = 4):
        if n_probe < 1:
            raise ValueError(f"n_probe must be >= 1, got {n_probe}")
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.n_probe = n_probe
        self.max_depth = max_depth
        self._produce: list = []
        self._consume: list = []
        self._warmed = False      # first sample (compile) dropped
        self.done = False

    def add_sample(self, produce_s: float, consume_s: float) -> None:
        if self.done:
            return
        if not self._warmed:
            self._warmed = True
            return
        self._produce.append(produce_s)
        self._consume.append(consume_s)
        if len(self._produce) >= self.n_probe:
            self.done = True

    def depth(self) -> int:
        p = max(statistics.median(self._produce), 1e-9)
        c = statistics.median(self._consume)
        return max(1, min(self.max_depth, int(c / p)))

    def report(self) -> dict:
        return {
            "producer_s": statistics.median(self._produce),
            "consumer_s": statistics.median(self._consume),
            "prefetch": self.depth(),
            "samples": len(self._produce),
        }


def evaluate_prefetched(evaluator, chunks: Iterable[M.MaskTree]
                        ) -> Iterator[np.ndarray]:
    """Producer/consumer driver for the trial loop.

    Yields one float64 ``(n,)`` accuracy array per chunk, in chunk order.
    When the evaluator supports staging (``stage``/``evaluate_staged``, e.g.
    :class:`PipelinedEvaluator`), up to ``prefetch_depth`` chunks beyond the
    one being consumed are kept staged: their host materialization, device
    transfer, and compute dispatch all happen while earlier chunks still
    compute.  Backends without staging — or with ``prefetch_depth == 0`` —
    degrade to the strict materialize → evaluate alternation.

    The consumer may stop early (ADT exit): closing the generator drops any
    staged-but-unread chunks, and because ``chunks`` is itself pulled lazily,
    chunks beyond the staging horizon are never even materialized.  Chunk k's
    result is always yielded before chunk k+depth+1 is staged, so an early
    exit at chunk k commits at most ``depth`` chunks of wasted work.

    When the evaluator carries a live :class:`PrefetchAutoTuner`
    (``prefetch="auto"``), the first chunks run in strict alternation while
    the tuner times the producer vs the consumer; once it converges the
    evaluator's ``prefetch_depth`` is fixed for the rest of the run and the
    loop switches to staged prefetching mid-stream.  The probe phase changes
    timing only — chunk results and their order are identical.
    """
    it = iter(chunks)
    tuner = getattr(evaluator, "auto_tuner", None)
    if tuner is not None and not tuner.done and hasattr(evaluator, "stage"):
        while not tuner.done:
            t0 = time.perf_counter()
            try:
                chunk = next(it)
            except StopIteration:
                return
            staged_one = evaluator.stage(chunk)
            t1 = time.perf_counter()
            accs = evaluator.evaluate_staged(staged_one)
            t2 = time.perf_counter()
            tuner.add_sample(t1 - t0, t2 - t1)
            if tuner.done:
                evaluator.prefetch_depth = tuner.depth()
                evaluator.auto_report = tuner.report()
            yield accs
    depth = int(getattr(evaluator, "prefetch_depth", 0) or 0)
    if depth <= 0 or not hasattr(evaluator, "stage"):
        for chunk in it:
            yield evaluator.evaluate(chunk)
        return
    staged: collections.deque = collections.deque()
    exhausted = False
    while True:
        while not exhausted and len(staged) <= depth:
            try:
                staged.append(evaluator.stage(next(it)))
            except StopIteration:
                exhausted = True
        if not staged:
            return
        yield evaluator.evaluate_staged(staged.popleft())


def _with_stacked_route(eval_fn):
    """Trace eval_fn under linearize.stacked_kernel_route so the TPU
    hard-mask dispatch emits the custom-vmap routed op: vmapping the
    candidate axis then lowers to the stacked Pallas kernel
    (kernels.masked_act_2d_batched) instead of vmapping the per-candidate
    kernel's grid.  Trace-time only — a no-op off TPU."""
    @functools.wraps(eval_fn)
    def routed(*args):
        from . import linearize
        with linearize.stacked_kernel_route():
            return eval_fn(*args)
    return routed


class SequentialEvaluator:
    """Reference backend: unstack and evaluate one candidate at a time."""

    name = "sequential"
    # One candidate per chunk: evaluating a whole chunk before checking the
    # ADT exit would waste up to chunk-1 forwards on this host-loop backend.
    preferred_chunk = 1
    prefetch_depth = 0

    def __init__(self, eval_acc: Callable[[M.MaskTree], float]):
        self._eval_acc = eval_acc

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        n = M.stacked_len(stacked)
        return np.array([float(self._eval_acc(M.index_stacked(stacked, i)))
                         for i in range(n)], dtype=np.float64)


class BatchedEvaluator:
    """vmap-over-masks backend: one jitted call per chunk of candidates."""

    name = "batched"
    preferred_chunk = None
    prefetch_depth = 0

    def __init__(self, eval_fn: EvalFn, *, pad_to: Optional[int] = None,
                 context=None):
        """eval_fn: traceable single-tree accuracy (device arrays in/out).
        pad_to: pad ragged candidate axes up to this size (use the BCD
        chunk_size) so jit sees one leading-dim signature.
        context: optional pytree (e.g. model params) passed to eval_fn as a
        second argument and mapped over with in_axes=None.  It is a jit
        *input*, not a closure constant — callers that finetune params
        between outer steps update it via :meth:`set_context` and the
        compiled executable picks up the new values without retracing."""
        self._has_ctx = context is not None
        self.context = context
        routed = _with_stacked_route(eval_fn)
        if self._has_ctx:
            self._vmapped = jax.jit(jax.vmap(routed, in_axes=(0, None)))
        else:
            self._vmapped = jax.jit(jax.vmap(routed))
        self._pad_to = pad_to

    def set_context(self, context) -> None:
        """Swap the auxiliary context (same treedef/shapes: no recompile)."""
        if not self._has_ctx:
            raise ValueError("evaluator was built without a context")
        self.context = context

    def _device_batch(self, stacked: M.MaskTree):
        return {k: jnp.asarray(v, dtype=jnp.float32)
                for k, v in stacked.items()}

    # -------------------------------------------------------------- staging
    #
    # evaluate() is stage() + evaluate_staged(); splitting them lets
    # evaluate_prefetched keep later chunks' transfers AND dispatched
    # computations in flight while it blocks on an earlier chunk's result.

    def stage(self, stacked: M.MaskTree) -> StagedChunk:
        """Pad, start the host→device transfer, dispatch the computation."""
        n = M.stacked_len(stacked)
        if self._pad_to is not None and n < self._pad_to:
            stacked = M.pad_stacked(stacked, self._pad_to)
        batch = self._device_batch(stacked)
        accs = self._vmapped(batch, self.context) if self._has_ctx \
            else self._vmapped(batch)
        return StagedChunk(n, accs)

    def evaluate_staged(self, staged: StagedChunk) -> np.ndarray:
        """Block on a staged chunk's result and strip its padding."""
        return np.asarray(staged.accs, dtype=np.float64)[:staged.n]

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        return self.evaluate_staged(self.stage(stacked))


def effective_chunk(evaluator, chunk_size: int) -> int:
    """The chunk size the trial loop actually uses: backends may cap it via
    ``preferred_chunk`` (SequentialEvaluator wants 1 so the ADT exit never
    pays for unevaluated chunk-mates).  Shared by ``bcd._select_block`` and
    the throughput benchmark so both drive the same loop."""
    return min(chunk_size,
               getattr(evaluator, "preferred_chunk", None) or chunk_size)


def context_batch_specs(context: dict, *, batch_key: str = "batch",
                        axis: str = "batch") -> dict:
    """PartitionSpec tree for an evaluator context dict: leaves under
    ``context[batch_key]`` shard their leading axis over mesh axis ``axis``
    (the axis size must divide their leading dim, e.g. batch 16 over a
    2-device axis); every other leaf replicates.  Feed the result to
    ShardedEvaluator(context_specs=...)."""
    from jax.sharding import PartitionSpec as P
    return {k: jax.tree.map(lambda _: P(axis) if k == batch_key else P(), v)
            for k, v in context.items()}


class ShardedEvaluator(BatchedEvaluator):
    """Batched backend with the candidate axis sharded across a mesh.

    1-D mesh (``make_candidate_mesh``): every axis contributes to the
    candidate sharding (pure candidate-parallel); counts pad up to the device
    count.  2-D ``("cand", "batch")`` mesh (``make_cand_batch_mesh``): the
    spec is chosen *per call* by padded per-device work — chunks big enough
    to give every device a candidate shard jointly over both axes; smaller
    chunks shard over ``"cand"`` only, and a ``context_specs``-sharded eval
    batch splits each candidate's forward across ``"batch"``.
    """

    name = "sharded"

    def __init__(self, eval_fn: EvalFn, mesh, *, pad_to: Optional[int] = None,
                 context=None, context_specs=None):
        super().__init__(eval_fn, pad_to=pad_to, context=context)
        from jax.sharding import NamedSharding, PartitionSpec as P
        self._mesh = mesh
        axes = tuple(mesh.axis_names)
        self._n_dev = int(np.prod([mesh.shape[a] for a in axes]))
        cand_axes = tuple(a for a in axes if a != "batch") or axes
        self._cand = int(np.prod([mesh.shape[a] for a in cand_axes]))
        self._joint_sharding = NamedSharding(mesh, P(axes))
        self._cand_sharding = NamedSharding(mesh, P(cand_axes))
        self._ctx_shardings = None
        if context_specs is not None:
            if context is None:
                raise ValueError("context_specs given without a context")
            self._ctx_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), context_specs,
                is_leaf=lambda x: isinstance(x, P))
            self.context = jax.device_put(context, self._ctx_shardings)

    def set_context(self, context) -> None:
        if self._ctx_shardings is not None:
            context = jax.device_put(context, self._ctx_shardings)
        super().set_context(context)

    def _chunk_sharding(self, n: int):
        """Per-call layout: (padded candidate count, NamedSharding).

        Minimize padded per-device work: the joint layout costs
        ceil(n / n_dev) candidate-forwards per device; the cand-only layout
        costs ceil(n / cand) forwards over 1/batch of the eval batch each.
        Ties prefer joint (no cross-device reduction inside a forward)."""
        batch_ax = self._n_dev // self._cand
        joint_cost = -(-n // self._n_dev)
        split_cost = -(-n // self._cand) / batch_ax
        if joint_cost <= split_cost:
            return n + (-n % self._n_dev), self._joint_sharding
        return n + (-n % self._cand), self._cand_sharding

    def _device_batch(self, stacked: M.MaskTree):
        n = M.stacked_len(stacked)
        n_pad, sharding = self._chunk_sharding(n)
        if n_pad > n:
            stacked = M.pad_stacked(stacked, n_pad)
        return {k: jax.device_put(np.asarray(v, dtype=np.float32), sharding)
                for k, v in stacked.items()}


class PipelinedEvaluator(ShardedEvaluator):
    """Double-buffered candidate staging (batched or sharded placement).

    ``prefetch`` chunks beyond the one being consumed stay staged: padded,
    transferred, and *dispatched*.  jax's async dispatch then overlaps chunk
    k+1's host materialization + H2D transfer with chunk k's device compute,
    which is the wall-clock the chunk-serial BatchedEvaluator leaves on the
    table.  ``mesh=None`` keeps single-device placement; passing a mesh
    layers the prefetch pipeline over ShardedEvaluator's joint
    candidate×batch layout.  Selection is unchanged versus every other
    backend: chunks are consumed in sampling order and the ADT early exit
    checks chunk k's results before chunk k+1+prefetch is committed.

    ``prefetch="auto"`` defers the depth to a :class:`PrefetchAutoTuner`:
    the run's first chunks execute in strict alternation while producer and
    consumer rates are measured, then ``prefetch_depth`` locks in for the
    rest of the run (``auto_report`` records the measurements) — the
    ROADMAP's "pick prefetch from measured rates instead of a flag".
    """

    name = "pipelined"

    def __init__(self, eval_fn: EvalFn, *, pad_to: Optional[int] = None,
                 context=None, prefetch: Union[int, str] = 1, mesh=None,
                 context_specs=None, auto_probe_chunks: int = 2,
                 auto_max_prefetch: int = 4):
        if prefetch == "auto":
            self.auto_tuner = PrefetchAutoTuner(
                n_probe=auto_probe_chunks, max_depth=auto_max_prefetch)
            prefetch = 0          # strict alternation until the probe locks
        elif isinstance(prefetch, str):
            raise ValueError(
                f"prefetch must be an int >= 0 or 'auto', got {prefetch!r}")
        elif prefetch < 0:
            raise ValueError(f"prefetch must be >= 0, got {prefetch}")
        else:
            self.auto_tuner = None
        self.auto_report: Optional[dict] = None
        if mesh is None:
            if context_specs is not None:
                raise ValueError("context_specs requires a mesh")
            BatchedEvaluator.__init__(self, eval_fn, pad_to=pad_to,
                                      context=context)
            self._mesh = None
            self._ctx_shardings = None
        else:
            ShardedEvaluator.__init__(self, eval_fn, mesh, pad_to=pad_to,
                                      context=context,
                                      context_specs=context_specs)
        self.prefetch_depth = int(prefetch)

    def _device_batch(self, stacked: M.MaskTree):
        if self._mesh is None:
            # device_put (not jnp.asarray) so the transfer is an async
            # dispatch the pipeline can run ahead of.
            return {k: jax.device_put(np.asarray(v, dtype=np.float32))
                    for k, v in stacked.items()}
        return ShardedEvaluator._device_batch(self, stacked)


def make_evaluator(
    backend: str,
    *,
    eval_acc: Optional[Callable[[M.MaskTree], float]] = None,
    eval_fn: Optional[EvalFn] = None,
    mesh=None,
    pad_to: Optional[int] = None,
    context=None,
    context_specs=None,
    prefetch: Union[int, str] = 1,
) -> CandidateEvaluator:
    """Factory: ``backend`` in {'sequential','batched','sharded','pipelined'}.

    sequential needs ``eval_acc`` (host callable); the rest need ``eval_fn``
    (traceable).  sharded defaults to a mesh over all local devices when
    ``mesh`` is None; pipelined keeps single-device placement unless a mesh
    is passed.  ``context_specs`` (see :func:`context_batch_specs`) shards
    the context over the mesh — the joint candidate×batch layout.
    ``prefetch`` is a depth or ``"auto"`` (measured-rate tuning, pipelined
    only).
    """
    if backend != "pipelined" and prefetch == "auto":
        raise ValueError(
            f"prefetch='auto' requires the pipelined backend; the "
            f"{backend!r} backend has no staging pipeline to tune "
            "(integer prefetch values are ignored as a no-op hint)")
    if backend == "sequential":
        if eval_acc is None:
            raise ValueError("sequential backend needs eval_acc")
        return SequentialEvaluator(eval_acc)
    if backend in ("batched", "sharded", "pipelined"):
        if eval_fn is None:
            raise ValueError(f"{backend} backend needs a traceable eval_fn")
    if backend == "batched":
        return BatchedEvaluator(eval_fn, pad_to=pad_to, context=context)
    if backend == "sharded":
        if mesh is None:
            from repro.launch import mesh as mesh_lib
            mesh = mesh_lib.make_candidate_mesh()
        return ShardedEvaluator(eval_fn, mesh, pad_to=pad_to,
                                context=context, context_specs=context_specs)
    if backend == "pipelined":
        return PipelinedEvaluator(eval_fn, pad_to=pad_to, context=context,
                                  prefetch=prefetch, mesh=mesh,
                                  context_specs=context_specs)
    raise ValueError(f"unknown evaluator backend {backend!r}; expected "
                     "'sequential' | 'batched' | 'sharded' | 'pipelined'")
