"""Candidate-evaluation engine for BCD (Alg. 2's hot path).

One BCD outer step evaluates up to RT candidate mask trees; the engine decides
*how*.  All backends implement the :class:`CandidateEvaluator` protocol —
``evaluate(stacked_tree) -> (n,) accuracies`` — and are interchangeable from
``run_bcd``'s point of view:

``SequentialEvaluator``
    The reference: one jitted forward per candidate, host loop.  Exactly the
    seed repo's behavior, kept for equivalence testing and tiny configs where
    vmap compile time dominates.

``BatchedEvaluator``
    Stacks the candidate axis through ``jax.vmap`` and evaluates a whole chunk
    in a single jitted call.  Masks stay jit *inputs* (no recompile across
    chunks); ragged final chunks are padded to the chunk size so the jit cache
    holds exactly one entry per (chunk, shapes) signature.

``ShardedEvaluator``
    BatchedEvaluator plus ``jax.sharding``: the candidate axis is laid out
    across the mesh (``launch.mesh``), so RT trials cost RT / n_devices
    forward passes of wall-clock.  On a 2-D ``("cand", "batch")`` mesh
    (``launch.mesh.make_cand_batch_mesh``) it picks a ``PartitionSpec`` per
    call: chunks with at least one candidate per device shard jointly over
    both axes; smaller chunks shard candidates over ``"cand"`` only and let a
    batch-sharded *context* split each forward over ``"batch"`` — no device
    idles when RT < n_devices.

``PipelinedEvaluator``
    Double-buffered staging on top of batched/sharded placement:
    :meth:`~BatchedEvaluator.stage` pads a chunk, starts its host→device
    transfer, and dispatches the vmapped computation (jax dispatch is async),
    so the trial loop (:func:`evaluate_prefetched`) materializes and stages
    chunk k+1 while the device still computes chunk k.

``SuffixEvaluator``
    Prefix-reuse (split-forward) evaluation: candidates are local mask
    edits, so for a chunk whose candidates all first differ from the base
    masks at/after one site, everything *before* that site is recomputed
    identically per candidate by the backends above.  This backend computes
    that shared prefix ONCE per (site, step) via the model's
    ``forward_prefix`` (kept device-resident, batch-sharded on a 2-D mesh so
    it never gathers) and vmaps only ``forward_suffix`` over the candidate
    axis.  Site-aware: ``core.bcd._select_block`` feeds it site-grouped
    chunks (:class:`SitedChunk`) in site-major order, and a cost model
    (``analysis.roofline.SuffixCostModel``) falls shallow-cut chunks back to
    the inner full-forward backend.

Backends must rank candidates identically: ``run_bcd`` breaks ties by first
occurrence, and all backends evaluate candidates in sampling order, so for a
given seed/config every backend selects the same block (tested in
``tests/test_bcd_parallel.py``; the site-aware path reorders *evaluation*
but replays selection in sampling order — ``tests/test_split_forward.py``).
"""
from __future__ import annotations

import collections
import functools
import statistics
import time
from typing import (Any, Callable, Dict, Iterable, Iterator, NamedTuple,
                    Optional, Protocol, Tuple, Union, runtime_checkable)

import numpy as np
import jax
import jax.numpy as jnp

from . import masks as M


def _donate_mask_arg():
    """``donate_argnums`` for the per-chunk mask stack (argument 0 of the
    vmapped eval): donating lets XLA reuse the stack's buffers, so a staged
    pipeline stops holding two live copies of every padded chunk.  CPU
    backends don't implement donation and would warn per dispatch, so the
    hint is only emitted where it can be honored."""
    return () if jax.default_backend() == "cpu" else (0,)

# eval_fn: traceable (device mask tree) -> scalar accuracy in percent.
EvalFn = Callable[[dict], jnp.ndarray]


@runtime_checkable
class CandidateEvaluator(Protocol):
    """Evaluates a *stacked* candidate mask tree -> per-candidate accuracy."""

    name: str
    # Chunk size the backend wants from run_bcd's trial loop; None defers to
    # cfg.chunk_size.  Chunking never changes selection (rng burns RT draws
    # per step regardless), so this is a pure performance hint.
    preferred_chunk: Optional[int]
    # How many chunks evaluate_prefetched may stage (transfer + dispatch)
    # ahead of the one being consumed.  0 = strict materialize -> evaluate.
    prefetch_depth: int

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        """stacked: {site: (n, *shape)} -> float64 (n,) accuracies [%]."""
        ...


class StagedChunk(NamedTuple):
    """A chunk in flight: transfer + compute dispatched, result not read."""
    n: int                  # true candidate count (before padding)
    accs: jax.Array         # (n_padded,) device array, possibly not ready


class PrefetchAutoTuner:
    """Picks a prefetch depth from measured producer/consumer rates.

    The pipeline overlaps the *producer* (host mask materialization + pad +
    H2D transfer + dispatch) with the *consumer* (blocking on the device
    result, i.e. the remaining compute).  :func:`evaluate_prefetched` runs
    the first chunks of a run in strict alternation, timing both sides; the
    first sample is discarded (it pays jit compile), and once ``n_probe``
    clean samples exist the depth is fixed for the rest of the run:

        depth = clamp(floor(consumer / producer), 1, max_depth)

    — the number of chunks the producer can stage during one consumer
    block.  Depth 1 already reaches steady-state overlap (per-chunk cost
    max(p, c)); deeper staging only buys robustness to producer jitter when
    the producer is much faster, and is capped because every staged chunk
    is wasted work on an ADT early exit.
    """

    def __init__(self, n_probe: int = 2, max_depth: int = 4):
        if n_probe < 1:
            raise ValueError(f"n_probe must be >= 1, got {n_probe}")
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.n_probe = n_probe
        self.max_depth = max_depth
        self._produce: list = []
        self._consume: list = []
        self._warmed = False      # first sample (compile) dropped
        self.done = False

    def add_sample(self, produce_s: float, consume_s: float) -> None:
        if self.done:
            return
        if not self._warmed:
            self._warmed = True
            return
        self._produce.append(produce_s)
        self._consume.append(consume_s)
        if len(self._produce) >= self.n_probe:
            self.done = True

    def depth(self) -> int:
        p = max(statistics.median(self._produce), 1e-9)
        c = statistics.median(self._consume)
        return max(1, min(self.max_depth, int(c / p)))

    def report(self) -> dict:
        return {
            "producer_s": statistics.median(self._produce),
            "consumer_s": statistics.median(self._consume),
            "prefetch": self.depth(),
            "samples": len(self._produce),
        }


def evaluate_prefetched(evaluator, chunks: Iterable[M.MaskTree]
                        ) -> Iterator[np.ndarray]:
    """Producer/consumer driver for the trial loop.

    Yields one float64 ``(n,)`` accuracy array per chunk, in chunk order.
    When the evaluator supports staging (``stage``/``evaluate_staged``, e.g.
    :class:`PipelinedEvaluator`), up to ``prefetch_depth`` chunks beyond the
    one being consumed are kept staged: their host materialization, device
    transfer, and compute dispatch all happen while earlier chunks still
    compute.  Backends without staging — or with ``prefetch_depth == 0`` —
    degrade to the strict materialize → evaluate alternation.

    The consumer may stop early (ADT exit): closing the generator drops any
    staged-but-unread chunks, and because ``chunks`` is itself pulled lazily,
    chunks beyond the staging horizon are never even materialized.  Chunk k's
    result is always yielded before chunk k+depth+1 is staged, so an early
    exit at chunk k commits at most ``depth`` chunks of wasted work.

    When the evaluator carries a live :class:`PrefetchAutoTuner`
    (``prefetch="auto"``), the first chunks run in strict alternation while
    the tuner times the producer vs the consumer; once it converges the
    evaluator's ``prefetch_depth`` is fixed for the rest of the run and the
    loop switches to staged prefetching mid-stream.  The probe phase changes
    timing only — chunk results and their order are identical.
    """
    it = iter(chunks)
    tuner = getattr(evaluator, "auto_tuner", None)
    if tuner is not None and not tuner.done and hasattr(evaluator, "stage"):
        while not tuner.done:
            t0 = time.perf_counter()
            try:
                chunk = next(it)
            except StopIteration:
                return
            staged_one = evaluator.stage(chunk)
            t1 = time.perf_counter()
            accs = evaluator.evaluate_staged(staged_one)
            t2 = time.perf_counter()
            tuner.add_sample(t1 - t0, t2 - t1)
            if tuner.done:
                evaluator.prefetch_depth = tuner.depth()
                evaluator.auto_report = tuner.report()
            yield accs
    depth = int(getattr(evaluator, "prefetch_depth", 0) or 0)
    if depth <= 0 or not hasattr(evaluator, "stage"):
        for chunk in it:
            yield evaluator.evaluate(chunk)
        return
    staged: collections.deque = collections.deque()
    exhausted = False
    while True:
        while not exhausted and len(staged) <= depth:
            try:
                staged.append(evaluator.stage(next(it)))
            except StopIteration:
                exhausted = True
        if not staged:
            return
        yield evaluator.evaluate_staged(staged.popleft())


def _with_stacked_route(eval_fn, *, fused: bool = False):
    """Trace eval_fn under linearize.stacked_kernel_route so the TPU
    hard-mask dispatch emits the custom-vmap routed op: vmapping the
    candidate axis then lowers to the stacked Pallas kernel
    (kernels.masked_act_2d_batched) instead of vmapping the per-candidate
    kernel's grid.  ``fused=True`` additionally arms
    linearize.fused_suffix_route, so models fold the masked-activation gate
    into the adjacent conv/matmul (kernels.ops fused entry points) instead
    of round-tripping the gated tensor through HBM.  Trace-time only — both
    hints are no-ops off TPU."""
    @functools.wraps(eval_fn)
    def routed(*args):
        from . import linearize
        with linearize.stacked_kernel_route():
            if fused:
                with linearize.fused_suffix_route():
                    return eval_fn(*args)
            return eval_fn(*args)
    return routed


class SequentialEvaluator:
    """Reference backend: unstack and evaluate one candidate at a time."""

    name = "sequential"
    # One candidate per chunk: evaluating a whole chunk before checking the
    # ADT exit would waste up to chunk-1 forwards on this host-loop backend.
    preferred_chunk = 1
    prefetch_depth = 0

    def __init__(self, eval_acc: Callable[[M.MaskTree], float]):
        self._eval_acc = eval_acc

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        n = M.stacked_len(stacked)
        return np.array([float(self._eval_acc(M.index_stacked(stacked, i)))
                         for i in range(n)], dtype=np.float64)


class BatchedEvaluator:
    """vmap-over-masks backend: one jitted call per chunk of candidates."""

    name = "batched"
    preferred_chunk = None
    prefetch_depth = 0

    def __init__(self, eval_fn: EvalFn, *, pad_to: Optional[int] = None,
                 context=None):
        """eval_fn: traceable single-tree accuracy (device arrays in/out).
        pad_to: pad ragged candidate axes up to this size (use the BCD
        chunk_size) so jit sees one leading-dim signature.
        context: optional pytree (e.g. model params) passed to eval_fn as a
        second argument and mapped over with in_axes=None.  It is a jit
        *input*, not a closure constant — callers that finetune params
        between outer steps update it via :meth:`set_context` and the
        compiled executable picks up the new values without retracing."""
        self._has_ctx = context is not None
        # commit the context to device once: leaving numpy leaves in the
        # tree makes every dispatch re-transfer them (and re-hash the host
        # arrays), which is pure per-chunk overhead on the hot path
        self.context = None if context is None else jax.device_put(context)
        routed = _with_stacked_route(eval_fn)
        # the mask stack (arg 0) is donated: each staged chunk's stack is a
        # fresh buffer (_device_batch copies) read by exactly one dispatch,
        # so XLA may reuse it in place of a second live copy
        if self._has_ctx:
            self._vmapped = jax.jit(jax.vmap(routed, in_axes=(0, None)),
                                    donate_argnums=_donate_mask_arg())
        else:
            self._vmapped = jax.jit(jax.vmap(routed),
                                    donate_argnums=_donate_mask_arg())
        self._pad_to = pad_to

    def set_context(self, context) -> None:
        """Swap the auxiliary context (same treedef/shapes: no recompile)."""
        if not self._has_ctx:
            raise ValueError("evaluator was built without a context")
        self.context = jax.device_put(context)

    def _device_batch(self, stacked: M.MaskTree):
        # copy=True: the stack is donated into the vmapped eval, so leaves
        # must be buffers this evaluator owns — jnp.asarray would alias a
        # caller's already-on-device float32 array and donation would
        # delete it out from under them
        return {k: jnp.array(v, dtype=jnp.float32, copy=True)
                for k, v in stacked.items()}

    # -------------------------------------------------------------- staging
    #
    # evaluate() is stage() + evaluate_staged(); splitting them lets
    # evaluate_prefetched keep later chunks' transfers AND dispatched
    # computations in flight while it blocks on an earlier chunk's result.

    def stage(self, stacked: M.MaskTree) -> StagedChunk:
        """Pad, start the host→device transfer, dispatch the computation."""
        n = M.stacked_len(stacked)
        if self._pad_to is not None and n < self._pad_to:
            stacked = M.pad_stacked(stacked, self._pad_to)
        batch = self._device_batch(stacked)
        accs = self._vmapped(batch, self.context) if self._has_ctx \
            else self._vmapped(batch)
        return StagedChunk(n, accs)

    def evaluate_staged(self, staged: StagedChunk) -> np.ndarray:
        """Block on a staged chunk's result and strip its padding."""
        return np.asarray(staged.accs, dtype=np.float64)[:staged.n]

    def evaluate(self, stacked: M.MaskTree) -> np.ndarray:
        return self.evaluate_staged(self.stage(stacked))


def effective_chunk(evaluator, chunk_size: int) -> int:
    """The chunk size the trial loop actually uses: backends may cap it via
    ``preferred_chunk`` (SequentialEvaluator wants 1 so the ADT exit never
    pays for unevaluated chunk-mates).  Shared by ``bcd._select_block`` and
    the throughput benchmark so both drive the same loop."""
    return min(chunk_size,
               getattr(evaluator, "preferred_chunk", None) or chunk_size)


def context_batch_specs(context: dict, *, batch_key: str = "batch",
                        axis: str = "batch") -> dict:
    """PartitionSpec tree for an evaluator context dict: leaves under
    ``context[batch_key]`` shard their leading axis over mesh axis ``axis``
    (the axis size must divide their leading dim, e.g. batch 16 over a
    2-device axis); every other leaf replicates.  Feed the result to
    ShardedEvaluator(context_specs=...)."""
    from jax.sharding import PartitionSpec as P
    return {k: jax.tree.map(lambda _: P(axis) if k == batch_key else P(), v)
            for k, v in context.items()}


class ShardedEvaluator(BatchedEvaluator):
    """Batched backend with the candidate axis sharded across a mesh.

    1-D mesh (``make_candidate_mesh``): every axis contributes to the
    candidate sharding (pure candidate-parallel); counts pad up to the device
    count.  2-D ``("cand", "batch")`` mesh (``make_cand_batch_mesh``): the
    spec is chosen *per call* by padded per-device work — chunks big enough
    to give every device a candidate shard jointly over both axes; smaller
    chunks shard over ``"cand"`` only, and a ``context_specs``-sharded eval
    batch splits each candidate's forward across ``"batch"``.
    """

    name = "sharded"

    def __init__(self, eval_fn: EvalFn, mesh, *, pad_to: Optional[int] = None,
                 context=None, context_specs=None):
        super().__init__(eval_fn, pad_to=pad_to, context=context)
        from jax.sharding import NamedSharding, PartitionSpec as P
        self._mesh = mesh
        axes = tuple(mesh.axis_names)
        self._n_dev = int(np.prod([mesh.shape[a] for a in axes]))
        cand_axes = tuple(a for a in axes if a != "batch") or axes
        self._cand = int(np.prod([mesh.shape[a] for a in cand_axes]))
        self._joint_sharding = NamedSharding(mesh, P(axes))
        self._cand_sharding = NamedSharding(mesh, P(cand_axes))
        self._ctx_shardings = None
        if context_specs is not None:
            if context is None:
                raise ValueError("context_specs given without a context")
            self._ctx_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), context_specs,
                is_leaf=lambda x: isinstance(x, P))
            self.context = jax.device_put(context, self._ctx_shardings)

    def set_context(self, context) -> None:
        if self._ctx_shardings is not None:
            context = jax.device_put(context, self._ctx_shardings)
        super().set_context(context)

    def _chunk_sharding(self, n: int):
        """Per-call layout: (padded candidate count, NamedSharding).

        Minimize padded per-device work: the joint layout costs
        ceil(n / n_dev) candidate-forwards per device; the cand-only layout
        costs ceil(n / cand) forwards over 1/batch of the eval batch each.
        Ties prefer joint (no cross-device reduction inside a forward)."""
        batch_ax = self._n_dev // self._cand
        joint_cost = -(-n // self._n_dev)
        split_cost = -(-n // self._cand) / batch_ax
        if joint_cost <= split_cost:
            return n + (-n % self._n_dev), self._joint_sharding
        return n + (-n % self._cand), self._cand_sharding

    def _device_batch(self, stacked: M.MaskTree):
        n = M.stacked_len(stacked)
        n_pad, sharding = self._chunk_sharding(n)
        if n_pad > n:
            stacked = M.pad_stacked(stacked, n_pad)
        return {k: jax.device_put(np.asarray(v, dtype=np.float32), sharding)
                for k, v in stacked.items()}


class PipelinedEvaluator(ShardedEvaluator):
    """Double-buffered candidate staging (batched or sharded placement).

    ``prefetch`` chunks beyond the one being consumed stay staged: padded,
    transferred, and *dispatched*.  jax's async dispatch then overlaps chunk
    k+1's host materialization + H2D transfer with chunk k's device compute,
    which is the wall-clock the chunk-serial BatchedEvaluator leaves on the
    table.  ``mesh=None`` keeps single-device placement; passing a mesh
    layers the prefetch pipeline over ShardedEvaluator's joint
    candidate×batch layout.  Selection is unchanged versus every other
    backend: chunks are consumed in sampling order and the ADT early exit
    checks chunk k's results before chunk k+1+prefetch is committed.

    ``prefetch="auto"`` defers the depth to a :class:`PrefetchAutoTuner`:
    the run's first chunks execute in strict alternation while producer and
    consumer rates are measured, then ``prefetch_depth`` locks in for the
    rest of the run (``auto_report`` records the measurements) — the
    ROADMAP's "pick prefetch from measured rates instead of a flag".
    """

    name = "pipelined"

    def __init__(self, eval_fn: EvalFn, *, pad_to: Optional[int] = None,
                 context=None, prefetch: Union[int, str] = 1, mesh=None,
                 context_specs=None, auto_probe_chunks: int = 2,
                 auto_max_prefetch: int = 4):
        if prefetch == "auto":
            self.auto_tuner = PrefetchAutoTuner(
                n_probe=auto_probe_chunks, max_depth=auto_max_prefetch)
            prefetch = 0          # strict alternation until the probe locks
        elif isinstance(prefetch, str):
            raise ValueError(
                f"prefetch must be an int >= 0 or 'auto', got {prefetch!r}")
        elif prefetch < 0:
            raise ValueError(f"prefetch must be >= 0, got {prefetch}")
        else:
            self.auto_tuner = None
        self.auto_report: Optional[dict] = None
        if mesh is None:
            if context_specs is not None:
                raise ValueError("context_specs requires a mesh")
            BatchedEvaluator.__init__(self, eval_fn, pad_to=pad_to,
                                      context=context)
            self._mesh = None
            self._ctx_shardings = None
        else:
            ShardedEvaluator.__init__(self, eval_fn, mesh, pad_to=pad_to,
                                      context=context,
                                      context_specs=context_specs)
        self.prefetch_depth = int(prefetch)

    def _device_batch(self, stacked: M.MaskTree):
        if self._mesh is None:
            # device_put (not jnp.asarray) so the transfer is an async
            # dispatch the pipeline can run ahead of.
            return {k: jax.device_put(np.asarray(v, dtype=np.float32))
                    for k, v in stacked.items()}
        return ShardedEvaluator._device_batch(self, stacked)


# ----------------------------------------------------- prefix-reuse backend


class SplitEval(NamedTuple):
    """A model's split-forward closure bundle (``make_suffix_eval_fns``).

    ``prefix(site, masks, ctx) -> cached`` and
    ``suffix(site, masks, cached, ctx) -> acc[%]`` satisfy the trace-time
    contract ``suffix(site, m, prefix(site, m, x)) == full(m)`` bitwise for
    every site; ``site`` is Python-level (static) — the evaluator compiles
    one prefix/suffix pair per cut segment.

    ``prefix_ext(from_site, to_site, masks, cached, ctx) -> cached`` extends
    an already-computed prefix by only the segments between the two cuts,
    satisfying ``prefix_ext(a, b, m, prefix(a, m, x)) == prefix(b, m, x)``
    (same fold over the same segment list, so the composition is exact under
    one jit; across jit boundaries the segment outputs are materialized f32
    either way).  Optional: ``None`` disables incremental extension and the
    trie recomputes every prefix from the input.

    ``pre(ctx) -> pre_act`` is the *mask-independent head* of the network
    (input to the first gate's pre-activation — e.g. the stem conv+bn, or
    the LM embed fold).  It depends only on the context, never on candidate
    masks, so the evaluator computes it ONCE per context and ships it inside
    the context as ``ctx["pre"]``; ``full`` then resumes from it, sparing
    every fallback candidate the recompute (``full(m, {**ctx, "pre":
    pre(ctx)}) == full(m, ctx)`` bitwise — the depth-0 analogue of the
    prefix-trie contract).  Optional: ``None`` keeps ``full`` folding from
    the raw input.

    ``site_repeats`` (site -> R) marks mask sites whose (R, ·) array spans
    R consecutive per-repeat cut segments starting at the site's
    ``site_segment`` entry — scanned-stack sites with carry-checkpointed
    per-repeat cuts (models.lm).  ``site_order``/``site_segment`` then also
    carry *virtual* repeat-qualified names (``"s0.ffn@r"`` at segment
    base+r) addressing the per-repeat cuts; ``suffix_sites`` keeps
    returning real mask names only (they key candidate tree slices).
    Grouping resolves each candidate coordinate's repeat row
    arithmetically (``masks.group_blocks_by_site`` ``repeat_sites=``), and
    :meth:`SuffixEvaluator.begin_step` diffs such sites per repeat row so
    trie entries at earlier repeats survive deep-repeat base edits.
    Optional: ``None`` means every site owns exactly one segment.
    """
    prefix: Callable[..., Any]
    suffix: Callable[..., Any]
    full: EvalFn                       # (masks, ctx) -> acc: fallback path
    site_order: Tuple[str, ...]        # topological site order
    site_segment: Dict[str, int]       # site -> cut segment (prefix key)
    suffix_sites: Callable[[str], Tuple[str, ...]]
    prefix_fraction: Dict[str, float]  # site -> fwd-FLOP fraction above it
    prefix_ext: Optional[Callable[..., Any]] = None
    pre: Optional[Callable[..., Any]] = None
    site_repeats: Optional[Dict[str, int]] = None


class SitedChunk(NamedTuple):
    """A candidate chunk annotated with its shared cut site.

    ``site is None`` routes the chunk down the full-forward fallback (the
    cost model declined suffix mode, or the caller had no site info)."""
    site: Optional[str]
    stacked: M.MaskTree


def tree_nbytes(tree) -> int:
    """Total device bytes of a pytree's leaves (global logical bytes for
    sharded arrays — the trie budget is a per-model-replica figure)."""
    return int(sum(np.asarray(leaf).nbytes if not hasattr(leaf, "nbytes")
                   else leaf.nbytes for leaf in jax.tree.leaves(tree)))


class PrefixTrie:
    """Byte-budgeted cache of device-resident prefix activations, keyed by
    cut-segment depth.

    Because every segment has exactly one successor, the "trie" of prefixes
    is a chain: the entry at depth ``d`` is the fold of segments ``[0, d)``
    and is an ancestor of every entry at depth > d.  :meth:`lookup` returns
    the *deepest* cached entry at or above a requested depth, so a chunk
    cutting at ``d`` either hits exactly (reuse), hits an ancestor (extend by
    the segments in between — ``SplitEval.prefix_ext``), or misses (compute
    from the input).

    Eviction is LRU with a site-major (shallow-first) tie-break, bounded by
    ``budget_bytes``: after every insert the total strictly respects the
    budget, evicting least-recently-used entries first and the just-inserted
    entry last (an entry that alone exceeds the budget is dropped too — the
    caller still holds the returned reference for its in-flight dispatches).
    ``budget_bytes=None`` disables eviction.  Counters (``hits`` /
    ``extensions`` / ``misses`` / ``evictions``) feed the bench report.
    """

    def __init__(self, budget_bytes: Optional[int] = None):
        if budget_bytes is not None and budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        self.budget_bytes = budget_bytes
        self._entries: Dict[int, Any] = {}
        self._nbytes: Dict[int, int] = {}
        self._tick: Dict[int, int] = {}
        self._clock = 0
        self.hits = 0
        self.extensions = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, depth: int) -> bool:
        return depth in self._entries

    def depths(self) -> Tuple[int, ...]:
        return tuple(sorted(self._entries))

    def items(self):
        return self._entries.items()

    def total_bytes(self) -> int:
        return sum(self._nbytes.values())

    def lookup(self, depth: int) -> Optional[Tuple[int, Any]]:
        """Deepest cached ancestor at depth <= ``depth`` -> (depth, cached),
        or None.  Touches the entry's LRU tick."""
        live = [d for d in self._entries if d <= depth]
        if not live:
            return None
        d = max(live)
        self._clock += 1
        self._tick[d] = self._clock
        return d, self._entries[d]

    def insert(self, depth: int, cached, nbytes: Optional[int] = None) -> None:
        self._entries[depth] = cached
        self._nbytes[depth] = tree_nbytes(cached) if nbytes is None else nbytes
        self._clock += 1
        self._tick[depth] = self._clock
        self._evict(newest=depth)

    def keep_where(self, pred: Callable[[int], bool]) -> None:
        """Drop every entry whose depth fails ``pred`` (cross-step
        invalidation: keep depths unaffected by changed base masks)."""
        for d in [d for d in self._entries if not pred(d)]:
            self._drop(d)

    def clear(self) -> None:
        self._entries.clear()
        self._nbytes.clear()
        self._tick.clear()

    def _drop(self, depth: int) -> None:
        del self._entries[depth]
        del self._nbytes[depth]
        del self._tick[depth]

    def _evict(self, newest: int) -> None:
        if self.budget_bytes is None:
            return
        while self.total_bytes() > self.budget_bytes:
            victims = sorted((d for d in self._entries if d != newest),
                             key=lambda d: (self._tick[d], d))
            victim = victims[0] if victims else newest
            self._drop(victim)
            self.evictions += 1
            if victim == newest:
                break


class SuffixEvaluator:
    """Prefix-reuse backend: one shared prefix per (site, step), vmapped
    suffix per candidate.

    The trial loop (``core.bcd._select_block``) calls :meth:`begin_step`
    with the step's base masks, then feeds :class:`SitedChunk`\\ s grouped
    site-major (``plan_sited_chunks``).  For each chunk the cut segment's
    prefix comes from a :class:`PrefixTrie` of device-resident activations:
    an exact-depth hit is reused outright; otherwise the deepest cached
    *ancestor* is extended by only the segments between its depth and the
    cut (``SplitEval.prefix_ext``), so consuming chunks shallow-to-deep
    turns the step's prefix work into one incremental pass over the network
    instead of one full prefix per segment.  Candidates never mutate sites
    above their cut, so prefixes depend only on the step's *base* masks —
    which also lets entries survive across outer steps: :meth:`begin_step`
    diffs the new base tree against the old one and keeps every entry whose
    depth is at or above no changed site (selective invalidation).  Entries
    stay batch-sharded on a 2-D ``("cand", "batch")`` mesh — lookup,
    extension, and eviction never gather them.  Residency is bounded by
    ``trie_budget_bytes`` (LRU, site-major tie-break).  Suffix dispatches
    ship only the *suffix-site* mask slices (sharded over ``"cand"``), so
    deep-site chunks also transfer a fraction of the mask bytes.

    Plain (un-sited) chunks and cost-model fallbacks delegate to an inner
    :class:`PipelinedEvaluator` sharing the same context/placement, so this
    backend composes batched / sharded / pipelined behavior: ``prefetch``
    staging works identically for sited chunks (stage = slice + pad +
    transfer + dispatch suffix), and ``prefetch="auto"`` hands the depth to
    the inner pipeline's :class:`PrefetchAutoTuner` (measured producer vs
    consumer rates — locks 0 where overlap can't help, >0 where it does).
    The fallback pipeline is built once and kept warm: consecutive fallback
    chunks reuse its jit executable and its device-committed context — no
    per-chunk re-staging cost.  When the model provides ``SplitEval.pre``
    (the mask-independent head fold — stem conv+bn / embed), it is computed
    once per context and shipped as ``ctx["pre"]``, so even fallback
    candidates skip the head recompute: the depth-0 analogue of the prefix
    trie.

    ``fused_kernels`` traces the suffix jits under
    ``linearize.fused_suffix_route`` so TPU hard-mask sites fuse the gate
    into the adjacent conv/matmul (kernels.ops fused entry points); inert
    off-TPU, where dispatch falls through to the reference path.
    """

    name = "suffix"
    site_aware = True
    preferred_chunk = None

    def __init__(self, split: SplitEval, *, pad_to: Optional[int] = None,
                 context=None, mesh=None, context_specs=None,
                 prefetch: int = 0, cost_model=None,
                 trie_budget_bytes: Optional[int] = None,
                 fused_kernels: bool = True):
        if not isinstance(context, dict) or "params" not in context \
                or "batch" not in context:
            raise ValueError(
                "SuffixEvaluator needs context={'params': …, 'batch': …} — "
                "prefix and suffix consume the eval batch and params as jit "
                "inputs (models' make_suffix_eval_fns contract)")
        if cost_model is None:
            from repro.analysis.roofline import SuffixCostModel
            cost_model = SuffixCostModel()
        self._split = split
        self.cost_model = cost_model
        self.fused_kernels = bool(fused_kernels)
        self._pad_to = pad_to
        self._mesh = mesh
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            axes = tuple(mesh.axis_names)
            cand_axes = tuple(a for a in axes if a != "batch") or axes
            self._cand = int(np.prod([mesh.shape[a] for a in cand_axes]))
            self._cand_sharding = NamedSharding(mesh, P(cand_axes))
            self._cache_sharding = NamedSharding(
                mesh, P("batch") if "batch" in axes else P())
        # mask-independent head fold (SplitEval.pre): computed once per
        # context and shipped INSIDE the inner context, so every fallback
        # full-forward resumes from it instead of re-tracing the stem/embed
        self._pre_jit = None if split.pre is None else jax.jit(split.pre)
        context = self._with_pre(context)
        if context_specs is not None and "pre" in context:
            from jax.sharding import PartitionSpec as P
            axes = tuple(mesh.axis_names) if mesh is not None else ()
            spec = P("batch") if "batch" in axes else P()
            context_specs = {**context_specs,
                             "pre": jax.tree.map(lambda _: spec,
                                                 context["pre"])}
        # prefetch passes straight through (including "auto": the inner
        # pipeline owns the PrefetchAutoTuner; this evaluator mirrors its
        # prefetch_depth/auto_report so evaluate_prefetched's probe loop
        # drives the tuner through the suffix staging protocol)
        self._inner = PipelinedEvaluator(
            split.full, pad_to=pad_to, context=context,
            prefetch=prefetch, mesh=mesh, context_specs=context_specs)
        # one representative site per segment: sites cutting at the same
        # segment share the prefix cache entry and the prefix/suffix jits
        self._segment_site: Dict[int, str] = {}
        for s in split.site_order:
            self._segment_site.setdefault(split.site_segment[s], s)
        self._prefix_jits: Dict[int, Callable] = {}
        self._prefix_ext_jits: Dict[Tuple[int, int], Callable] = {}
        self._suffix_jits: Dict[int, Callable] = {}
        self.trie = PrefixTrie(budget_bytes=trie_budget_bytes)
        self._base_masks: Optional[M.MaskTree] = None
        self._base_dev: Optional[dict] = None   # device copy, lazy per step

    def _with_pre(self, context):
        """Augment a raw context with the mask-independent head fold
        (``ctx["pre"]``), batch-sharded under a mesh like the trie cache —
        suffix/prefix closures ignore the extra key; ``split.full`` resumes
        from it."""
        if self._pre_jit is None:
            return context
        pre = self._pre_jit(context)
        if self._mesh is not None:
            pre = jax.device_put(pre, self._cache_sharding)
        return {**context, "pre": pre}

    # the inner pipeline owns the staging depth and (for prefetch="auto")
    # the tuner; mirroring them as properties lets evaluate_prefetched
    # treat this evaluator exactly like a PipelinedEvaluator
    @property
    def prefetch_depth(self) -> int:
        return self._inner.prefetch_depth

    @prefetch_depth.setter
    def prefetch_depth(self, depth) -> None:
        self._inner.prefetch_depth = int(depth)

    @property
    def auto_tuner(self):
        return self._inner.auto_tuner

    @property
    def auto_report(self):
        return self._inner.auto_report

    @auto_report.setter
    def auto_report(self, report) -> None:
        self._inner.auto_report = report

    # context lives on the inner evaluator (single source of truth; it owns
    # the device placement / context_specs resharding)
    @property
    def context(self):
        return self._inner.context

    def set_context(self, context) -> None:
        """Swap params/batch context; cached prefixes are invalidated (they
        were computed from the old params/batch) and the mask-independent
        head fold is recomputed from the new context."""
        self._inner.set_context(self._with_pre(context))
        self.trie.clear()

    def begin_step(self, base_masks: M.MaskTree) -> None:
        """Fix the outer step's base mask tree (what prefixes are computed
        from) and selectively invalidate the trie.  The trial loop calls
        this once per step, before any sited chunk is staged.

        A trie entry at depth ``d`` folds segments ``[0, d)``, so it reads
        exactly the base masks of sites with segment < d: diffing the new
        base tree against the previous step's, entries with
        ``d <= min(changed segments)`` are still byte-identical prefixes and
        survive.  A BCD step that only flipped coordinates at/below the
        deepest cut (the common case late in a sweep) therefore keeps its
        whole chain warm.

        Sites in ``SplitEval.site_repeats`` (scanned-stack masks spanning R
        per-repeat segments) are diffed per repeat ROW: the effective
        changed segment is the site's base segment plus the first repeat
        row that differs, so a base edit at repeat r keeps every carry
        checkpoint at repeats <= r warm instead of flushing the whole
        stack's chain."""
        new = {k: np.asarray(v, dtype=np.float32)
               for k, v in base_masks.items()}
        if self._base_masks is None or set(new) != set(self._base_masks):
            self.trie.clear()
        elif len(self.trie):
            reps = self._split.site_repeats or {}
            changed = []
            for k in new:
                if np.array_equal(new[k], self._base_masks[k]):
                    continue
                seg = self._split.site_segment[k]
                rk = int(reps.get(k, 1))
                if rk > 1:
                    rows = np.any(new[k].reshape(rk, -1)
                                  != self._base_masks[k].reshape(rk, -1),
                                  axis=1)
                    seg += int(np.flatnonzero(rows)[0])
                changed.append(seg)
            if changed:
                min_seg = min(changed)
                self.trie.keep_where(lambda d: d <= min_seg)
        self._base_masks = new
        self._base_dev = None

    def prefix_fraction(self, site: str) -> float:
        return self._split.prefix_fraction[site]

    # ----------------------------------------------------------- internals

    def _base_masks_dev(self) -> dict:
        if self._base_masks is None:
            raise RuntimeError(
                "SuffixEvaluator.begin_step(base_masks) must be called "
                "before sited evaluation (the prefix needs the step's base "
                "mask tree)")
        if self._base_dev is None:
            self._base_dev = {k: jnp.asarray(v)
                              for k, v in self._base_masks.items()}
        return self._base_dev

    def covered_fraction(self, site: str) -> float:
        """Prefix-FLOP fraction already resident in the trie for a cut at
        ``site``'s segment — the planner prices suffix mode with only the
        *incremental* prefix cost (cut fraction minus this)."""
        seg = self._split.site_segment[site]
        live = [d for d in self.trie.depths() if d <= seg]
        if not live:
            return 0.0
        anc_site = self._segment_site.get(max(live))
        if anc_site is None:
            return 0.0
        return self._split.prefix_fraction[anc_site]

    def _pin(self, cached):
        if self._mesh is not None:
            # pin the cache batch-sharded: suffix dispatches read it in
            # place (in_axes=None) — it is never gathered across "batch"
            return jax.device_put(cached, self._cache_sharding)
        return cached

    def _prefix_for(self, site: str):
        seg = self._split.site_segment[site]
        hit = self.trie.lookup(seg)
        if hit is not None and hit[0] == seg:
            self.trie.hits += 1
            return hit[1]
        base = self._base_masks_dev()
        if hit is not None and self._split.prefix_ext is not None:
            # deepest-ancestor extension: fold only segments [hit_depth, seg)
            from_seg, ancestor = hit
            key = (from_seg, seg)
            jit_fn = self._prefix_ext_jits.get(key)
            if jit_fn is None:
                jit_fn = jax.jit(functools.partial(
                    self._split.prefix_ext, self._segment_site[from_seg],
                    self._segment_site[seg]))
                self._prefix_ext_jits[key] = jit_fn
            cached = self._pin(jit_fn(base, ancestor, self.context))
            self.trie.extensions += 1
        else:
            jit_fn = self._prefix_jits.get(seg)
            if jit_fn is None:
                jit_fn = jax.jit(functools.partial(
                    self._split.prefix, self._segment_site[seg]))
                self._prefix_jits[seg] = jit_fn
            cached = self._pin(jit_fn(base, self.context))
            self.trie.misses += 1
        self.trie.insert(seg, cached)
        return cached

    def _suffix_for(self, site: str):
        seg = self._split.site_segment[site]
        jit_fn = self._suffix_jits.get(seg)
        if jit_fn is None:
            routed = _with_stacked_route(
                functools.partial(self._split.suffix,
                                  self._segment_site[seg]),
                fused=self.fused_kernels)
            # masks stack donated, prefix cache and context read-only
            jit_fn = jax.jit(jax.vmap(routed, in_axes=(0, None, None)),
                             donate_argnums=_donate_mask_arg())
            self._suffix_jits[seg] = jit_fn
        return jit_fn

    def _stage_sited(self, site: str, stacked: M.MaskTree) -> StagedChunk:
        n = M.stacked_len(stacked)
        # ship only the masks the suffix consumes (sites at/after the cut)
        sub = {k: stacked[k] for k in self._split.suffix_sites(site)}
        n_pad = max(n, self._pad_to or 0)
        if self._mesh is not None:
            n_pad += -n_pad % self._cand
        if n_pad > n:
            sub = M.pad_stacked(sub, n_pad)
        put = (jax.device_put if self._mesh is None else
               functools.partial(jax.device_put,
                                 device=self._cand_sharding))
        batch = {k: put(np.asarray(v, dtype=np.float32))
                 for k, v in sub.items()}
        cached = self._prefix_for(site)
        accs = self._suffix_for(site)(batch, cached, self.context)
        return StagedChunk(n, accs)

    # ------------------------------------------------------------- protocol

    def stage(self, item) -> StagedChunk:
        """Stage a chunk: ``SitedChunk`` with a site takes the suffix path;
        everything else (plain stacked trees, cost-model fallbacks) stages
        on the inner full-forward pipeline."""
        if isinstance(item, SitedChunk):
            if item.site is None:
                return self._inner.stage(item.stacked)
            return self._stage_sited(item.site, item.stacked)
        return self._inner.stage(item)

    def evaluate_staged(self, staged: StagedChunk) -> np.ndarray:
        return self._inner.evaluate_staged(staged)

    def evaluate(self, item) -> np.ndarray:
        return self.evaluate_staged(self.stage(item))


def plan_sited_chunks(evaluator: SuffixEvaluator, indices, layout: list,
                      chunk_size: int):
    """Site-major evaluation plan for the suffix backend.

    ``indices`` is either an (n, k) flat-coordinate array
    (``masks.sample_removal_indices``) or a list of typed
    :class:`masks.Move` candidates (``masks.sample_moves``).

    Returns ``(order, chunks)``: ``order`` is a permutation of candidate
    positions — grouped by the *cut segment* of each candidate's earliest
    touched site, sampling order preserved within a group — and ``chunks``
    is ``[(site | None, start, stop)]`` bounds into ``order``.  Sited
    chunks never straddle a group, so every sited chunk shares one prefix;
    groups are emitted depth-ascending, so the trie extends each prefix
    from its predecessor instead of recomputing from the input (the trie
    locality ``core.bcd._scan_sited`` relies on).  Multi-site moves (swap /
    share / add_back) group by the *shallowest* site they touch — over
    off ∪ on ∪ tie (``masks.group_moves_by_site``) — because a cached
    prefix is only reusable if it reads none of the candidate's edited
    masks.  Scanned-stack sites with per-repeat cuts
    (``SplitEval.site_repeats``) resolve each coordinate to its repeat
    row's segment, so a candidate editing only repeat r cuts at r's carry
    checkpoint instead of the whole stack's entry.
    ``site is None`` marks chunks the cost model sent down the
    full-forward fallback (shallow cut or undersized chunk); runs of
    adjacent fallback chunks are coalesced back up to ``chunk_size``
    (``masks.coalesce_fallback_chunks``) so a fragmented depth mix doesn't
    degrade the inner pipeline into ragged dispatches.

    Suffix-vs-fallback pricing is trie-aware: the cost model sees the cut's
    prefix fraction *and* the fraction already resident in the trie
    (``SuffixEvaluator.covered_fraction``), so a warm trie makes suffix
    mode cheaper than the analytic cold-start estimate.  The plan must be
    built after :meth:`SuffixEvaluator.begin_step` — surviving entries are
    part of the price."""
    split = evaluator._split
    if isinstance(indices, (list, tuple)):
        order, groups = M.group_moves_by_site(indices, layout,
                                              split.site_segment,
                                              repeat_sites=split.site_repeats)
    else:
        order, groups = M.group_blocks_by_site(
            indices, layout, split.site_segment,
            repeat_sites=split.site_repeats)
    raw = []
    planned_cover = 0.0   # prefixes earlier planned chunks will have cached
    for seg, g0, g1 in groups:
        site = evaluator._segment_site.get(seg)
        frac = split.prefix_fraction[site] if site is not None else 0.0
        covered = 0.0
        if site is not None:
            covered = min(max(evaluator.covered_fraction(site),
                              planned_cover), frac)
        group_sited = False
        for s, e in M.chunk_bounds(g1 - g0, chunk_size):
            n = e - s
            use = site is not None and \
                evaluator.cost_model.use_suffix(frac, n, covered)
            group_sited = group_sited or use
            raw.append((site if use else None, g0 + s, g0 + e))
        if group_sited:
            planned_cover = max(planned_cover, frac)
    return order, M.coalesce_fallback_chunks(raw, chunk_size)


def materialize_sited(flat: np.ndarray, layout: list, indices,
                      order: np.ndarray, chunks) -> Iterator[SitedChunk]:
    """Lazy :class:`SitedChunk` producer over a ``plan_sited_chunks`` plan
    (the site-aware counterpart of ``masks.materialize_chunks`` — same
    laziness contract: the prefetch pipeline pulls it, early exit closes
    it).  ``indices`` matches ``plan_sited_chunks``: an (n, k) removal
    array or a list of typed ``masks.Move`` candidates."""
    typed = isinstance(indices, (list, tuple))
    for site, s, e in chunks:
        sel = order[s:e]
        if typed:
            stacked = M.materialize_moves_from_flat(
                flat, layout, [indices[int(i)] for i in sel])
        else:
            stacked = M.materialize_from_flat(flat, layout, indices[sel])
        yield SitedChunk(site, stacked)


def make_evaluator(
    backend: str,
    *,
    eval_acc: Optional[Callable[[M.MaskTree], float]] = None,
    eval_fn: Optional[EvalFn] = None,
    mesh=None,
    pad_to: Optional[int] = None,
    context=None,
    context_specs=None,
    prefetch: Union[int, str] = 1,
    split: Optional[SplitEval] = None,
    cost_model=None,
    trie_budget_bytes: Optional[int] = None,
    fused_kernels: bool = True,
) -> CandidateEvaluator:
    """Factory: ``backend`` in {'sequential','batched','sharded',
    'pipelined','suffix'}.

    sequential needs ``eval_acc`` (host callable); batched/sharded/pipelined
    need ``eval_fn`` (traceable); suffix needs ``split`` (the model's
    ``make_suffix_eval_fns()`` bundle) plus a ``context`` carrying params
    AND the eval batch.  sharded defaults to a mesh over all local devices
    when ``mesh`` is None; pipelined/suffix keep single-device placement
    unless a mesh is passed.  ``context_specs`` (see
    :func:`context_batch_specs`) shards the context over the mesh — the
    joint candidate×batch layout.  ``prefetch`` is a depth or ``"auto"``
    (measured-rate tuning; pipelined and suffix).  ``cost_model`` overrides
    the
    suffix backend's per-site fallback policy; ``trie_budget_bytes`` bounds
    its prefix-trie residency and ``fused_kernels`` gates the fused TPU
    suffix megakernels (both suffix-only).
    """
    if backend not in ("pipelined", "suffix") and prefetch == "auto":
        raise ValueError(
            f"prefetch='auto' requires a staging pipeline (pipelined or "
            f"suffix backend); the {backend!r} backend has none to tune "
            "(integer prefetch values are ignored as a no-op hint)")
    if backend == "sequential":
        if eval_acc is None:
            raise ValueError("sequential backend needs eval_acc")
        return SequentialEvaluator(eval_acc)
    if backend == "suffix":
        if split is None:
            raise ValueError("suffix backend needs split= — the model's "
                             "make_suffix_eval_fns() bundle")
        return SuffixEvaluator(split, pad_to=pad_to, context=context,
                               mesh=mesh, context_specs=context_specs,
                               prefetch=prefetch, cost_model=cost_model,
                               trie_budget_bytes=trie_budget_bytes,
                               fused_kernels=fused_kernels)
    if backend in ("batched", "sharded", "pipelined"):
        if eval_fn is None:
            raise ValueError(f"{backend} backend needs a traceable eval_fn")
    if backend == "batched":
        return BatchedEvaluator(eval_fn, pad_to=pad_to, context=context)
    if backend == "sharded":
        if mesh is None:
            from repro.launch import mesh as mesh_lib
            mesh = mesh_lib.make_candidate_mesh()
        return ShardedEvaluator(eval_fn, mesh, pad_to=pad_to,
                                context=context, context_specs=context_specs)
    if backend == "pipelined":
        return PipelinedEvaluator(eval_fn, pad_to=pad_to, context=context,
                                  prefetch=prefetch, mesh=mesh,
                                  context_specs=context_specs)
    raise ValueError(f"unknown evaluator backend {backend!r}; expected "
                     "'sequential' | 'batched' | 'sharded' | 'pipelined' | "
                     "'suffix'")
