"""SNL — Selective Network Linearization (Cho et al., ICML 2022).

The paper's main baseline AND the recommended starting point for BCD
(B_ref checkpoints).  Learns real-valued per-site mask parameters α jointly
with θ under  CE + λ·||α||₁  (the L1 relaxation of Eq. 1), with the λ←κ·λ
correction schedule the paper's appendix analyzes, then hard-thresholds to the
target budget and finetunes — reproducing the "threshold cliff" that motivates
BCD.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import optimizer as opt_lib
from . import masks as M


@dataclasses.dataclass
class SNLConfig:
    b_target: int
    lam0: float = 1e-4            # initial lasso coefficient λ₀
    kappa: float = 1.2            # λ ← κ·λ when sparsification stalls
    stall_delta: int = 0          # "stalled" = fewer ReLUs dropped than this
    alpha_threshold: float = 1e-2  # binarization threshold for budget counting
    epochs: int = 30
    steps_per_epoch: int = 20
    lr: float = 1e-3
    finetune_steps: int = 100
    seed: int = 0


@dataclasses.dataclass
class SNLResult:
    params: object
    masks: M.MaskTree             # hard binary masks at exactly b_target
    alphas: Dict[str, np.ndarray]  # final soft masks (pre-threshold)
    snapshots: List[M.MaskTree]   # binarized masks per epoch (Fig. 6 analysis)
    budget_per_epoch: List[int]
    lam_per_epoch: List[float]

    def stage_init(self) -> dict:
        """This result as a BCD warm-start (the paper's B_ref checkpoint),
        in the shared stage-init layout ``core.runner.save_stage_init``
        persists: SNL and AutoReP emit the same {kind, masks, params, aux}
        shape, so a budget sweep can descend from either."""
        return {"kind": "snl", "masks": self.masks, "params": self.params,
                "aux": {"alphas": self.alphas}}


def run_snl(
    params,
    alphas: Dict[str, jnp.ndarray],
    loss_fn: Callable,            # (params, alphas, batch, soft) -> (loss, acc)
    batches: Callable[[int], object],   # step -> batch
    cfg: SNLConfig,
    *,
    verbose: bool = False,
) -> SNLResult:
    opt = opt_lib.sgd(lr=cfg.lr, momentum=0.9,
                      schedule=opt_lib.cosine(cfg.lr, cfg.epochs *
                                              cfg.steps_per_epoch))

    def train_loss(both, batch, lam):
        p, a = both
        loss, _acc = loss_fn(p, a, batch, True)
        l1 = sum(jnp.sum(jnp.abs(v)) for v in a.values())
        return loss + lam * l1

    @jax.jit
    def step(both, ostate, batch, lam):
        grads = jax.grad(train_loss)(both, batch, lam)
        updates, ostate = opt.update(grads, ostate, both)
        p, a = opt_lib.apply_updates(both, updates)
        a = {k: jnp.clip(v, 0.0, 1.0) for k, v in a.items()}
        return (p, a), ostate

    both = (params, {k: jnp.asarray(v) for k, v in alphas.items()})
    ostate = opt.init(both)
    lam = cfg.lam0
    snapshots, budgets, lams = [], [], []
    prev_budget = None
    it = 0
    for epoch in range(cfg.epochs):
        for _ in range(cfg.steps_per_epoch):
            both, ostate = step(both, ostate, batches(it), lam)
            it += 1
        a_host = {k: np.asarray(v) for k, v in both[1].items()}
        hard = {k: (v > cfg.alpha_threshold).astype(np.float32)
                for k, v in a_host.items()}
        budget = M.count(hard)
        snapshots.append(hard)
        budgets.append(budget)
        lams.append(lam)
        if verbose:
            print(f"[snl] epoch={epoch} budget={budget} lam={lam:.2e}")
        if budget <= cfg.b_target:
            break
        if prev_budget is not None and prev_budget - budget <= cfg.stall_delta:
            lam *= cfg.kappa          # the κ correction mechanism
        prev_budget = budget

    # Hard threshold to EXACTLY b_target (the step that costs accuracy).
    a_host = {k: np.asarray(v) for k, v in both[1].items()}
    hard = M.threshold(a_host, cfg.b_target)

    # Finetune θ with binarized masks.
    params = finetune(both[0], hard, loss_fn, batches,
                      steps=cfg.finetune_steps, lr=cfg.lr, start_step=it)
    return SNLResult(params, hard, a_host, snapshots, budgets, lams)


def finetune(params, hard_masks: M.MaskTree, loss_fn, batches,
             *, steps: int, lr: float = 1e-3, start_step: int = 0,
             use_adam: bool = False):
    """Finetune θ under fixed binary masks (shared by SNL / BCD / AutoReP)."""
    opt = (opt_lib.adamw(lr=lr, schedule=opt_lib.cosine(lr, steps))
           if use_adam else
           opt_lib.sgd(lr=lr, momentum=0.9,
                       schedule=opt_lib.cosine(lr, steps)))
    masks_dev = M.as_device(hard_masks)

    @jax.jit
    def step(p, ostate, batch):
        def l(p):
            loss, _ = loss_fn(p, masks_dev, batch, False)
            return loss
        grads = jax.grad(l)(p)
        updates, ostate = opt.update(grads, ostate, p)
        return opt_lib.apply_updates(p, updates), ostate

    ostate = opt.init(params)
    for i in range(steps):
        params, ostate = step(params, ostate, batches(start_step + i))
    return params
