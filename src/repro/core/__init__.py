"""Paper core: Block Coordinate Descent for Network Linearization."""
from . import masks, linearize, bcd, engine, snl, autorep, pi_cost, analysis  # noqa

from .bcd import BCDConfig, run_bcd            # noqa: F401
from .engine import (CandidateEvaluator, SequentialEvaluator,  # noqa: F401
                     BatchedEvaluator, ShardedEvaluator, make_evaluator)
from .snl import SNLConfig, run_snl, finetune  # noqa: F401
from .autorep import AutoRepConfig, run_autorep  # noqa: F401
