"""AutoReP — Automatic ReLU Replacement (Peng et al., ICCV 2023), simplified.

The second Selective baseline the paper composes with.  Differences from SNL:
(1) eliminated ReLUs are replaced by a *learnable degree-2 polynomial*
    g(x) = a·x² + b·x + c  (per-channel coefficients, initialized to identity,
    so distribution-aware coefficients are learned jointly with θ);
(2) the binary indicator m = 1[α > 0] is trained with a straight-through
    estimator stabilized by a *hysteresis loop*: m flips 1→0 only when α < −h
    and 0→1 only when α > +h, suppressing indicator oscillation;
(3) the budget is soft-enforced by a penalty on the active fraction.

Final masks are hard top-|B| selections over α, followed by finetune of
(θ, poly) under fixed masks — exactly the checkpoint BCD starts from in the
paper's Fig. 4 experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import optimizer as opt_lib
from . import masks as M


@dataclasses.dataclass
class AutoRepConfig:
    b_target: int
    hysteresis: float = 0.05
    budget_weight: float = 1.0     # λ on the budget penalty
    epochs: int = 30
    steps_per_epoch: int = 20
    lr: float = 1e-3
    finetune_steps: int = 100
    seed: int = 0


@dataclasses.dataclass
class AutoRepResult:
    params: object
    poly: Dict[str, jnp.ndarray]
    masks: M.MaskTree
    alphas: Dict[str, np.ndarray]
    budget_per_epoch: List[int]

    def stage_init(self) -> dict:
        """This result as a BCD warm-start, in the same shared stage-init
        layout as :meth:`SNLResult.stage_init` (``core.runner``): the poly
        replacement coefficients ride in ``aux`` so a BCD stage finetuning
        (θ, poly) can restore them alongside θ."""
        return {"kind": "autorep", "masks": self.masks,
                "params": self.params, "aux": {"poly": self.poly}}


def _ste_indicator(alpha, m_prev, h):
    """Hysteresis indicator with straight-through gradient."""
    up = (alpha > h).astype(jnp.float32)
    down = (alpha >= -h).astype(jnp.float32)
    m = jnp.where(m_prev > 0.5, down, up)
    # straight-through: d m / d alpha := 1 in backward
    return m + alpha - jax.lax.stop_gradient(alpha)


def run_autorep(
    params,
    alphas: Dict[str, jnp.ndarray],
    poly: Dict[str, jnp.ndarray],
    loss_fn: Callable,   # (params, masks, poly, batch, soft) -> (loss, acc)
    batches: Callable[[int], object],
    cfg: AutoRepConfig,
    *,
    verbose: bool = False,
) -> AutoRepResult:
    total = sum(int(np.prod(v.shape)) for v in alphas.values())
    target_frac = cfg.b_target / total

    opt = opt_lib.sgd(lr=cfg.lr, momentum=0.9,
                      schedule=opt_lib.cosine(
                          cfg.lr, cfg.epochs * cfg.steps_per_epoch))

    def train_loss(trainable, m_prev, batch):
        p, a, q = trainable
        m = {k: _ste_indicator(a[k], m_prev[k], cfg.hysteresis) for k in a}
        loss, _acc = loss_fn(p, m, q, batch, True)
        frac = (sum(jnp.sum(v) for v in m.values()) / total)
        budget_pen = jnp.abs(frac - target_frac)
        return loss + cfg.budget_weight * budget_pen, m

    @jax.jit
    def step(trainable, m_prev, ostate, batch):
        (_, m), grads = jax.value_and_grad(train_loss, has_aux=True)(
            trainable, m_prev, batch)
        updates, ostate = opt.update(grads, ostate, trainable)
        trainable = opt_lib.apply_updates(trainable, updates)
        m_hard = {k: jax.lax.stop_gradient((v > 0.5).astype(jnp.float32))
                  for k, v in m.items()}
        return trainable, m_hard, ostate

    trainable = (params,
                 {k: jnp.asarray(v) for k, v in alphas.items()},
                 {k: jnp.asarray(v) for k, v in poly.items()})
    m_prev = {k: jnp.ones_like(v) for k, v in trainable[1].items()}
    ostate = opt.init(trainable)
    budgets, it = [], 0
    for epoch in range(cfg.epochs):
        for _ in range(cfg.steps_per_epoch):
            trainable, m_prev, ostate = step(trainable, m_prev, ostate,
                                             batches(it))
            it += 1
        budget = M.count({k: np.asarray(v) for k, v in m_prev.items()})
        budgets.append(budget)
        if verbose:
            print(f"[autorep] epoch={epoch} budget={budget}")

    params, a, q = trainable
    a_host = {k: np.asarray(v) for k, v in a.items()}
    hard = M.threshold(a_host, cfg.b_target)

    # Finetune (θ, poly) with fixed binary masks.
    masks_dev = M.as_device(hard)
    fopt = opt_lib.adamw(lr=3.5e-5,
                         schedule=opt_lib.cosine(3.5e-5, cfg.finetune_steps))

    @jax.jit
    def fstep(pq, ostate, batch):
        def l(pq):
            loss, _ = loss_fn(pq[0], masks_dev, pq[1], batch, False)
            return loss
        grads = jax.grad(l)(pq)
        updates, ostate = fopt.update(grads, ostate, pq)
        return opt_lib.apply_updates(pq, updates), ostate

    pq = (params, q)
    fstate = fopt.init(pq)
    for i in range(cfg.finetune_steps):
        pq, fstate = fstep(pq, fstate, batches(it + i))
    return AutoRepResult(pq[0], pq[1], hard, a_host, budgets)
