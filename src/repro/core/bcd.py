"""Block Coordinate Descent for Network Linearization (the paper's Alg. 1/2).

Works directly in the discrete mask domain: every iterate is a binary mask with
exactly-known ||m||_0 — no relaxation, no hard-threshold cliff.  The algorithm
is model-agnostic: it consumes two callables,

  eval_acc(mask_tree) -> float         train-subset accuracy with these masks
  finetune(mask_tree) -> None          finetune θ in place (closure-owned)

so the same driver runs the paper's ResNets and the LM-family backbones.
Candidate evaluation never recompiles: masks are jit inputs.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from . import masks as M


@dataclasses.dataclass
class BCDConfig:
    b_target: int                 # target ReLU budget
    drc: int = 100                # Delta ReLU Count per outer step
    rt: int = 50                  # random trials per outer step
    adt: float = 0.3              # accuracy degradation tolerance [%]
    finetune_every_step: bool = True
    seed: int = 0


@dataclasses.dataclass
class BCDStepLog:
    step: int
    budget_before: int
    budget_after: int
    trials: int
    found_early: bool
    best_drop: float              # accepted block's accuracy drop [%]
    acc_before: float
    acc_after_finetune: Optional[float]
    wall_s: float


@dataclasses.dataclass
class BCDResult:
    masks: M.MaskTree
    history: List[BCDStepLog]
    mask_snapshots: List[M.MaskTree]  # for IoU / golden-set analysis


def run_bcd(
    masks: M.MaskTree,
    cfg: BCDConfig,
    eval_acc: Callable[[M.MaskTree], float],
    finetune: Optional[Callable[[M.MaskTree], None]] = None,
    *,
    verbose: bool = False,
    keep_snapshots: bool = False,
) -> BCDResult:
    """Run Alg. 2 until ||m||_0 == cfg.b_target.

    Accuracies are in percent (0..100).  ΔAcc = acc(m) − acc(m⊙block).
    """
    rng = np.random.default_rng(cfg.seed)
    masks = {k: np.array(v, dtype=np.float32) for k, v in masks.items()}
    b_ref = M.count(masks)
    if cfg.b_target >= b_ref:
        return BCDResult(masks, [], [])
    t_total = math.ceil((b_ref - cfg.b_target) / cfg.drc)
    history: List[BCDStepLog] = []
    snaps: List[M.MaskTree] = []

    for t in range(t_total):
        t0 = time.perf_counter()
        budget = M.count(masks)
        drc_t = min(cfg.drc, budget - cfg.b_target)
        if drc_t <= 0:
            break
        acc_base = float(eval_acc(masks))
        best_cand, best_drop, found = None, float("inf"), False
        n = 0
        while n < cfg.rt and not found:
            cand = M.sample_removal_block(rng, masks, drc_t)
            drop = acc_base - float(eval_acc(cand))
            if drop < best_drop:
                best_cand, best_drop = cand, drop
            if drop < cfg.adt:
                found = True
            n += 1
        masks = best_cand
        acc_after = None
        if finetune is not None and cfg.finetune_every_step:
            finetune(masks)
            acc_after = float(eval_acc(masks))
        log = BCDStepLog(
            step=t, budget_before=budget, budget_after=M.count(masks),
            trials=n, found_early=found, best_drop=best_drop,
            acc_before=acc_base, acc_after_finetune=acc_after,
            wall_s=time.perf_counter() - t0)
        history.append(log)
        if keep_snapshots:
            snaps.append({k: v.copy() for k, v in masks.items()})
        if verbose:
            print(f"[bcd] t={t} budget {log.budget_before}->{log.budget_after}"
                  f" trials={n} early={found} drop={best_drop:.3f}%"
                  f" acc={acc_base:.2f}->"
                  f"{acc_after if acc_after is not None else float('nan'):.2f}")
    assert M.count(masks) == cfg.b_target, (M.count(masks), cfg.b_target)
    return BCDResult(masks, history, snaps)
