"""Block Coordinate Descent for Network Linearization (the paper's Alg. 1/2).

Works directly in the discrete mask domain: every iterate is a binary mask with
exactly-known ||m||_0 — no relaxation, no hard-threshold cliff.  The algorithm
is model-agnostic: it consumes two callables,

  eval_acc(mask_tree) -> float         train-subset accuracy with these masks
  finetune(mask_tree) -> None          finetune θ in place (closure-owned)

so the same driver runs the paper's ResNets and the LM-family backbones.
Candidate evaluation never recompiles: masks are jit inputs.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from . import masks as M


@dataclasses.dataclass
class BCDConfig:
    b_target: int                 # target (billable) ReLU budget
    drc: int = 100                # Delta ReLU Count per outer step
    rt: int = 50                  # random trials per outer step
    adt: float = 0.3              # accuracy degradation tolerance [%]
    finetune_every_step: bool = True
    seed: int = 0
    chunk_size: int = 8           # candidates per evaluator call
    # typed-move vocabulary (masks.MOVE_KINDS subset) and proposal
    # distribution over it.  The default reproduces the paper's Alg. 2
    # exactly — single removal moves, bit-identical rng stream.
    moves: Tuple[str, ...] = ("remove",)
    proposal: str = "uniform"     # 'uniform' | 'sensitivity'

    def validate(self) -> None:
        """Raise ValueError on configs that cannot run (Alg. 2 needs at
        least one trial per step to pick a block from)."""
        if self.b_target < 0:
            raise ValueError(f"b_target must be >= 0, got {self.b_target}")
        if self.drc <= 0:
            raise ValueError(f"drc must be > 0, got {self.drc}")
        if self.rt <= 0:
            raise ValueError(
                f"rt must be > 0, got {self.rt}: every outer step needs at "
                "least one candidate trial to select a removal block")
        if self.chunk_size <= 0:
            raise ValueError(
                f"chunk_size must be > 0, got {self.chunk_size}")
        if not math.isfinite(self.adt):
            raise ValueError(f"adt must be finite, got {self.adt}")
        if not self.moves:
            raise ValueError("moves must name at least one move kind")
        for kind in self.moves:
            if kind not in M.MOVE_KINDS:
                raise ValueError(f"unknown move kind {kind!r}; expected a "
                                 f"subset of {M.MOVE_KINDS}")
        if self.proposal not in M.PROPOSALS:
            raise ValueError(f"unknown proposal {self.proposal!r}; "
                             f"expected one of {M.PROPOSALS}")


@dataclasses.dataclass
class BCDStepLog:
    step: int
    budget_before: int
    budget_after: int
    trials: int
    found_early: bool
    best_drop: float              # accepted block's accuracy drop [%]
    acc_before: float
    acc_after_finetune: Optional[float]
    wall_s: float
    # defaulted last so BCDStepLog(**h) still loads pre-move-vocabulary
    # checkpoint manifests (core.runner.restore_run_state)
    move_kind: str = "remove"     # accepted move's kind (masks.MOVE_KINDS)


@dataclasses.dataclass
class BCDResult:
    masks: M.MaskTree
    history: List[BCDStepLog]
    mask_snapshots: List[M.MaskTree]  # for IoU / golden-set analysis
    # per-kind / per-site proposed-vs-accepted counters (JSON-able) — the
    # sweep artifact's acceptance-stats payload
    move_stats: dict = dataclasses.field(default_factory=dict)


def record_move_stats(stats: dict, moves: List[M.Move], accepted_idx: int,
                      layout: list) -> None:
    """Fold one step's proposals into the running acceptance counters.

    ``stats`` is mutated in place: ``stats["kinds"][kind]`` and
    ``stats["sites"][site]`` each carry ``{"proposed", "accepted"}``
    counts.  These are both the sweep artifact's per-move acceptance stats
    and the signal the 'sensitivity' proposal samples from."""
    kinds = stats.setdefault("kinds", {})
    sites = stats.setdefault("sites", {})
    for i, mv in enumerate(moves):
        hit = 1 if i == accepted_idx else 0
        k = kinds.setdefault(mv.kind, {"proposed": 0, "accepted": 0})
        k["proposed"] += 1
        k["accepted"] += hit
        for s in M.move_sites(mv, layout):
            site = sites.setdefault(s, {"proposed": 0, "accepted": 0})
            site["proposed"] += 1
            site["accepted"] += hit


@dataclasses.dataclass
class BCDState:
    """Everything Alg. 2 carries between outer steps.

    This is the unit of persistence for resumable runs (core.runner): a run
    checkpointed after step ``t`` and restored restarts the loop at step
    ``t+1`` with the same masks and the same rng stream position, so it
    replays bit-identically against an uninterrupted run.  Model params are
    *not* part of this state — they live with the caller's finetune closure /
    evaluator context and are checkpointed alongside by the runner.
    """
    masks: M.MaskTree
    rng: np.random.Generator
    step: int                      # next outer step index (== steps done)
    b_ref: int                     # billable budget at run start
    history: List[BCDStepLog]
    snapshots: List[M.MaskTree]
    # per-kind and per-site proposed/accepted counters, fed back into the
    # 'sensitivity' proposal sampler.  Part of the resume state (the sampler
    # reads it, so bit-identical replay requires restoring it).
    move_stats: dict = dataclasses.field(default_factory=dict)


def init_state(masks: M.MaskTree, cfg: BCDConfig) -> BCDState:
    """Fresh run state: copies the masks, seeds the rng from cfg.seed."""
    cfg.validate()
    masks = {k: np.array(v, dtype=np.float32) for k, v in masks.items()}
    return BCDState(masks=masks, rng=np.random.default_rng(cfg.seed),
                    step=0, b_ref=M.relu_cost(masks), history=[],
                    snapshots=[])


def _select_block(
    masks: M.MaskTree,
    cfg: BCDConfig,
    rng: np.random.Generator,
    evaluator,
    drc_t: int,
    acc_base: float,
    *,
    move_stats: Optional[dict] = None,
    max_remove: Optional[int] = None,
):
    """One outer step's trial loop: sample RT candidate blocks, evaluate in
    chunks of ``cfg.chunk_size``, return the accepted candidate.

    The loop is a producer/consumer pipeline: the producer materializes
    chunk mask trees lazily from the pre-sampled indices, and
    ``engine.evaluate_prefetched`` stages up to ``evaluator.prefetch_depth``
    chunks (host materialization + H2D transfer + compute dispatch) ahead of
    the chunk whose results are being consumed — double-buffering for the
    PipelinedEvaluator, a plain materialize → evaluate alternation for
    everything else (prefetch_depth 0).

    Selection is backend-independent: candidates are scanned in sampling
    order; the *first* candidate with drop < adt wins (ADT early exit —
    later chunks' results are never consumed, and chunks beyond the staging
    horizon are never materialized); otherwise the first-occurrence argmin
    over all RT.  The rng always burns exactly RT draws per step so early
    exit does not desynchronize subsequent steps across backends.

    Site-aware backends (``engine.SuffixEvaluator``) evaluate in *site-major*
    order instead — candidates grouped by the segment of their earliest
    touched site, so each group shares one cached forward prefix — and
    :func:`_scan_sited` replays the sampling-order selection rules on the
    reordered results; the returned (winner, best_drop, trials, found) are
    provably identical to the sampling-order loop (see its docstring).

    Candidates are typed moves (``cfg.moves`` / ``cfg.proposal`` — see
    masks.sample_moves); all sampling happens up front, so the rng burns a
    deterministic number of draws per step regardless of evaluation order
    or early exit.  ``move_stats`` feeds the 'sensitivity' proposal;
    ``max_remove`` caps macro-moves (pass ``budget - b_target``).

    Returns (candidate_tree, best_idx, best_drop, trials_evaluated, found,
    moves) — ``moves[best_idx]`` is the accepted move.
    """
    from . import engine

    moves = M.sample_moves(rng, masks, drc_t, cfg.rt, kinds=cfg.moves,
                           proposal=cfg.proposal, move_stats=move_stats,
                           max_remove=max_remove)
    flat, layout = M._flatten(masks)     # once per step, not per chunk
    # Backends may cap the chunk (engine.effective_chunk); selection is
    # invariant under chunking either way.
    chunk_size = engine.effective_chunk(evaluator, cfg.chunk_size)
    if getattr(evaluator, "site_aware", False):
        best_idx, best_drop, n_done, found = _scan_sited(
            masks, cfg, evaluator, flat, layout, moves, chunk_size,
            acc_base)
    else:
        bounds = M.chunk_bounds(cfg.rt, chunk_size)
        best_idx, best_drop, found, n_done = -1, float("inf"), False, 0
        results = engine.evaluate_prefetched(
            evaluator,
            M.materialize_move_chunks(flat, layout, moves, chunk_size))
        try:
            for (start, _), accs in zip(bounds, results):
                drops = acc_base - np.asarray(accs, dtype=np.float64)
                for j, drop in enumerate(drops):
                    n_done += 1
                    if drop < best_drop:
                        best_idx, best_drop = start + j, float(drop)
                    if drop < cfg.adt:
                        found = True
                        break
                if found:
                    break
        finally:
            results.close()      # drop any staged-but-unread chunks
    if best_idx < 0:
        raise RuntimeError(
            "BCD trial loop produced no candidate: evaluator returned "
            f"{n_done} results for rt={cfg.rt} trials")
    cand = M.materialize_moves_from_flat(flat, layout,
                                         [moves[best_idx]])
    return (M.index_stacked(cand, 0), best_idx, best_drop, n_done, found,
            moves)


def _scan_sited(masks, cfg, evaluator, flat, layout, moves, chunk_size,
                acc_base):
    """Site-major trial scan with sampling-order selection replay.

    Chunks are evaluated grouped by cut segment in depth-ascending order
    (one cached prefix per group — ``engine.plan_sited_chunks``; ascending
    depth lets the suffix engine's prefix trie extend each cached prefix
    into the next group's deeper one instead of recomputing from the
    input), which permutes *evaluation* order.  Selection stays
    bit-identical to the sampling-order loop because its outcome is a pure
    function of the drop vector:

    * if any candidate has drop < adt, the sampling-order loop stops at the
      FIRST such index ``i*`` and returns it (every earlier candidate has
      drop >= adt > impossible-to-win), with trials = i* + 1;
    * otherwise it returns the first-occurrence argmin with trials = RT.

    This scan accumulates drops in sampling positions and applies exactly
    those rules.  Early exit: once some evaluated index i* has
    drop < adt AND all sampling positions before i* are evaluated, no
    unevaluated candidate can change the outcome — stop (at most the
    staged-ahead chunks are wasted, same bound as the prefetch loop).

    Returns (best_idx, best_drop, trials, found).
    """
    from . import engine

    rt = len(moves)
    evaluator.begin_step(masks)
    order, chunks = engine.plan_sited_chunks(evaluator, moves, layout,
                                             chunk_size)
    drops = np.full(rt, np.inf)
    evaluated = np.zeros(rt, dtype=bool)
    hit = rt                       # min sampling index with drop < adt
    results = engine.evaluate_prefetched(
        evaluator,
        engine.materialize_sited(flat, layout, moves, order, chunks))
    try:
        for (_, s, e), accs in zip(chunks, results):
            pos = order[s:e]
            d = acc_base - np.asarray(accs, dtype=np.float64)
            drops[pos] = d
            evaluated[pos] = True
            below = pos[d < cfg.adt]
            if below.size:
                hit = min(hit, int(below.min()))
            if hit < rt and evaluated[:hit].all():
                break
    finally:
        results.close()          # drop any staged-but-unread chunks
    if hit < rt and evaluated[:hit].all():
        return hit, float(drops[hit]), hit + 1, True
    return int(np.argmin(drops)), float(drops.min()), rt, False


def total_steps(b_ref: int, cfg: BCDConfig) -> int:
    """The schedule length: outer steps from ``b_ref`` down to b_target."""
    return max(0, math.ceil((b_ref - cfg.b_target) / cfg.drc))


def bcd_steps(
    state: BCDState,
    cfg: BCDConfig,
    eval_acc: Callable[[M.MaskTree], float],
    finetune: Optional[Callable[[M.MaskTree], None]] = None,
    *,
    evaluator=None,
    verbose: bool = False,
    keep_snapshots: bool = False,
):
    """Step-granular Alg. 2: yields one :class:`BCDStepLog` per accepted
    block, mutating ``state`` in place.

    This is the resumable core of :func:`run_bcd`: a caller (core.runner)
    may checkpoint ``state`` after any yield and later rebuild an identical
    generator from the restored state — the loop carries no hidden
    per-iteration context beyond ``state`` itself, so the continuation
    replays bit-identically (``wall_s`` excepted, which is wall-clock).
    """
    cfg.validate()
    if evaluator is None:
        from . import engine
        evaluator = engine.SequentialEvaluator(eval_acc)
    t_cap = total_steps(state.b_ref, cfg)
    while state.step < t_cap:
        t0 = time.perf_counter()
        budget = M.relu_cost(state.masks)
        drc_t = min(cfg.drc, budget - cfg.b_target)
        if drc_t <= 0:
            return
        acc_base = float(eval_acc(state.masks))
        masks, best_idx, best_drop, n, found, moves = _select_block(
            state.masks, cfg, state.rng, evaluator, drc_t, acc_base,
            move_stats=state.move_stats,
            max_remove=budget - cfg.b_target)
        _, layout = M._flatten(state.masks)
        record_move_stats(state.move_stats, moves, best_idx, layout)
        state.masks = masks
        acc_after = None
        if finetune is not None and cfg.finetune_every_step:
            finetune(state.masks)
            acc_after = float(eval_acc(state.masks))
        log = BCDStepLog(
            step=state.step, budget_before=budget,
            budget_after=M.relu_cost(state.masks),
            trials=n, found_early=found, best_drop=best_drop,
            acc_before=acc_base, acc_after_finetune=acc_after,
            wall_s=time.perf_counter() - t0,
            move_kind=moves[best_idx].kind)
        state.step += 1
        state.history.append(log)
        if keep_snapshots:
            state.snapshots.append(
                {k: v.copy() for k, v in state.masks.items()})
        if verbose:
            print(f"[bcd] t={log.step} budget "
                  f"{log.budget_before}->{log.budget_after}"
                  f" move={log.move_kind}"
                  f" trials={n} early={found} drop={best_drop:.3f}%"
                  f" acc={acc_base:.2f}->"
                  f"{acc_after if acc_after is not None else float('nan'):.2f}"
                  f" [{getattr(evaluator, 'name', '?')}]")
        yield log


def check_reached_target(state: BCDState, cfg: BCDConfig) -> None:
    """Raise if a completed schedule did not land exactly on b_target
    (billable budget — share-tied coordinates don't count)."""
    final = M.relu_cost(state.masks)
    if final != cfg.b_target:
        raise RuntimeError(
            f"BCD terminated at budget {final}, target {cfg.b_target} "
            f"(b_ref={state.b_ref}, drc={cfg.drc}, steps run="
            f"{len(state.history)}/{total_steps(state.b_ref, cfg)}) — the "
            "schedule did not reach the target; check drc/b_target against "
            "the initial mask count")


def run_bcd(
    masks: M.MaskTree,
    cfg: BCDConfig,
    eval_acc: Callable[[M.MaskTree], float],
    finetune: Optional[Callable[[M.MaskTree], None]] = None,
    *,
    evaluator=None,
    verbose: bool = False,
    keep_snapshots: bool = False,
) -> BCDResult:
    """Run Alg. 2 until ||m||_0 == cfg.b_target.

    Accuracies are in percent (0..100).  ΔAcc = acc(m) − acc(m⊙block).
    ``evaluator`` is a core.engine.CandidateEvaluator for the trial loop
    (defaults to SequentialEvaluator over ``eval_acc``); ``eval_acc`` is
    always used for the per-step base / post-finetune accuracies.  For
    checkpointed / resumable runs, drive :func:`bcd_steps` through
    ``core.runner.BCDRunner`` instead — this wrapper is the fire-and-forget
    path.
    """
    state = init_state(masks, cfg)
    if cfg.b_target >= state.b_ref:
        return BCDResult(state.masks, [], [])
    for _ in bcd_steps(state, cfg, eval_acc, finetune, evaluator=evaluator,
                       verbose=verbose, keep_snapshots=keep_snapshots):
        pass
    check_reached_target(state, cfg)
    return BCDResult(state.masks, state.history, state.snapshots,
                     state.move_stats)
