"""Mask analytics reproducing the paper's Figs. 6 and 7.

* IoU dynamics along an optimization trajectory (golden-set evidence):
  IoU(m1, m2) = ||m1 ⊙ m2||_0 / ||m1||_0 for budgets B2 > B1.
* Per-layer/site ReLU distribution at a budget.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from . import masks as M


def iou_matrix(snapshots: List[M.MaskTree]) -> np.ndarray:
    """IoU for every ordered snapshot pair (i later/smaller-budget than j)."""
    n = len(snapshots)
    out = np.full((n, n), np.nan)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            bi, bj = M.count(snapshots[i]), M.count(snapshots[j])
            if bi <= bj:
                out[i, j] = M.intersection_over_union(
                    snapshots[i], snapshots[j])
    return out


def consecutive_iou(snapshots: List[M.MaskTree]) -> List[float]:
    """Paper Fig. 6(a): IoU of consecutive binarized masks over epochs."""
    vals = []
    for a, b in zip(snapshots[1:], snapshots[:-1]):
        small, big = (a, b) if M.count(a) <= M.count(b) else (b, a)
        vals.append(M.intersection_over_union(small, big))
    return vals


def golden_set_fraction(snapshots: List[M.MaskTree]) -> float:
    """Fraction of ordered pairs with IoU > 0.85 (paper: ≈ 1.0)."""
    mat = iou_matrix(snapshots)
    vals = mat[~np.isnan(mat)]
    if vals.size == 0:
        return 1.0
    return float(np.mean(vals > 0.85))


def layer_distribution(masks: M.MaskTree) -> Dict[str, Tuple[int, int]]:
    """Per-site (active, total) counts — paper Fig. 7."""
    return {k: (int(np.sum(v > 0.5)), int(v.size))
            for k, v in sorted(masks.items())}
