"""Mask ↔ model glue: declares *mask sites* and applies masked activations.

A model exposes ``mask_sites() -> {name: MaskSite}``; the linearization engine
builds the mask tree, and the model's forward applies ``apply_masked_act`` at
each site.  This keeps the paper's algorithm (core.bcd / core.snl) fully
model-agnostic: BCD only ever sees the mask tree.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.kernels import ops
from . import masks as M

_ROUTE_STATE = threading.local()


@contextlib.contextmanager
def stacked_kernel_route(on: bool = True):
    """Trace-time hint (thread-local): inside this context, the hard-mask
    TPU dispatch in :func:`apply_masked_act` emits the custom-vmap routed op
    (``ops.masked_act_sited_routed``), so a candidate-axis vmap — the
    batched/sharded/pipelined engines in ``core.engine`` — lowers every mask
    site to the stacked Pallas kernel (``masked_act_2d_batched``) instead of
    vmapping the per-candidate kernel's grid.  Off by default: custom_vmap
    does not support differentiation, and training forwards must keep the
    plain kernel."""
    prev = getattr(_ROUTE_STATE, "on", False)
    _ROUTE_STATE.on = on
    try:
        yield
    finally:
        _ROUTE_STATE.on = prev


def stacked_route_active() -> bool:
    return getattr(_ROUTE_STATE, "on", False)


@contextlib.contextmanager
def fused_suffix_route(interpret: bool = False):
    """Trace-time hint (thread-local): inside this context, models fold a
    hard-mask activation gate into the adjacent conv/matmul via the fused
    Pallas entry points (``ops.masked_act_conv3x3_routed`` /
    ``ops.masked_act_matmul_routed``) instead of the gate-then-dispatch
    pair — the gated tensor never round-trips HBM.  The suffix engine
    (``core.engine.SuffixEvaluator``) arms this while tracing its suffix
    jits; soft/poly sites and non-TPU backends fall through to the plain
    path.  ``interpret=True`` forces the fused kernels in Pallas interpret
    mode regardless of backend — CPU parity tests only."""
    prev = getattr(_ROUTE_STATE, "fused", None)
    _ROUTE_STATE.fused = "interpret" if interpret else "device"
    try:
        yield
    finally:
        _ROUTE_STATE.fused = prev


def fused_route_mode() -> Optional[str]:
    """``None`` (off), ``"device"`` (fuse where Pallas runs natively), or
    ``"interpret"`` (force interpret-mode kernels — tests)."""
    return getattr(_ROUTE_STATE, "fused", None)


# Activation kinds with a masked lowering (ref path + Pallas kernels).
# Families register their gates against this set: dense/moe FFNs use the
# config's act (relu/gelu/silu), expert FFNs share the routed experts'
# (E, F) site, rwkv6's channel mix registers 'sqrelu' (relu(x)²), mamba
# registers 'silu' on the gated inner width.
KINDS = ("relu", "gelu", "silu", "sqrelu")
REPLACEMENTS = ("identity", "poly2")


@dataclasses.dataclass(frozen=True)
class MaskSite:
    """One maskable nonlinearity site.

    shape: the mask shape (shared over batch / sequence).  CNNs use the full
    (H, W, C) activation-site shape (paper's per-pixel masks); transformers use
    per-channel (n_layers_in_stack, d_ff) for a scanned stack.
    kind:  activation at the site ('relu' | 'gelu' | 'silu' | 'sqrelu').
    replacement: 'identity' (Network Linearization) or 'poly2' (AutoReP).

    Validated at registration: a typo'd kind would otherwise only surface
    at trace time, deep inside the kernel dispatch of whichever backend
    first evaluates the site.
    """
    shape: Tuple[int, ...]
    kind: str = "relu"
    replacement: str = "identity"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown activation kind {self.kind!r} (one of {KINDS})")
        if self.replacement not in REPLACEMENTS:
            raise ValueError(
                f"unknown replacement {self.replacement!r} "
                f"(one of {REPLACEMENTS})")
        if not self.shape or any(int(d) <= 0 for d in self.shape):
            raise ValueError(f"mask shape must be non-empty positive dims, "
                             f"got {self.shape!r}")


def init_masks(sites: Dict[str, MaskSite]) -> M.MaskTree:
    return M.full_masks({k: s.shape for k, s in sites.items()})


def init_poly(sites: Dict[str, MaskSite]) -> Dict[str, jnp.ndarray]:
    """AutoReP poly2 coefficients per site, initialized near identity:
    g(x) = 0·x² + 1·x + 0."""
    out = {}
    for k, s in sites.items():
        if s.replacement == "poly2":
            p = jnp.zeros((3,) + s.shape, dtype=jnp.float32)
            p = p.at[1].set(1.0)
            out[k] = p
    return out


def _apply_share_ties(x, mask, out):
    """Override share-tied coordinates (``masks.TIE``) in a hard-masked
    activation output.

    A tied coordinate keeps its gate but reuses the *sign decision* of its
    driver — the previous coordinate along the site's last axis (DeepShare-
    style neighbor sharing: one garbled-circuit comparison serves both
    coordinates in the PI protocol, which is why ``masks.relu_cost`` does
    not bill ties).  ``out = x * H(x_driver)`` where H is the Heaviside
    step on the driver's pre-activation.  Binary masks make ``tied``
    all-False and the ``where`` selects ``out`` everywhere — bit-identical
    to the pre-move-vocabulary forward, so kernel-parity and backend-
    equivalence contracts are unchanged.

    Note: the fused conv/matmul suffix kernels (``fused_suffix_route``)
    bypass this wrapper — run share-enabled configs with
    ``fused_kernels=False`` on the suffix backend (inert off-TPU, where
    the fused route never arms).
    """
    tied = (mask > 0.5) & (mask < 0.9)
    drv = (jnp.roll(x, 1, axis=-1) > 0).astype(x.dtype)
    return jnp.where(tied, x * drv, out)


def apply_masked_act(x, mask, site: MaskSite, poly=None, soft: bool = False):
    """Apply the (possibly soft, for SNL) masked activation at a site.

    x: (batch..., *site.shape) — site shape must be the trailing dims.
    soft=True keeps real-valued masks differentiable (SNL's relaxation);
    hard masks route through the fused kernel wrapper.  Hard masks may
    carry share-tied coordinates (``masks.TIE``), overridden by
    :func:`_apply_share_ties`; soft mode treats every real value as an SNL
    relaxation weight and never ties.
    """
    from repro.kernels import ref
    p = None
    if poly is not None and (soft or site.replacement == "poly2"):
        p = poly
    if soft:
        mask = jnp.clip(mask, 0.0, 1.0)
    if soft or not ops._use_pallas():
        # Direct broadcast application — NO reshape.  Flattening the site
        # dims (e.g. an MoE (E, F) mask) merges a model-sharded axis into a
        # mixed one and forces GSPMD to fully rematerialize the activation
        # (EXPERIMENTS.md §Perf, mixtral hillclimb).  Pallas needs the 2D
        # layout, but it only runs on TPU where the kernel owns the tiling.
        y = ref._act(x, site.kind)
        if p is None:
            lin = x
        else:
            a, b, c = p[0], p[1], p[2]
            lin = a * x * x + b * x + c
        m = mask.astype(x.dtype)
        out = m * y + (1.0 - m) * lin
        return out if soft else _apply_share_ties(x, mask, out)
    if stacked_route_active():
        out = ops.masked_act_sited_routed(x, mask, kind=site.kind, poly=p)
    else:
        out = ops.masked_act_sited(x, mask, kind=site.kind, poly=p)
    return _apply_share_ties(x, mask, out)
