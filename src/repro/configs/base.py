"""Architecture config system: ArchConfig, input shapes, registry.

Every assigned architecture is a ``configs/<id>.py`` exporting ``CONFIG``.
Backbones are built from a repeating ``pattern`` of Blocks (scan-compiled),
plus optional unrolled ``head_blocks`` (before) and an automatic tail (the
``n_layers % len(pattern)`` remainder, taken from the pattern prefix).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Block:
    kind: str                      # dense | moe | mamba | rwkv | attn_only
    window: Optional[int] = None   # sliding-window size for this block's attn
    rope_theta: float = 1e4
    shared: bool = False           # share params across repeats (zamba2 attn)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int
    pattern: Tuple[Block, ...]
    head_blocks: Tuple[Block, ...] = ()
    act: str = "silu"
    gated_ffn: bool = True
    qk_norm: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    # ssm
    ssm_state: int = 0
    mamba_head_dim: int = 64
    rwkv_head_dim: int = 64
    # performance knobs (§Perf hillclimb variants; defaults = baseline)
    moe_dispatch: str = "scatter"  # 'scatter' | 'gather' (see models.moe)
    remat_group: int = 1           # layers per remat group in the train scan
    # io / modality
    prefix_len: int = 0            # stubbed frontend embeddings (vlm)
    subquadratic: bool = False     # eligible for long_500k
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # activation replacement mode when masked: 'identity' | 'poly2'
    act_when_masked: str = "identity"

    @property
    def n_repeats(self) -> int:
        return (self.n_layers - len(self.head_blocks)) // len(self.pattern)

    @property
    def tail(self) -> Tuple[Block, ...]:
        rem = (self.n_layers - len(self.head_blocks)) % len(self.pattern)
        return self.pattern[:rem]

    @property
    def d_inner(self) -> int:      # mamba inner width
        return 2 * self.d_model

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        pat = self.pattern
        nl = len(self.head_blocks) + 2 * len(pat) + len(self.tail)
        return dataclasses.replace(
            self, n_layers=nl, d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2), head_dim=16,
            d_ff=96, vocab=128,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_ff_expert=32 if self.n_experts else 0,
            d_ff_shared=32 if self.n_shared_experts else 0,
            ssm_state=8 if self.ssm_state else 0,
            mamba_head_dim=16, rwkv_head_dim=16,
            prefix_len=8 if self.prefix_len else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2_2p7b", "stablelm_1p6b", "mistral_nemo_12b", "qwen3_32b",
    "gemma3_27b", "mixtral_8x22b", "deepseek_moe_16b", "rwkv6_3b",
    "paligemma_3b", "musicgen_large",
]


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def cell_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if not."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 512k decode is quadratic"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeCell):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   tokens/labels (B, S) (+ prefix_embeds for stub frontends;
             text length shrinks so total seq == shape.seq_len)
    prefill: tokens (B, S)
    decode:  token (B, 1) + cache handled by the step factory.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    text = S - cfg.prefix_len
    specs = {}
    if shape.mode == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, text), i32)
    elif shape.mode == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, text), i32)
    else:  # decode: one new token, cache of length S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.prefix_len and shape.mode != "decode":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_len, cfg.d_model), f)
    return specs


def make_inputs(cfg: ArchConfig, shape: ShapeCell, seed: int = 0):
    """Concrete (small-RNG) inputs matching input_specs — for smoke tests."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, sds in input_specs(cfg, shape).items():
        if sds.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=sds.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(rng.normal(size=sds.shape) * 0.02,
                                 dtype=sds.dtype)
    return out
