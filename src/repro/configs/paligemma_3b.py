"""PaliGemma-3B — SigLIP + Gemma backbone [arXiv:2407.07726].

The SigLIP vision tower is a STUB: input_specs() supplies 256 precomputed
patch embeddings at d_model; only the Gemma text backbone is modeled.
"""
from .base import ArchConfig, Block

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256,
    pattern=(Block("dense", rope_theta=1e4),), act="gelu",
    prefix_len=256,
)
