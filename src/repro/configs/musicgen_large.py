"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec tokenizer (and text-conditioning cross-attention) is a STUB:
input_specs() supplies precomputed audio-frame token ids (one codebook
stream, vocab 2048); only the transformer backbone is modeled.
"""
from .base import ArchConfig, Block

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=2048, head_dim=64,
    pattern=(Block("dense", rope_theta=1e4),), act="gelu", gated_ffn=False,
)
