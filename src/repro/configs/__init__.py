from .base import (ArchConfig, Block, ShapeCell, SHAPES, ARCH_IDS,
                   get_config, cell_applicable, input_specs, make_inputs)  # noqa
