"""Gemma3-27B — 5:1 local:global attention, 128k [hf:google/gemma-3-27b-pt]."""
from .base import ArchConfig, Block

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab=262144, head_dim=128, qk_norm=True,
    # 5 sliding-window (1024) layers per full-attention layer; 62 = 10×6 + 2.
    pattern=(Block("dense", window=1024, rope_theta=1e4),) * 5
            + (Block("dense", rope_theta=1e6),),
    act="gelu",
)
