"""DeepSeekMoE-16B — 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066].

Layer 0 is a dense FFN (d_ff 10944) per the released config; layers 1..27 MoE.
"""
from .base import ArchConfig, Block

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400, head_dim=128,
    n_experts=64, top_k=6, d_ff_expert=1408,
    n_shared_experts=2, d_ff_shared=2816,
    head_blocks=(Block("dense"),),
    pattern=(Block("moe"),), act="silu",
)
