"""Zamba2-2.7B — Mamba2 backbone with shared attention blocks [arXiv:2411.15242]."""
from .base import ArchConfig, Block

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, ssm_state=64, mamba_head_dim=64,
    # 5 Mamba2 blocks then one SHARED full-attention block, ×9 = 54 layers.
    pattern=(Block("mamba"),) * 5 + (Block("attn_only", shared=True),),
    act="silu", subquadratic=True,
)
