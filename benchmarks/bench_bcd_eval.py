"""Candidate-evaluation engine throughput: sequential vs batched vs sharded
vs pipelined.

Measures candidates/sec for each core.engine backend on the mini ResNet
config — the number that bounds BCD wall-clock (Alg. 2 evaluates up to RT
candidates per outer step).  The timed loop reproduces ``run_bcd``'s real
trial loop: chunk mask trees are *materialized from removal indices inside
the loop* and driven through ``engine.evaluate_prefetched``, so the
pipelined backend's overlap of chunk k+1's host materialization + transfer
with chunk k's compute shows up in the number (the chunk-serial backends pay
those phases back-to-back).  Emits the repo's CSV row format plus a
machine-readable ``BENCH_bcd_eval.json`` so future PRs can track the
candidates/sec trajectory (CI gates on it — see
benchmarks/check_bench_regression.py).

    PYTHONPATH=src python -m benchmarks.bench_bcd_eval \
        [--rt 32] [--chunk-size 8] [--prefetch 2] [--repeats 3] \
        [--out BENCH_bcd_eval.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig


def build_pipeline(image_size=16, eval_batch=128):
    """Mini ResNet config (same code path as the paper's ResNet18)."""
    model = CNN(CNNConfig("r18-mini", 4, image_size,
                          ((8, 2, 1), (16, 2, 2)), stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(
        n_classes=4, image_size=image_size, n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(eval_batch)
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def time_backend(evaluator, masks0, indices, chunk_size, repeats,
                 warmup=True):
    """Drive the real trial loop (materialize per chunk, prefetch-aware);
    return (cands/sec, us/cand).  warmup=False skips the untimed
    compile-and-cache sweep (the evaluator was already warmed)."""
    # Match _select_block's chunk policy so the benchmark pays the same
    # per-chunk materialization cost the real loop pays.
    chunk_size = engine.effective_chunk(evaluator, chunk_size)
    flat, layout = M._flatten(masks0)
    n = indices.shape[0]

    def sweep():
        chunks = M.materialize_chunks(flat, layout, indices, chunk_size)
        for accs in engine.evaluate_prefetched(evaluator, chunks):
            pass

    if warmup:
        sweep()                              # warmup: compile + cache
    t0 = time.perf_counter()
    for _ in range(repeats):
        sweep()
    dt = time.perf_counter() - t0
    total = n * repeats
    return total / dt, dt / total * 1e6


def main():
    ap = argparse.ArgumentParser()
    # Defaults target the regime BCD actually runs in: a small train-subset
    # eval batch (the paper scores candidates on a subsample, not the full
    # set), where per-candidate dispatch/transfer/sync overhead is the
    # bottleneck the batched engine exists to amortize.  chunk-size defaults
    # to 8 (several chunks per RT sweep) so the pipelined backend has chunk
    # boundaries to overlap across; pass --chunk-size == --rt for the
    # one-call-per-sweep operating point.
    ap.add_argument("--rt", type=int, default=32)
    ap.add_argument("--chunk-size", type=int, default=8)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    # Trials interleave across backends and each backend reports its MEDIAN
    # trial: on shared/noisy hosts (CI, this 2-core container) a single
    # measurement can swing ±30%, and a best-of would bias the committed
    # baseline to its upper envelope — making the CI regression gate fire
    # on ordinary noise.
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--drc", type=int, default=64)
    ap.add_argument("--eval-batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_bcd_eval.json")
    args = ap.parse_args()

    model, params, batch, masks0 = build_pipeline(
        eval_batch=args.eval_batch)
    indices = M.sample_removal_indices(
        np.random.default_rng(0), masks0, args.drc, args.rt)
    # Don't let ragged-chunk padding exceed RT: with rt < chunk_size the
    # batched backend would evaluate padding candidates that can never
    # exist (sharded may still round up to the device count).
    chunk = min(args.chunk_size, args.rt)

    eval_acc = model.make_eval_acc(params, batch)
    eval_fn = model.make_eval_fn(params, batch)
    backends = {
        "sequential": engine.SequentialEvaluator(eval_acc),
        "batched": engine.BatchedEvaluator(eval_fn, pad_to=chunk),
        "sharded": engine.ShardedEvaluator(
            eval_fn, mesh_lib.make_candidate_mesh(), pad_to=chunk),
        "pipelined": engine.PipelinedEvaluator(
            eval_fn, pad_to=chunk, prefetch=args.prefetch),
    }

    trials = {name: [] for name in backends}
    for trial in range(max(1, args.trials)):
        for name, ev in backends.items():
            cps, _ = time_backend(ev, masks0, indices, chunk, args.repeats,
                                  warmup=(trial == 0))
            trials[name].append(cps)
    results = {}
    for name, cands in trials.items():
        cps = float(np.median(cands))
        results[name] = {"cands_per_s": round(cps, 2),
                         "us_per_cand": round(1e6 / cps, 2)}
        print(f"bcd_eval_{name},{1e6 / cps:.1f},{cps:.1f}")

    def speedup(a, b):
        return round(results[a]["cands_per_s"] / results[b]["cands_per_s"], 2)

    report = {
        "bench": "bcd_eval",
        "config": {"rt": args.rt, "chunk_size": chunk,
                   "prefetch": args.prefetch,
                   "drc": args.drc, "repeats": args.repeats,
                   "trials": args.trials,
                   "eval_batch": args.eval_batch,
                   "model": model.cfg.name,
                   "n_devices": jax.device_count(),
                   "backend": jax.default_backend()},
        "backends": results,
        "speedup_batched_vs_sequential": speedup("batched", "sequential"),
        "speedup_sharded_vs_sequential": speedup("sharded", "sequential"),
        "speedup_pipelined_vs_sequential": speedup("pipelined", "sequential"),
        "speedup_pipelined_vs_batched": speedup("pipelined", "batched"),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"batched vs sequential: "
          f"{report['speedup_batched_vs_sequential']:.2f}x; "
          f"pipelined vs batched: "
          f"{report['speedup_pipelined_vs_batched']:.2f}x  -> {args.out}")
    return report


if __name__ == "__main__":
    main()
