"""Candidate-evaluation engine throughput: sequential vs batched vs sharded.

Measures candidates/sec for each core.engine backend on the mini ResNet
config — the number that bounds BCD wall-clock (Alg. 2 evaluates up to RT
candidates per outer step).  Emits the repo's CSV row format plus a
machine-readable ``BENCH_bcd_eval.json`` so future PRs can track the
candidates/sec trajectory.

    PYTHONPATH=src python -m benchmarks.bench_bcd_eval \
        [--rt 32] [--chunk-size 8] [--repeats 3] [--out BENCH_bcd_eval.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig


def build_pipeline(image_size=16, eval_batch=128):
    """Mini ResNet config (same code path as the paper's ResNet18)."""
    model = CNN(CNNConfig("r18-mini", 4, image_size,
                          ((8, 2, 1), (16, 2, 2)), stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(
        n_classes=4, image_size=image_size, n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(eval_batch)
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def time_backend(evaluator, stacked, chunk_size, repeats):
    """Evaluate all candidates in chunks; return (cands/sec, us/cand)."""
    n = M.stacked_len(stacked)
    chunks = [M.slice_stacked(stacked, s, min(s + chunk_size, n))
              for s in range(0, n, chunk_size)]
    evaluator.evaluate(chunks[0])            # warmup: compile + cache
    t0 = time.perf_counter()
    for _ in range(repeats):
        for c in chunks:
            evaluator.evaluate(c)
    dt = time.perf_counter() - t0
    total = n * repeats
    return total / dt, dt / total * 1e6


def main():
    ap = argparse.ArgumentParser()
    # Defaults target the regime BCD actually runs in: a small train-subset
    # eval batch (the paper scores candidates on a subsample, not the full
    # set), where per-candidate dispatch/transfer/sync overhead is the
    # bottleneck the batched engine exists to amortize.
    # chunk-size defaults to rt (one vmapped call per backend sweep) —
    # maximal amortization, i.e. what BCD runs when the ADT early exit is
    # disabled; pass a smaller chunk to measure the early-exit trade-off.
    ap.add_argument("--rt", type=int, default=32)
    ap.add_argument("--chunk-size", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--drc", type=int, default=64)
    ap.add_argument("--eval-batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_bcd_eval.json")
    args = ap.parse_args()

    model, params, batch, masks0 = build_pipeline(
        eval_batch=args.eval_batch)
    stacked = M.sample_removal_blocks(
        np.random.default_rng(0), masks0, args.drc, args.rt)
    # Don't let ragged-chunk padding exceed RT: with rt < chunk_size the
    # batched backend would evaluate padding candidates that can never
    # exist (sharded may still round up to the device count).
    chunk = min(args.chunk_size, args.rt)

    eval_acc = model.make_eval_acc(params, batch)
    eval_fn = model.make_eval_fn(params, batch)
    backends = {
        "sequential": engine.SequentialEvaluator(eval_acc),
        "batched": engine.BatchedEvaluator(eval_fn, pad_to=chunk),
        "sharded": engine.ShardedEvaluator(
            eval_fn, mesh_lib.make_candidate_mesh(), pad_to=chunk),
    }

    results = {}
    for name, ev in backends.items():
        cps, us = time_backend(ev, stacked, chunk, args.repeats)
        results[name] = {"cands_per_s": round(cps, 2),
                         "us_per_cand": round(us, 2)}
        print(f"bcd_eval_{name},{us:.1f},{cps:.1f}")

    speedup = (results["batched"]["cands_per_s"]
               / results["sequential"]["cands_per_s"])
    report = {
        "bench": "bcd_eval",
        "config": {"rt": args.rt, "chunk_size": chunk,
                   "drc": args.drc, "repeats": args.repeats,
                   "eval_batch": args.eval_batch,
                   "model": model.cfg.name,
                   "n_devices": jax.device_count(),
                   "backend": jax.default_backend()},
        "backends": results,
        "speedup_batched_vs_sequential": round(speedup, 2),
        "speedup_sharded_vs_sequential": round(
            results["sharded"]["cands_per_s"]
            / results["sequential"]["cands_per_s"], 2),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"batched vs sequential: {speedup:.2f}x  -> {args.out}")
    return report


if __name__ == "__main__":
    main()
