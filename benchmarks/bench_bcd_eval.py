"""Candidate-evaluation engine throughput: sequential vs batched vs sharded
vs pipelined vs suffix (prefix-reuse).

Measures candidates/sec for each core.engine backend on the mini ResNet
config — the number that bounds BCD wall-clock (Alg. 2 evaluates up to RT
candidates per outer step).  The timed loop reproduces ``run_bcd``'s real
trial loop: chunk mask trees are *materialized from removal indices inside
the loop* and driven through ``engine.evaluate_prefetched`` (site-aware
backends additionally run the real site-major plan + per-step prefix
computation), so every backend pays exactly what the real loop pays.
Every vmapped backend is constructed at the finetune-ready operating
point the example pipeline uses (``make_param_eval_fn`` +
``context=params``): params are a jit input swapped via ``set_context``,
not a closure constant XLA could fold the mask-independent stem through.

Two workloads:

* the main ``backends`` table samples removal blocks from the GLOBAL active
  set (the Alg. 2 default).  Global blocks almost always touch a shallow
  site, so the suffix backend's cost model falls most chunks back to the
  full forward — its row measures that fallback overhead, not the reuse win;
* ``per_site_depth`` samples *site-local* blocks at a shallow / middle /
  deep site and times suffix vs batched on each — the regime where
  candidates are local edits and the prefix-reuse engine shines;
* ``move_mix`` drives typed candidates over all five move kinds
  (core.masks.sample_moves) through the batched backend and reports the
  throughput ratio against removal-only blocks — the move vocabulary's
  trial-loop overhead, kept outside ``config`` so committed-baseline
  compares don't treat the workload mix as an operating-point change.  The
  headline keys are explicit about what they summarize:
  ``speedup_suffix_vs_batched_deep`` (deep-site ratio),
  ``..._shallow`` (all-fallback floor), ``..._mean`` (mean over the three
  depth classes) and ``..._aggregate`` (global workload, the main table's
  suffix/batched ratio).  CI gates deep+mean relative to the committed
  baseline and floors mean/shallow absolutely
  (benchmarks/check_bench_regression.py --gate-speedup / --floor).

When a ``BENCH_history.jsonl`` is present, the suffix evaluator's cost
model is calibrated from its per-depth measurements
(``SuffixCostModel.calibrated`` — EWMA per site over entries matching
this run's config fingerprint), so the bench exercises the measured
decision path; a first run (no history) uses the analytic prior.

Emits the repo's CSV row format plus a machine-readable
``BENCH_bcd_eval.json``, and appends one line per run to the append-only
``BENCH_history.jsonl`` so the perf trajectory is recorded across PRs.

    PYTHONPATH=src python -m benchmarks.bench_bcd_eval \
        [--rt 32] [--chunk-size 8] [--prefetch auto] [--repeats 3] \
        [--out BENCH_bcd_eval.json] [--history BENCH_history.jsonl] \
        [--compile-cache DIR]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import time

import numpy as np
import jax

from repro.analysis.roofline import SuffixCostModel
from repro.configs import ARCH_IDS, get_config
from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, MarkovTokens, SyntheticImages
from repro.launch import compile_cache, mesh as mesh_lib
from repro.models.lm import LM
from repro.models.resnet import CNN, CNNConfig


def build_pipeline(image_size=16, eval_batch=128):
    """Mini ResNet config (same code path as the paper's ResNet18)."""
    model = CNN(CNNConfig("r18-mini", 4, image_size,
                          ((8, 2, 1), (16, 2, 2)), stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(
        n_classes=4, image_size=image_size, n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(eval_batch)
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def build_pipeline_family(arch: str, eval_batch=4, seq=33):
    """Per-family row: an LM arch at its reduced config on Markov tokens.

    Same downstream contract as the ResNet pipeline (``make_param_eval_fn``
    / ``make_suffix_eval_fns``); for scanned-stack families the deep depth
    site is a per-repeat virtual site (``s0.rwkv@1``), so its suffix row
    times the carry-checkpointed mid-scan cut."""
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mt = MarkovTokens(cfg.vocab, seed=0)
    batch = {"tokens": mt.batch(eval_batch, seq, 10**6)["tokens"]}
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def time_backend(evaluator, masks0, indices, chunk_size, repeats,
                 warmup=True):
    """Drive the real trial loop (materialize per chunk, prefetch-aware;
    site-aware backends run the site-major plan with per-sweep prefix
    recomputation — the per-BCD-step cost); return (cands/sec, us/cand).
    ``indices`` is an (n, k) removal array or a list of typed
    ``masks.Move`` candidates (the move-mix workload).  warmup=False skips
    the untimed compile-and-cache sweep (the evaluator was already
    warmed)."""
    # Match _select_block's chunk policy so the benchmark pays the same
    # per-chunk materialization cost the real loop pays.
    chunk_size = engine.effective_chunk(evaluator, chunk_size)
    flat, layout = M._flatten(masks0)
    typed = isinstance(indices, (list, tuple))
    n = len(indices)
    sited = getattr(evaluator, "site_aware", False)

    def sweep():
        if sited:
            evaluator.begin_step(masks0)
            order, chunks = engine.plan_sited_chunks(
                evaluator, indices, layout, chunk_size)
            gen = engine.materialize_sited(flat, layout, indices, order,
                                           chunks)
        elif typed:
            gen = M.materialize_move_chunks(flat, layout, indices,
                                            chunk_size)
        else:
            gen = M.materialize_chunks(flat, layout, indices, chunk_size)
        for accs in engine.evaluate_prefetched(evaluator, gen):
            pass

    if warmup:
        sweep()                              # warmup: compile + cache
    t0 = time.perf_counter()
    for _ in range(repeats):
        sweep()
    dt = time.perf_counter() - t0
    total = n * repeats
    return total / dt, dt / total * 1e6


def depth_sites(model):
    """Representative shallow / middle / deep cut sites (forward order)."""
    order = model.site_order()
    return {"shallow": order[0], "middle": order[len(order) // 2],
            "deep": order[-1]}


def append_history(path, report):
    """Append one compact line to the append-only perf-trajectory log."""
    try:
        git = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or None
    except Exception:
        git = None
    entry = {
        "utc": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "git": git,
        "config": report["config"],
        "cands_per_s": {k: v["cands_per_s"]
                        for k, v in report["backends"].items()},
        # per-depth rows feed SuffixCostModel.calibrated on later runs
        "per_site_depth": report["per_site_depth"],
        **{k: v for k, v in report.items() if k.startswith("speedup_")},
    }
    with open(path, "a") as f:
        json.dump(entry, f, separators=(",", ":"))
        f.write("\n")


def main():
    ap = argparse.ArgumentParser()
    # Defaults target the regime BCD actually runs in: a small train-subset
    # eval batch (the paper scores candidates on a subsample, not the full
    # set), where per-candidate dispatch/transfer/sync overhead is the
    # bottleneck the batched engine exists to amortize.  chunk-size defaults
    # to 8 (several chunks per RT sweep) so the pipelined backend has chunk
    # boundaries to overlap across; pass --chunk-size == --rt for the
    # one-call-per-sweep operating point.
    ap.add_argument("--rt", type=int, default=32)
    ap.add_argument("--chunk-size", type=int, default=8)
    # "auto" = measured-rate tuning (PrefetchAutoTuner): the depth locks
    # during the untimed warmup sweep, so timed sweeps run at the tuned
    # depth — same flag the example pipeline's sweep jobs pass.
    ap.add_argument("--prefetch",
                    type=lambda v: v if v == "auto" else int(v),
                    default="auto")
    # repeats: timed sweeps per measurement.  8 makes each timing window
    # ~0.3 s on the mini config — long enough that scheduler noise on a
    # 1-2 core host averages out instead of dominating a single sweep.
    ap.add_argument("--repeats", type=int, default=8)
    # Trials interleave across backends and each backend reports its MEDIAN
    # trial: on shared/noisy hosts (CI, this 2-core container) a single
    # measurement can swing ±30%, and a best-of would bias the committed
    # baseline to its upper envelope — making the CI regression gate fire
    # on ordinary noise.  The default is 5 so the committed baseline's
    # cross-backend ratios settle near their true values (the suffix
    # fallback path sits within a few percent of batched, so 3-trial
    # medians of the aggregate ratio still wander either side of parity);
    # CI's PR gate passes --trials 3 to trade precision for runtime.
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--drc", type=int, default=64)
    ap.add_argument("--arch", default="resnet",
                    choices=["resnet"] + list(ARCH_IDS),
                    help="workload family: 'resnet' (the default mini-CNN "
                         "row) or an LM arch id at its reduced config — "
                         "per-family rows land in the same history file, "
                         "keyed by config.model")
    ap.add_argument("--eval-batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_bcd_eval.json")
    ap.add_argument("--history", default=None,
                    help="append-only perf log (default: BENCH_history.jsonl"
                         " next to --out; pass 'none' to skip)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable the jax persistent compilation cache at "
                         "DIR (re-runs skip re-jit; hit counts are logged)")
    args = ap.parse_args()

    counter = None
    if args.compile_cache:
        compile_cache.enable(args.compile_cache)
        counter = compile_cache.hit_counter()

    if args.arch == "resnet":
        model, params, batch, masks0 = build_pipeline(
            eval_batch=args.eval_batch)
    else:
        model, params, batch, masks0 = build_pipeline_family(
            args.arch, eval_batch=args.eval_batch)
    repeat_sites = getattr(model, "site_repeats", lambda: None)()
    indices = M.sample_removal_indices(
        np.random.default_rng(0), masks0, args.drc, args.rt)
    # Don't let ragged-chunk padding exceed RT: with rt < chunk_size the
    # batched backend would evaluate padding candidates that can never
    # exist (sharded may still round up to the device count).
    chunk = min(args.chunk_size, args.rt)

    history = args.history
    if history is None:
        history = os.path.join(os.path.dirname(args.out) or ".",
                               "BENCH_history.jsonl")
    # Calibrate the suffix cost model from prior runs at this operating
    # point (missing/legacy history -> analytic prior, measured=None).
    fingerprint = {"model": model.cfg.name, "chunk_size": chunk,
                   "eval_batch": args.eval_batch,
                   "n_devices": jax.device_count(),
                   "backend": jax.default_backend()}
    cost_model = SuffixCostModel() if history == "none" else \
        SuffixCostModel.calibrated(history, fingerprint=fingerprint)
    if cost_model.measured:
        print(f"suffix cost model: calibrated from {history} "
              f"({len(cost_model.measured)} site points)")

    eval_acc = model.make_eval_acc(params, batch)
    # All vmapped backends run at the finetune-ready operating point the
    # example pipeline uses (examples/resnet18_bcd_pipeline.py): params ride
    # as evaluator *context* (a jit input swapped via set_context after each
    # finetune), never a baked closure constant.  Closure-of-params lets XLA
    # constant-fold the whole mask-independent stem (and delete init-valued
    # bn affines outright) — a compiled graph no real run ever executes, and
    # one that skews every cross-backend ratio.
    eval_fn_p = model.make_param_eval_fn(batch)
    suffix_ctx = {"params": params,
                  "batch": {k: np.asarray(v) for k, v in batch.items()}}
    # Measurement order per trial: suffix runs back-to-back with batched —
    # their paired ratio is the headline number, and adjacency minimizes the
    # host-drift window inside each pair.
    backends = {
        "sequential": engine.SequentialEvaluator(eval_acc),
        "batched": engine.BatchedEvaluator(eval_fn_p, pad_to=chunk,
                                           context=params),
        "suffix": engine.SuffixEvaluator(
            model.make_suffix_eval_fns(), pad_to=chunk, context=suffix_ctx,
            prefetch=args.prefetch, cost_model=cost_model),
        "sharded": engine.ShardedEvaluator(
            eval_fn_p, mesh_lib.make_candidate_mesh(), pad_to=chunk,
            context=params),
        "pipelined": engine.PipelinedEvaluator(
            eval_fn_p, pad_to=chunk, context=params,
            prefetch=args.prefetch),
    }

    trials = {name: [] for name in backends}
    for trial in range(max(1, args.trials)):
        for name, ev in backends.items():
            cps, _ = time_backend(ev, masks0, indices, chunk, args.repeats,
                                  warmup=(trial == 0))
            trials[name].append(cps)
    results = {}
    for name, cands in trials.items():
        cps = float(np.median(cands))
        results[name] = {"cands_per_s": round(cps, 2),
                         "us_per_cand": round(1e6 / cps, 2)}
        print(f"bcd_eval_{name},{1e6 / cps:.1f},{cps:.1f}")

    def paired_speedup(a, b):
        """median over trials of the within-trial a/b ratio.

        Backends interleave inside each trial (seconds apart), so a paired
        ratio cancels the minutes-scale host-speed drift that a
        ratio-of-medians is exposed to — on shared/throttled hosts the two
        estimators can disagree by several percent on backends that are
        near parity."""
        return round(float(np.median([x / y for x, y
                                      in zip(trials[a], trials[b])])), 2)

    # --- per-site-depth breakdown: site-local removal blocks, the regime
    # where every candidate in a chunk shares a deep prefix
    fractions = model.site_prefix_fractions()
    per_depth = {}
    for depth, site in depth_sites(model).items():
        site_idx = M.sample_removal_indices_within(
            np.random.default_rng(1), masks0, args.drc, args.rt, [site],
            repeat_sites=repeat_sites)
        rows = {"batched": [], "suffix": []}
        for name in rows:                     # compile + tune, untimed
            time_backend(backends[name], masks0, site_idx, chunk, 1)
        # sweep-level pairing: alternate single batched / suffix sweeps so
        # each ratio sample spans ~2 sweeps of wall-clock — host-speed
        # drift (minutes-scale on shared runners) cancels inside the pair,
        # which trial-level pairing can't do for near-parity rows
        for _ in range(max(1, args.trials) * args.repeats):
            for name in rows:
                cps, _ = time_backend(backends[name], masks0, site_idx,
                                      chunk, 1, warmup=False)
                rows[name].append(cps)
        b = float(np.median(rows["batched"]))
        s = float(np.median(rows["suffix"]))
        ratio = round(float(np.median([x / y for x, y
                                       in zip(rows["suffix"],
                                              rows["batched"])])), 2)
        frac = float(fractions[site])
        # what the evaluator's cost model decided for this site-local
        # workload (cold trie): "suffix" rows are real prefix-reuse
        # measurements — the only ones calibration may consume
        mode = "suffix" if cost_model.use_suffix(frac, chunk) else "fallback"
        per_depth[depth] = {
            "site": site,
            "prefix_fraction": round(frac, 4),
            "mode": mode,
            "batched_cands_per_s": round(b, 2),
            "suffix_cands_per_s": round(s, 2),
            "speedup_suffix_vs_batched": ratio,
        }
        print(f"bcd_eval_suffix_{depth},{site},{mode},"
              f"{per_depth[depth]['speedup_suffix_vs_batched']:.2f}x")

    # --- move-mix workload: typed candidates over all five kinds through
    # the batched backend, vs the same backend on removal-only blocks.
    # Prices the move vocabulary's trial-loop overhead (host-side
    # multi-coordinate application: off/on/tie assignment per candidate
    # instead of one put_along_axis) — a pure-overhead row, not a speedup
    # claim, reported outside ``config`` so baseline compares don't treat
    # the workload mix as an operating-point change.
    mixed_moves = M.sample_moves(
        np.random.default_rng(2), masks0, args.drc, args.rt,
        kinds=M.MOVE_KINDS, max_remove=4 * args.drc)
    move_rows = {"removal": [], "moves": []}
    time_backend(backends["batched"], masks0, mixed_moves, chunk, 1)
    for _ in range(max(1, args.trials)):
        cps, _ = time_backend(backends["batched"], masks0, indices, chunk,
                              args.repeats, warmup=False)
        move_rows["removal"].append(cps)
        cps, _ = time_backend(backends["batched"], masks0, mixed_moves,
                              chunk, args.repeats, warmup=False)
        move_rows["moves"].append(cps)
    move_mix = {
        "kinds": list(M.MOVE_KINDS),
        "removal_cands_per_s": round(float(np.median(
            move_rows["removal"])), 2),
        "moves_cands_per_s": round(float(np.median(
            move_rows["moves"])), 2),
        "ratio_moves_vs_removal": round(float(np.median(
            [x / y for x, y in zip(move_rows["moves"],
                                   move_rows["removal"])])), 2),
    }
    print(f"bcd_eval_move_mix,batched,"
          f"{move_mix['ratio_moves_vs_removal']:.2f}x")

    report = {
        "bench": "bcd_eval",
        "config": {"rt": args.rt, "chunk_size": chunk,
                   "prefetch": args.prefetch,
                   "drc": args.drc, "repeats": args.repeats,
                   "trials": args.trials,
                   "eval_batch": args.eval_batch,
                   "model": model.cfg.name,
                   "n_devices": jax.device_count(),
                   "backend": jax.default_backend(),
                   "calibrated": bool(cost_model.measured),
                   # provenance: identifies what produced a committed
                   # baseline without entering the operating-point compare
                   "provenance": {
                       "jax": jax.__version__,
                       "platform": jax.default_backend(),
                       "device_kind": jax.devices()[0].device_kind,
                   }},
        "backends": results,
        "per_site_depth": per_depth,
        "move_mix": move_mix,
        "speedup_batched_vs_sequential":
            paired_speedup("batched", "sequential"),
        "speedup_sharded_vs_sequential":
            paired_speedup("sharded", "sequential"),
        "speedup_pipelined_vs_sequential":
            paired_speedup("pipelined", "sequential"),
        "speedup_pipelined_vs_batched":
            paired_speedup("pipelined", "batched"),
        # headline prefix-reuse numbers, each with an explicit suffix: the
        # deep-site ratio, the shallow all-fallback floor, the mean over
        # depth classes (deep+mean CI-gated vs baseline; mean+shallow
        # floored absolutely), and the global-workload aggregate
        "speedup_suffix_vs_batched_deep":
            per_depth["deep"]["speedup_suffix_vs_batched"],
        "speedup_suffix_vs_batched_shallow":
            per_depth["shallow"]["speedup_suffix_vs_batched"],
        "speedup_suffix_vs_batched_mean": round(
            float(np.mean([d["speedup_suffix_vs_batched"]
                           for d in per_depth.values()])), 2),
        "speedup_suffix_vs_batched_aggregate":
            paired_speedup("suffix", "batched"),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if history != "none":
        append_history(history, report)
    print(f"batched vs sequential: "
          f"{report['speedup_batched_vs_sequential']:.2f}x; "
          f"suffix vs batched: deep "
          f"{report['speedup_suffix_vs_batched_deep']:.2f}x, shallow "
          f"{report['speedup_suffix_vs_batched_shallow']:.2f}x, mean "
          f"{report['speedup_suffix_vs_batched_mean']:.2f}x, aggregate "
          f"{report['speedup_suffix_vs_batched_aggregate']:.2f}x"
          f"  -> {args.out}")
    if counter is not None:
        print(counter.log_line())
    return report


if __name__ == "__main__":
    main()
