"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Output: ``name,us_per_call,derived`` CSV rows (derived = the table's metric).
All paper tables are accuracy-vs-budget pipelines; offline they run the same
algorithms at reduced scale on synthetic CIFAR (EXPERIMENTS.md documents the
mapping; absolute accuracies differ from the paper, relative claims hold).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import analysis, autorep, linearize, masks as M, pi_cost


def bench_table23_bcd_vs_snl():
    """Tables 2 & 3 / Fig. 1: accuracy vs ReLU budget, SNL vs SNL+BCD."""
    model, data, params, loss_fn, batches, masks0 = C.trained_pipeline()
    sloss = C.soft_loss_fn(model)
    total = M.count(masks0)
    for frac in (0.25, 0.1):
        b_target = int(total * frac)
        b_ref = int(total * (frac + 0.15))
        t0 = time.perf_counter()
        res_ref = C.run_snl_to(model, params, sloss, batches, masks0, b_ref)
        res_snl = C.run_snl_to(model, params, sloss, batches, masks0,
                               b_target)
        acc_snl = C.test_acc(model, res_snl.params, res_snl.masks, data)
        holder = {"params": res_ref.params}
        res_bcd = C.run_bcd_from(model, data, holder, sloss, batches,
                                 res_ref.masks, b_target)
        acc_bcd = C.test_acc(model, holder["params"], res_bcd.masks, data)
        us = (time.perf_counter() - t0) * 1e6
        C.row(f"table23.budget={b_target}", us,
              f"snl_acc={acc_snl:.1f};bcd_acc={acc_bcd:.1f};"
              f"budget_exact={M.count(res_bcd.masks) == b_target}")


def bench_fig4_bcd_on_autorep():
    """Fig. 4: BCD on top of AutoReP (poly2 replacement)."""
    model, data, params, loss_fn, batches, masks0 = C.trained_pipeline(seed=1)
    sites = {k: linearize.MaskSite(s.shape, "relu", "poly2")
             for k, s in model.mask_sites().items()}
    alphas = {k: jnp.full(s.shape, 0.5) for k, s in sites.items()}
    poly = linearize.init_poly(sites)
    total = M.count(masks0)
    b_ref, b_target = int(total * 0.35), int(total * 0.15)

    def loss3(p, m, q, batch, soft):
        logits = model.forward(p, m, batch["images"], poly=q, soft=soft)
        from repro.training.train import cross_entropy
        return cross_entropy(logits, batch["labels"]), 0.0

    t0 = time.perf_counter()
    res_ar = autorep.run_autorep(
        params, alphas, poly, loss3, batches,
        autorep.AutoRepConfig(b_target=b_ref, epochs=4, steps_per_epoch=5,
                              lr=3e-2, finetune_steps=10))
    acc_ar = C.test_acc(model, res_ar.params, res_ar.masks, data)
    holder = {"params": res_ar.params}
    sloss = C.soft_loss_fn(model)
    res_bcd = C.run_bcd_from(model, data, holder, sloss, batches,
                             res_ar.masks, b_target)
    acc_bcd = C.test_acc(model, holder["params"], res_bcd.masks, data)
    us = (time.perf_counter() - t0) * 1e6
    C.row("fig4.autorep+bcd", us,
          f"autorep@{b_ref}={acc_ar:.1f};bcd@{b_target}={acc_bcd:.1f}")


def bench_fig5_ablations():
    """Fig. 5: DRC / finetune-epochs / ADT ablations."""
    model, data, params, loss_fn, batches, masks0 = C.trained_pipeline(seed=2)
    sloss = C.soft_loss_fn(model)
    total = M.count(masks0)
    b_ref, b_target = int(total * 0.35), int(total * 0.15)
    res_ref = C.run_snl_to(model, params, sloss, batches, masks0, b_ref)
    for drc_frac, name in ((0.05, "small"), (0.25, "large")):
        drc = max(1, int((b_ref - b_target) * drc_frac))
        holder = {"params": res_ref.params}
        t0 = time.perf_counter()
        res = C.run_bcd_from(model, data, holder, sloss, batches,
                             res_ref.masks, b_target, drc=drc)
        acc = C.test_acc(model, holder["params"], res.masks, data)
        C.row(f"fig5a.drc_{name}", (time.perf_counter() - t0) * 1e6,
              f"drc={drc};acc={acc:.1f};steps={len(res.history)}")
    for ft_steps in (2, 12):
        holder = {"params": res_ref.params}
        t0 = time.perf_counter()
        res = C.run_bcd_from(model, data, holder, sloss, batches,
                             res_ref.masks, b_target, ft_steps=ft_steps)
        acc = C.test_acc(model, holder["params"], res.masks, data)
        C.row(f"fig5b.ft={ft_steps}", (time.perf_counter() - t0) * 1e6,
              f"acc={acc:.1f}")
    for adt in (0.1, 1.0):
        holder = {"params": res_ref.params}
        t0 = time.perf_counter()
        res = C.run_bcd_from(model, data, holder, sloss, batches,
                             res_ref.masks, b_target, adt=adt)
        acc = C.test_acc(model, holder["params"], res.masks, data)
        trials = sum(h.trials for h in res.history)
        C.row(f"fig5c.adt={adt}", (time.perf_counter() - t0) * 1e6,
              f"acc={acc:.1f};total_trials={trials}")


def bench_fig6_mask_iou():
    """Fig. 6: IoU of masks along an SNL optimization path (> 0.85)."""
    model, data, params, loss_fn, batches, masks0 = C.trained_pipeline(seed=3)
    sloss = C.soft_loss_fn(model)
    total = M.count(masks0)
    t0 = time.perf_counter()
    res = C.run_snl_to(model, params, sloss, batches, masks0,
                       int(total * 0.4), epochs=8)
    snaps = [s for s in res.snapshots if M.count(s) > 0]
    ious = analysis.consecutive_iou(snaps)
    frac = analysis.golden_set_fraction(snaps)
    C.row("fig6.snl_iou", (time.perf_counter() - t0) * 1e6,
          f"min_consec_iou={min(ious):.3f};frac_pairs_gt_0.85={frac:.2f}")


def bench_fig7_relu_distribution():
    """Fig. 7: per-layer ReLU distribution of the BCD result."""
    model, data, params, loss_fn, batches, masks0 = C.trained_pipeline(seed=4)
    sloss = C.soft_loss_fn(model)
    total = M.count(masks0)
    holder = {"params": params}
    t0 = time.perf_counter()
    res = C.run_bcd_from(model, data, holder, sloss, batches, masks0,
                         int(total * 0.5))
    dist = analysis.layer_distribution(res.masks)
    kept = ";".join(f"{k}={a}/{b}" for k, (a, b) in list(dist.items())[:4])
    C.row("fig7.distribution", (time.perf_counter() - t0) * 1e6, kept)


def bench_table1_relu_counts():
    """Table 1: total ReLUs per backbone × image size."""
    from repro.models.resnet import CNN, CNNConfig
    t0 = time.perf_counter()
    vals = {}
    for name, mk, sz in (("resnet18", CNNConfig.resnet18, 32),
                         ("resnet18", CNNConfig.resnet18, 64),
                         ("wrn22_8", CNNConfig.wrn22_8, 32),
                         ("wrn22_8", CNNConfig.wrn22_8, 64)):
        vals[f"{name}@{sz}"] = CNN(mk(10, sz)).relu_count()
    C.row("table1.relu_counts", (time.perf_counter() - t0) * 1e6,
          ";".join(f"{k}={v}" for k, v in vals.items()))


def bench_pi_latency():
    """Intro claim: PI latency scales with ReLU count (DELPHI cost model)."""
    t0 = time.perf_counter()
    parts = []
    for budget in (570_000, 100_000, 15_000, 6_000):
        c = pi_cost.cost(budget, 17)
        parts.append(f"B={budget}:lat={c.online_latency_s:.2f}s")
    C.row("pi.latency_model", (time.perf_counter() - t0) * 1e6,
          ";".join(parts))


def bench_kernel_masked_act():
    """Kernel microbench: fused masked activation (jnp path timing on CPU;
    the Pallas path is validated in interpret mode in tests)."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096, 4096)).astype(np.float32))
    m = jnp.asarray((rng.random(4096) > 0.5).astype(np.float32))
    f = jax.jit(lambda x, m: ops.masked_act(x, m, kind="relu"))
    f(x, m).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(x, m).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    gb = x.size * 4 * 2 / 1e9
    C.row("kernel.masked_act", us, f"GBps={gb / (us / 1e6):.1f}")


def bench_lm_linearize():
    """Beyond-paper: BCD linearization of a reduced LM (FFN channel masks)."""
    from repro.configs import get_config
    from repro.core import bcd
    from repro.models.lm import LM
    from repro.data import MarkovTokens
    from repro.training import optimizer as opt_lib, train as train_lib
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    mt = MarkovTokens(cfg.vocab, seed=0)
    opt = opt_lib.adamw(lr=2e-3)
    step = jax.jit(train_lib.make_train_step(
        model, opt, train_lib.TrainStepCfg(remat=False, dp_axes=())))
    state = train_lib.make_state(model, opt, jax.random.PRNGKey(1))
    masks0 = linearize.init_masks(model.mask_sites())
    mdev = M.as_device(masks0)
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in mt.batch(8, 64, i).items()}
        state, metrics = step(state, b, mdev)
    eval_b = {k: jnp.asarray(v) for k, v in mt.batch(16, 64, 999).items()}

    @jax.jit
    def acc(masks):
        logits, _ = model.forward(state["params"], masks, eval_b["tokens"])
        return jnp.mean((jnp.argmax(logits, -1) == eval_b["labels"])
                        .astype(jnp.float32)) * 100
    total = M.count(masks0)
    t0 = time.perf_counter()
    res = bcd.run_bcd(
        masks0, bcd.BCDConfig(b_target=total // 2, drc=total // 8, rt=4,
                              adt=0.5, finetune_every_step=False),
        lambda m: float(acc(M.as_device(m))))
    a = float(acc(M.as_device(res.masks)))
    C.row("lm.bcd_linearize", (time.perf_counter() - t0) * 1e6,
          f"budget={M.count(res.masks)}/{total};token_acc={a:.1f}")


ALL = [bench_table1_relu_counts, bench_pi_latency, bench_kernel_masked_act,
       bench_fig6_mask_iou, bench_fig7_relu_distribution,
       bench_fig5_ablations, bench_table23_bcd_vs_snl,
       bench_fig4_bcd_on_autorep, bench_lm_linearize]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            C.row(fn.__name__, 0.0, f"ERROR:{type(e).__name__}:{e}")
            import traceback
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
