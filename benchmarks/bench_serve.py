"""Load generator for the continuous-batching serve loop.

Drives ``repro.launch.serve_loop.ServeLoop`` with a deterministic stream of
random-token requests across ≥2 SLO classes (each routed to a different
ReLU-budget mask set) and writes ``BENCH_serve.json``:

- per class: requests served, decode tok/s, p50/p95 queue / prefill /
  decode / total latency (ms), the class's ReLU cost and PI-priced online
  seconds per token, and the summed per-request PI bill;
- totals: submitted vs completed (the drain check), wall seconds, and
  aggregate decode tok/s.

CI gates this report with ``check_bench_regression --serve`` against the
committed baseline:

    PYTHONPATH=src python -m benchmarks.bench_serve --out BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_serve.json BENCH_serve_new.json --serve
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.launch import serve_loop
from repro.models.lm import LM
from repro.training import serve as serve_lib


def build_loop(args):
    """Model + mask-set store + ServeLoop from CLI args."""
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.masks_from:
        shapes = {k: s.shape for k, s in model.mask_sites().items()}
        store = serve_lib.MaskSetStore.from_run_dir(args.masks_from, shapes)
    else:
        fracs = [float(x) for x in args.budget_fracs.split(",")]
        store = serve_loop.threshold_mask_sets(model, fracs, seed=args.seed)
    classes = serve_loop.default_classes(store, args.max_new)
    loop = serve_loop.ServeLoop(
        model, params, store, classes, slots=args.slots,
        max_len=args.max_len, prompt_bucket=args.prompt_bucket)
    return cfg, loop


def run_load(loop, cfg, args):
    """Submit the deterministic request stream and drain the loop."""
    rng = np.random.default_rng(args.seed)
    names = list(loop.lanes)
    for i in range(args.requests):
        slo = names[i % len(names)]
        cap = args.max_len - loop.lanes[slo].slo.max_new_tokens
        plen = int(rng.integers(2, max(3, cap)))
        loop.submit(rng.integers(0, cfg.vocab, plen), slo)
    t0 = time.perf_counter()
    loop.shutdown(drain=True)
    return time.perf_counter() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--prompt-bucket", type=int, default=16)
    ap.add_argument("--budget-fracs", default="1.0,0.25",
                    help="comma keep-fracs -> synthetic mask sets; one SLO "
                         "class per set (≥2 for the CI contract)")
    ap.add_argument("--masks-from", default=None, metavar="RUN_DIR",
                    help="serve checkpointed sweep masks instead")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve_new.json")
    args = ap.parse_args(argv)

    cfg, loop = build_loop(args)
    # warm the compiled prefill/decode shapes so measured latencies are
    # steady-state, not jit time
    warm = serve_loop.ServeLoop(
        loop.model, loop.params, loop.store,
        serve_loop.default_classes(loop.store, 2), slots=args.slots,
        max_len=args.max_len, prompt_bucket=args.prompt_bucket)
    warm.submit(np.arange(1, 3), warm.store.names[0])
    warm.shutdown(drain=True)

    wall = run_load(loop, cfg, args)
    stats = loop.stats()
    gen = sum(len(r.tokens) - 1 for r in loop.completed)
    report = {
        "bench": "serve",
        "config": {"model": args.arch + (":reduced" if args.reduced else ""),
                   "slots": args.slots, "max_len": args.max_len,
                   "max_new": args.max_new,
                   "prompt_bucket": args.prompt_bucket,
                   "requests": args.requests,
                   "budget_fracs": args.budget_fracs,
                   "masks_from": args.masks_from,
                   "n_devices": jax.device_count(), "seed": args.seed},
        "classes": stats["classes"],
        "total": {"submitted": args.requests,
                  "completed": stats["completed"],
                  "drained": stats["pending"] == 0,
                  "wall_s": wall,
                  "decode_tok_s": gen / wall if wall > 0 else 0.0},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for name, c in report["classes"].items():
        print(f"{name}: {c['requests']} reqs, "
              f"{c.get('decode_tok_s', 0):.1f} tok/s, "
              f"p95 total {c.get('total_ms_p95', 0):.0f} ms, "
              f"relu_cost {c['relu_cost']}")
    print(f"wrote {args.out} ({report['total']['completed']}/"
          f"{report['total']['submitted']} completed in {wall:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
