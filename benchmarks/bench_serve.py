"""Load generator for the continuous-batching serve loop.

Drives ``repro.launch.serve_loop.ServeLoop`` with a deterministic stream of
random-token requests across ≥2 SLO classes (each routed to a different
ReLU-budget mask set) and writes ``BENCH_serve.json``:

- per class: requests served, decode tok/s, p50/p95 queue / prefill /
  decode / total latency (ms), the class's ReLU cost and PI-priced online
  seconds per token, and the summed per-request PI bill;
- totals: submitted vs completed (the drain check), wall seconds, and
  aggregate decode tok/s.

**Overload mode** (``--overload N``): arrivals are generated at N× the
loop's modeled service capacity under a virtual clock, with per-class
deadlines, a bounded admission queue, a :class:`DegradationLadder` over the
stored budgets, and (``--fault-plan default``) the committed chaos
:class:`FaultPlan` injected at every crosspoint.  The report gains an
``overload`` section — deadline-hit-rate, goodput (tokens delivered within
deadline per second), degrade/shed rates, retries, and the sha256 of the
admit/degrade/shed decision log.  Virtual time makes every number in that
section bit-for-bit reproducible for a given seed + plan, which is what
lets CI gate it tightly.

CI gates these reports with ``check_bench_regression --serve``:

    PYTHONPATH=src python -m benchmarks.bench_serve --out BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.bench_serve --overload 3 \
        --fault-plan default --out BENCH_serve_overload.json
    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_serve_overload.json BENCH_serve_overload_new.json --serve
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.core import pi_cost
from repro.launch import faults, serve_loop
from repro.models.lm import LM
from repro.training import serve as serve_lib

#: Protocol used for overload runs: bandwidth-bound (12.5 MB/s ≈ 100 Mb/s
#: WAN) so per-token latency scales with the mask set's ReLU count and the
#: budget ladder's rungs have materially different prices — with the
#: default 1 Gb/s + 10 ms RTT protocol, round-trips dominate at reduced
#: scale and degradation would buy almost nothing.
OVERLOAD_PROTO = pi_cost.PIProtocol(bandwidth_bytes_per_s=12.5e6,
                                    rtt_s=0.001)


def parse_args(argv=None):
    """CLI for both the fair-weather and the overload load shapes."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--prompt-bucket", type=int, default=16)
    ap.add_argument("--budget-fracs", default="1.0,0.25",
                    help="comma keep-fracs -> synthetic mask sets; one SLO "
                         "class per set (≥2 for the CI contract)")
    ap.add_argument("--masks-from", default=None, metavar="RUN_DIR",
                    help="serve checkpointed sweep masks instead")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--overload", type=float, default=0.0, metavar="FACTOR",
                    help="generate arrivals at FACTOR x modeled capacity "
                         "under a virtual clock with deadlines, a bounded "
                         "queue, and the degradation ladder (0 = off)")
    ap.add_argument("--fault-plan", choices=("none", "default"),
                    default="none",
                    help="chaos schedule injected during overload runs")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--deadline-slack", type=float, default=2.5,
                    help="per-class deadline = slack x modeled mean "
                         "request latency under the class's own set")
    ap.add_argument("--queue-cap", type=int, default=4,
                    help="bounded per-class admission queue (overload mode)")
    ap.add_argument("--out", default="BENCH_serve_new.json")
    return ap.parse_args(argv)


def _mean_prompt_len(args) -> float:
    cap = args.max_len - args.max_new
    return (2 + max(3, cap) - 1) / 2            # mean of the submit range


def _overload_classes(store, args):
    """One deadlined SLO class per budget; deadline = slack × its own
    modeled mean request latency (deterministic: pure cost model)."""
    classes = []
    mean_total = _mean_prompt_len(args) + args.max_new
    for name in store.names:
        per = store.pi_cost_per_token(name, OVERLOAD_PROTO).online_latency_s
        deadline_ms = args.deadline_slack * per * mean_total * 1e3
        classes.append(serve_loop.SLOClass(
            name=name, mask_set=name, max_new_tokens=args.max_new,
            deadline_ms=deadline_ms))
    return classes


def make_fault_plan(args):
    """The committed chaos schedule, or None."""
    if args.fault_plan == "default":
        return faults.default_chaos_plan(seed=args.fault_seed)
    return None


def build_loop(args):
    """Model + mask-set store + ServeLoop from CLI args."""
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.masks_from:
        shapes = {k: s.shape for k, s in model.mask_sites().items()}
        store = serve_lib.MaskSetStore.from_run_dir(args.masks_from, shapes)
    else:
        fracs = [float(x) for x in args.budget_fracs.split(",")]
        store = serve_loop.threshold_mask_sets(model, fracs, seed=args.seed)
    if args.overload:
        loop = serve_loop.ServeLoop(
            model, params, store, _overload_classes(store, args),
            slots=args.slots, max_len=args.max_len,
            prompt_bucket=args.prompt_bucket,
            ladder=serve_loop.DegradationLadder.from_store(store),
            queue_cap=args.queue_cap, clock=faults.VirtualClock(),
            fault_plan=make_fault_plan(args), proto=OVERLOAD_PROTO)
    else:
        classes = serve_loop.default_classes(store, args.max_new)
        loop = serve_loop.ServeLoop(
            model, params, store, classes, slots=args.slots,
            max_len=args.max_len, prompt_bucket=args.prompt_bucket)
    return cfg, loop


def run_load(loop, cfg, args):
    """Submit the deterministic request stream and drain the loop."""
    rng = np.random.default_rng(args.seed)
    names = list(loop.lanes)
    for i in range(args.requests):
        slo = names[i % len(names)]
        cap = args.max_len - loop.lanes[slo].slo.max_new_tokens
        plen = int(rng.integers(2, max(3, cap)))
        loop.submit(rng.integers(0, cfg.vocab, plen), slo)
    t0 = time.perf_counter()
    loop.shutdown(drain=True)
    return time.perf_counter() - t0


def run_overload(loop, cfg, args):
    """Arrivals at ``--overload`` × modeled capacity, stepped per arrival.

    Mean service seconds per request is the modeled per-token cost
    averaged over classes, times mean (prompt + generated) tokens; the
    interarrival gap divides that by ``factor × total slots``.  A
    ``burst`` fault replaces the gap with a same-instant batch of extra
    arrivals — that is what drives queues into their bound.  Returns the
    number of requests submitted (bursts included).
    """
    rng = np.random.default_rng(args.seed)
    names = list(loop.lanes)
    mean_total = _mean_prompt_len(args) + args.max_new
    mean_service = float(np.mean(
        [loop.latency.estimate_s(loop.lanes[n].slo.mask_set,
                                 _mean_prompt_len(args), args.max_new)
         for n in names]))
    gap_s = mean_service / (args.overload * args.slots * len(names))
    submitted = 0

    def _arrival(i):
        slo = names[i % len(names)]
        cap = args.max_len - loop.lanes[slo].slo.max_new_tokens
        plen = int(rng.integers(2, max(3, cap)))
        loop.submit(rng.integers(0, cfg.vocab, plen), slo)

    i = 0
    while submitted < args.requests:
        loop.clock.advance(gap_s)
        _arrival(i)
        i += 1
        submitted += 1
        fault = loop.fault_plan.draw("burst") if loop.fault_plan else None
        if fault is not None and fault.kind == "burst":
            for _ in range(fault.burst):
                _arrival(i)
                i += 1
                submitted += 1
        loop.step()
    t0 = time.perf_counter()
    loop.shutdown(drain=True)
    return submitted, time.perf_counter() - t0


def overload_report(loop, stats, submitted, factor, plan):
    """The gated ``overload`` section: every number here is virtual-time
    deterministic for a given (seed, plan)."""
    expired = sum(r.shed_reason == "deadline_expired" for r in loop.shed)
    return {
        "factor": factor,
        "fault_plan": plan.describe() if plan else None,
        "submitted": submitted,
        "terminal": stats["terminal"],
        "all_terminal": (stats["terminal"] == submitted
                         and stats["pending"] == 0),
        "served": sum(r.state == "served" for r in loop.completed),
        "degraded": sum(r.state == "degraded" for r in loop.completed),
        "shed": stats["shed"],
        "expired": expired,
        "deadline_hit_rate": stats["deadline_hit_rate"],
        "goodput_tok_s": stats["goodput_tok_s"],
        "degrade_rate": stats["degrade_rate"],
        "shed_rate": stats["shed_rate"],
        "retries": stats["retries"],
        "faults_injected": stats["faults_injected"],
        "decisions_sha256": stats["decisions_sha256"],
    }


def run_bench(args):
    """Build, warm, drive, and report; returns ``(loop, report)``.

    Importable entry point: the CI chaos-smoke job reruns this and asserts
    over ``loop.completed`` / ``loop.shed`` directly.
    """
    cfg, loop = build_loop(args)
    # warm the compiled prefill/decode shapes so measured latencies are
    # steady-state, not jit time
    warm = serve_loop.ServeLoop(
        loop.model, loop.params, loop.store,
        serve_loop.default_classes(loop.store, 2), slots=args.slots,
        max_len=args.max_len, prompt_bucket=args.prompt_bucket)
    warm.submit(np.arange(1, 3), warm.store.names[0])
    warm.shutdown(drain=True)

    if args.overload:
        submitted, wall = run_overload(loop, cfg, args)
    else:
        submitted, wall = args.requests, run_load(loop, cfg, args)
    stats = loop.stats()
    gen = sum(len(r.tokens) - 1 for r in loop.completed)
    report = {
        "bench": "serve",
        "config": {"model": args.arch + (":reduced" if args.reduced else ""),
                   "slots": args.slots, "max_len": args.max_len,
                   "max_new": args.max_new,
                   "prompt_bucket": args.prompt_bucket,
                   "requests": args.requests,
                   "budget_fracs": args.budget_fracs,
                   "masks_from": args.masks_from,
                   "n_devices": jax.device_count(), "seed": args.seed,
                   "overload": args.overload,
                   "fault_plan": args.fault_plan,
                   "fault_seed": args.fault_seed,
                   "deadline_slack": args.deadline_slack,
                   "queue_cap": args.queue_cap if args.overload else None},
        "classes": stats["classes"],
        "total": {"submitted": submitted,
                  "completed": stats["completed"],
                  "drained": stats["pending"] == 0,
                  "wall_s": wall,
                  "decode_tok_s": gen / wall if wall > 0 else 0.0},
    }
    if args.overload:
        report["overload"] = overload_report(
            loop, stats, submitted, args.overload, loop.fault_plan)
    return loop, report


def main(argv=None):
    args = parse_args(argv)
    loop, report = run_bench(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for name, c in report["classes"].items():
        print(f"{name}: {c['requests']} reqs "
              f"({c['served']} served, {c['degraded']} degraded, "
              f"{c['shed']} shed), "
              f"p95 total {c.get('total_ms_p95', 0):.0f} ms, "
              f"relu_cost {c['relu_cost']}")
    if "overload" in report:
        o = report["overload"]
        print(f"overload x{o['factor']}: {o['terminal']}/{o['submitted']} "
              f"terminal, deadline-hit {o['deadline_hit_rate']:.2f}, "
              f"goodput {o['goodput_tok_s']:.1f} tok/s, "
              f"degrade {o['degrade_rate']:.2f}, shed {o['shed_rate']:.2f}")
    print(f"wrote {args.out} ({report['total']['completed']}/"
          f"{report['total']['submitted']} completed in "
          f"{report['total']['wall_s']:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
