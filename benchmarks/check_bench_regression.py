"""Gate candidates/sec against the committed benchmark baseline.

Compares a fresh ``bench_bcd_eval`` report against the repo's committed
``BENCH_bcd_eval.json`` and exits non-zero when any backend's candidates/sec
dropped by more than ``--tolerance`` (default 30%).  Backends present in only
one of the two reports are reported but never fail the gate (so adding a
backend does not require a lockstep baseline refresh).  Faster-than-baseline
results print a note suggesting a refresh.

    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_bcd_eval.json BENCH_new.json [--tolerance 0.30] \
        [--gate-speedup KEY ...] [--floor KEY=MIN ...]

``--floor speedup_suffix_vs_batched_mean=2.0`` gates a top-level speedup
key of the FRESH report against an absolute minimum (no baseline
involved — within-report ratios are hardware-robust, so an absolute
floor is meaningful even on a slow CI runner).  Repeatable; a floored
key missing from the fresh report is exit 2, like --gate-speedup.

``--sweep-acc`` switches the gate to *accuracy-at-budget* mode over two
``launch.sweep`` artifacts (``SWEEP_<model>.json``) instead of two bench
reports: at every budget present in both curves, the fresh artifact's
``test_acc`` must be at least the baseline's minus ``--acc-tolerance``
(absolute accuracy points, default 0).  CI uses it to assert that the
richer move vocabulary never loses accuracy against the removal-only
descent at the same budget schedule:

    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        SWEEP_removal.json SWEEP_mixed.json --sweep-acc [--acc-tolerance 0.5]

``--serve`` switches the gate to *serving* mode over two
``benchmarks.bench_serve`` reports (``BENCH_serve.json``): per SLO class
common to both, fresh decode tok/s must hold ``>= baseline * (1 -
--tolerance)`` and fresh p95 total latency must stay ``<= baseline p95 *
--latency-factor`` (default 3.0 — generous because absolute latencies on a
shared CI runner are noisy; throughput carries the tight gate).  The fresh
report must also have served every submitted request and drained its
queues — an undrained loop is a scheduler bug, not noise:

    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_serve.json BENCH_serve_new.json --serve [--latency-factor 3]

When both serve reports carry an ``overload`` section (produced by
``bench_serve --overload``), the gate additionally requires the fresh
run's deadline-hit-rate to hold within ``--tolerance`` (absolute) of the
baseline, goodput within ``--tolerance`` (ratio), shed-rate under
baseline + tolerance, and **every** submitted request to have reached a
terminal state.  Overload and fair-weather reports are never comparable
(exit 2), and a class that completed zero requests (everything shed) is
unusable input — fix the operating point, exit 2.

Exit codes: 0 pass, 1 candidates/sec regression, floor violation,
accuracy-at-budget drop, or serve-mode throughput/latency/drain/overload
failure,
2 unusable input (missing or malformed report,
incomparable operating points, malformed/missing gate key, unscored or
non-overlapping sweep curves) — always with a human-readable FAIL
line, never a traceback, so CI logs say what to fix.
A backend sitting exactly at the threshold (ratio == 1 - tolerance) passes:
the gate fails only on drops strictly beyond the tolerance, with a small
epsilon so float rounding cannot flip an at-threshold result.
"""
from __future__ import annotations

import argparse
import json
import sys

# Guards the exactly-at-threshold case against float rounding: 1.0 - 0.30
# is a hair above the literal 0.70, which would otherwise fail a backend
# sitting exactly at 70% of baseline.
_EPS = 1e-9

# Config keys that define the benchmark's operating point: two reports are
# only comparable when all of these match.  Timing-precision knobs
# (repeats, trials) and host identity deliberately excluded — but note the
# committed baseline must come from hardware comparable to where the gate
# runs; refresh it from the CI artifact if the fleet changes.
OPERATING_POINT_KEYS = ("rt", "chunk_size", "prefetch", "drc", "eval_batch",
                        "model", "n_devices", "backend")

# Same idea for serving reports: two BENCH_serve.json runs are only
# comparable at the same model / slot count / sequence budget / load —
# and, for overload runs, the same overload factor, fault plan + seed,
# deadline slack, and queue bound (they define the chaos schedule the
# decision log is replayed against).
SERVE_OPERATING_POINT_KEYS = ("model", "slots", "max_len", "max_new",
                              "prompt_bucket", "requests", "budget_fracs",
                              "n_devices", "overload", "fault_plan",
                              "fault_seed", "deadline_slack", "queue_cap")

# The overload section's gated metrics: all must be numeric rates/counts.
OVERLOAD_NUMERIC_KEYS = ("factor", "submitted", "terminal", "served",
                         "degraded", "shed", "expired", "deadline_hit_rate",
                         "goodput_tok_s", "degrade_rate", "shed_rate")


def config_mismatches(baseline: dict, fresh: dict) -> list:
    """Operating-point keys whose values differ between the two reports."""
    base_c = baseline.get("config", {})
    new_c = fresh.get("config", {})
    return [f"{k}: baseline={base_c.get(k)!r} fresh={new_c.get(k)!r}"
            for k in OPERATING_POINT_KEYS
            if base_c.get(k) != new_c.get(k)]


def compare(baseline: dict, fresh: dict, tolerance: float,
            relative_to: str | None = None):
    """Returns (failures, lines): failed backend names + a report line per
    backend common to both reports.

    relative_to: normalize every backend's candidates/sec by the named
    backend *within the same report* before comparing.  Self-normalizing
    across hosts (a slower CI runner scales all backends alike), at the
    cost of missing a slowdown that hits the reference backend equally —
    pair with an occasional same-host absolute check.
    """
    base_b = baseline.get("backends", {})
    new_b = fresh.get("backends", {})

    def rate(backends, name):
        v = float(backends[name]["cands_per_s"])
        if relative_to:
            v /= float(backends[relative_to]["cands_per_s"])
        return v

    unit = f"x {relative_to}" if relative_to else "cands/s"
    failures, lines = [], []
    for name in sorted(set(base_b) | set(new_b)):
        if name not in base_b or name not in new_b:
            lines.append(f"  {name}: only in "
                         f"{'baseline' if name in base_b else 'fresh run'} "
                         "(skipped)")
            continue
        old, new = rate(base_b, name), rate(new_b, name)
        ratio = new / old if old > 0 else float("inf")
        status = "OK"
        if ratio < 1.0 - tolerance - _EPS:
            status = "REGRESSION"
            failures.append(name)
        elif ratio > 1.0 + tolerance:
            status = "faster (consider refreshing the baseline)"
        lines.append(f"  {name}: {old:.2f} -> {new:.2f} {unit} "
                     f"({ratio:.2f}x)  {status}")
    return failures, lines


def compare_speedup_keys(baseline: dict, fresh: dict, keys, tolerance: float):
    """Gate top-level ``speedup_*`` report keys (e.g.
    ``speedup_suffix_vs_batched``).

    These are *within-report* backend ratios, so they are hardware-robust
    the same way ``--relative-to`` normalization is: a uniformly slower CI
    runner scales numerator and denominator alike.  A key missing from
    either report fails loudly (exit 2 path) — gating a speedup that
    silently stopped being measured would be a green lie.

    Returns (failures, missing, lines).
    """
    failures, missing, lines = [], [], []
    for key in keys:
        old, new = baseline.get(key), fresh.get(key)
        if not isinstance(old, (int, float)) or \
                not isinstance(new, (int, float)):
            missing.append(key)
            lines.append(f"  {key}: missing or non-numeric "
                         f"(baseline={old!r} fresh={new!r})")
            continue
        ratio = new / old if old > 0 else float("inf")
        status = "OK"
        if ratio < 1.0 - tolerance - _EPS:
            status = "REGRESSION"
            failures.append(key)
        elif ratio > 1.0 + tolerance:
            status = "faster (consider refreshing the baseline)"
        lines.append(f"  {key}: {old:.2f}x -> {new:.2f}x "
                     f"({ratio:.2f} of baseline)  {status}")
    return failures, missing, lines


def check_floors(fresh: dict, floors):
    """Gate top-level speedup keys of the fresh report against absolute
    minima.  ``floors``: [(key, min_value)].  Returns (failures, missing,
    lines); a floored key sitting exactly at its minimum passes."""
    failures, missing, lines = [], [], []
    for key, floor in floors:
        val = fresh.get(key)
        if not isinstance(val, (int, float)):
            missing.append(key)
            lines.append(f"  {key}: missing or non-numeric ({val!r})")
            continue
        ok = float(val) >= floor - _EPS
        lines.append(f"  {key}: {val:.2f}x (floor {floor:.2f}x)  "
                     f"{'OK' if ok else 'BELOW FLOOR'}")
        if not ok:
            failures.append(key)
    return failures, missing, lines


def parse_floor(spec: str):
    """``KEY=MIN`` -> (key, float(min)); raises argparse-friendly errors."""
    key, sep, val = spec.partition("=")
    if not sep or not key:
        raise argparse.ArgumentTypeError(
            f"--floor expects KEY=MIN, got {spec!r}")
    try:
        return key, float(val)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--floor {key}: minimum {val!r} is not a number")


def load_report(path: str, which: str):
    """Load one benchmark report; returns None after printing a clear FAIL
    line when the file is missing, unreadable, or not a report-shaped dict
    (the CI log then says exactly what to fix — no traceback)."""
    try:
        with open(path) as f:
            report = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: {which} report missing: {path}")
        if which == "baseline":
            print("Commit a baseline first: "
                  "`python -m benchmarks.bench_bcd_eval --out "
                  f"{path}` on representative hardware.")
        return None
    except OSError as e:
        print(f"FAIL: cannot read {which} report {path}: {e}")
        return None
    except json.JSONDecodeError as e:
        print(f"FAIL: {which} report {path} is not valid JSON: {e}")
        print("Re-generate it with benchmarks.bench_bcd_eval (a truncated "
              "file usually means the benchmark run was interrupted).")
        return None
    backends = report.get("backends") if isinstance(report, dict) else None
    if not isinstance(backends, dict) or not backends:
        print(f"FAIL: {which} report {path} has no 'backends' table — not "
              "a bench_bcd_eval report?")
        return None
    bad = [name for name, rec in backends.items()
           if not isinstance(rec, dict)
           or not isinstance(rec.get("cands_per_s"), (int, float))]
    if bad:
        print(f"FAIL: {which} report {path}: backend(s) {sorted(bad)} "
              "missing a numeric 'cands_per_s'")
        return None
    return report


def load_sweep(path: str, which: str):
    """Load one ``launch.sweep`` artifact; returns None after a clear FAIL
    line (same no-traceback contract as :func:`load_report`)."""
    try:
        with open(path) as f:
            artifact = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot load {which} sweep artifact {path}: {e}")
        return None
    stages = artifact.get("stages") if isinstance(artifact, dict) else None
    if not isinstance(stages, list) or not stages:
        print(f"FAIL: {which} sweep artifact {path} has no 'stages' list — "
              "not a launch.sweep artifact?")
        return None
    return artifact


def compare_sweep_acc(baseline: dict, fresh: dict, tolerance: float):
    """Accuracy-at-budget gate over two sweep curves.

    Matches stages by ``budget``; the fresh curve must hold
    ``test_acc >= baseline - tolerance`` (absolute accuracy points) at
    every common budget.  Budgets present in only one curve are reported
    but never gate (schedules may legitimately differ in length).

    Returns (failures, unscored, common, lines).
    """
    def by_budget(artifact):
        return {int(s["budget"]): s for s in artifact["stages"]
                if isinstance(s.get("budget"), (int, float))}

    base_s, new_s = by_budget(baseline), by_budget(fresh)
    failures, unscored, common, lines = [], [], 0, []
    for budget in sorted(set(base_s) | set(new_s), reverse=True):
        if budget not in base_s or budget not in new_s:
            lines.append(f"  B={budget}: only in "
                         f"{'baseline' if budget in base_s else 'fresh'} "
                         "curve (skipped)")
            continue
        old = base_s[budget].get("test_acc")
        new = new_s[budget].get("test_acc")
        if not isinstance(old, (int, float)) or \
                not isinstance(new, (int, float)):
            unscored.append(budget)
            lines.append(f"  B={budget}: unscored stage "
                         f"(baseline={old!r} fresh={new!r})")
            continue
        common += 1
        ok = float(new) >= float(old) - tolerance - _EPS
        lines.append(f"  B={budget}: {old:.2f}% -> {new:.2f}% "
                     f"({'OK' if ok else 'ACCURACY DROP'})")
        if not ok:
            failures.append(f"B={budget}")
    return failures, unscored, common, lines


def load_serve(path: str, which: str):
    """Load one ``bench_serve`` report; returns None after a clear FAIL
    line (same no-traceback contract as :func:`load_report`)."""
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot load {which} serve report {path}: {e}")
        if which == "baseline" and isinstance(e, FileNotFoundError):
            print("Commit a baseline first: `python -m benchmarks."
                  f"bench_serve --out {path}` on representative hardware.")
        return None
    classes = report.get("classes") if isinstance(report, dict) else None
    if not isinstance(classes, dict) or not classes:
        print(f"FAIL: {which} serve report {path} has no 'classes' table — "
              "not a bench_serve report?")
        return None
    zero = [n for n, rec in classes.items()
            if isinstance(rec, dict) and rec.get("requests") == 0]
    if zero:
        print(f"FAIL: {which} serve report {path}: class(es) {sorted(zero)} "
              "completed zero requests (every request shed?) — no "
              "latency/throughput to gate.  Raise the class's deadline or "
              "queue cap, or lower --overload, so the committed operating "
              "point completes at least one request per class.")
        return None
    bad = [n for n, rec in classes.items()
           if not isinstance(rec, dict)
           or not isinstance(rec.get("decode_tok_s"), (int, float))
           or not isinstance(rec.get("total_ms_p95"), (int, float))]
    if bad:
        print(f"FAIL: {which} serve report {path}: class(es) {sorted(bad)} "
              "missing numeric 'decode_tok_s'/'total_ms_p95' (did the load "
              "run serve any requests in that class?)")
        return None
    over = report.get("overload")
    if over is not None:
        if not isinstance(over, dict):
            print(f"FAIL: {which} serve report {path}: 'overload' section "
                  "is not an object — regenerate with benchmarks."
                  "bench_serve --overload")
            return None
        malformed = [k for k in OVERLOAD_NUMERIC_KEYS
                     if not isinstance(over.get(k), (int, float))
                     or isinstance(over.get(k), bool)]
        if malformed:
            print(f"FAIL: {which} serve report {path}: overload section "
                  f"missing numeric key(s) {sorted(malformed)} — "
                  "regenerate with the current benchmarks.bench_serve")
            return None
    return report


def compare_serve(baseline: dict, fresh: dict, tolerance: float,
                  latency_factor: float):
    """Serving gate: per-class decode tok/s ratio + p95 latency ceiling.

    Classes present in only one report are noted but never gate.  Returns
    (failures, common, lines).
    """
    base_c, new_c = baseline["classes"], fresh["classes"]
    failures, common, lines = [], 0, []
    for name in sorted(set(base_c) | set(new_c)):
        if name not in base_c or name not in new_c:
            lines.append(f"  {name}: only in "
                         f"{'baseline' if name in base_c else 'fresh run'} "
                         "(skipped)")
            continue
        common += 1
        old, new = base_c[name], new_c[name]
        ratio = new["decode_tok_s"] / old["decode_tok_s"] \
            if old["decode_tok_s"] > 0 else float("inf")
        ok = ratio >= 1.0 - tolerance - _EPS
        status = "OK" if ok else "REGRESSION"
        if ratio > 1.0 + tolerance:
            status = "faster (consider refreshing the baseline)"
        if not ok:
            failures.append(f"{name}:decode_tok_s")
        lines.append(f"  {name}: {old['decode_tok_s']:.2f} -> "
                     f"{new['decode_tok_s']:.2f} tok/s ({ratio:.2f}x)  "
                     f"{status}")
        ceiling = old["total_ms_p95"] * latency_factor
        lat_ok = new["total_ms_p95"] <= ceiling + _EPS
        if not lat_ok:
            failures.append(f"{name}:total_ms_p95")
        lines.append(f"  {name}: p95 total {old['total_ms_p95']:.0f} -> "
                     f"{new['total_ms_p95']:.0f} ms (ceiling "
                     f"{ceiling:.0f})  {'OK' if lat_ok else 'OVER CEILING'}")
    return failures, common, lines


def compare_overload(baseline: dict, fresh: dict, tolerance: float):
    """Overload-mode gate over the two reports' ``overload`` sections.

    Deadline-hit-rate is gated absolutely (fresh >= baseline −
    tolerance), goodput as a ratio (>= 1 − tolerance of baseline), and
    shed-rate as a ceiling (<= baseline + tolerance) — under a virtual
    clock all three are deterministic, so the tolerance only absorbs
    intentional re-tuning, not runner noise.  ``all_terminal`` must hold
    outright: a hung or unterminated request is a scheduler bug.

    Returns (failures, lines).
    """
    old, new = baseline["overload"], fresh["overload"]
    failures, lines = [], []

    hit_ok = new["deadline_hit_rate"] >= \
        old["deadline_hit_rate"] - tolerance - _EPS
    if not hit_ok:
        failures.append("deadline_hit_rate")
    lines.append(f"  deadline_hit_rate: {old['deadline_hit_rate']:.3f} -> "
                 f"{new['deadline_hit_rate']:.3f} (floor "
                 f"{old['deadline_hit_rate'] - tolerance:.3f})  "
                 f"{'OK' if hit_ok else 'REGRESSION'}")

    ratio = new["goodput_tok_s"] / old["goodput_tok_s"] \
        if old["goodput_tok_s"] > 0 else float("inf")
    good_ok = ratio >= 1.0 - tolerance - _EPS
    if not good_ok:
        failures.append("goodput_tok_s")
    lines.append(f"  goodput: {old['goodput_tok_s']:.2f} -> "
                 f"{new['goodput_tok_s']:.2f} tok/s ({ratio:.2f}x)  "
                 f"{'OK' if good_ok else 'REGRESSION'}")

    shed_ok = new["shed_rate"] <= old["shed_rate"] + tolerance + _EPS
    if not shed_ok:
        failures.append("shed_rate")
    lines.append(f"  shed_rate: {old['shed_rate']:.3f} -> "
                 f"{new['shed_rate']:.3f} (ceiling "
                 f"{old['shed_rate'] + tolerance:.3f})  "
                 f"{'OK' if shed_ok else 'OVER CEILING'}")

    term_ok = new.get("all_terminal") is True
    if not term_ok:
        failures.append("all_terminal")
    lines.append(f"  terminal: {new['terminal']}/{new['submitted']} "
                 f"({new['served']} served, {new['degraded']} degraded, "
                 f"{new['shed']} shed, {new['expired']} expired)  "
                 f"{'OK' if term_ok else 'NOT ALL TERMINAL'}")
    return failures, lines


def run_serve(args) -> int:
    """``--serve`` mode: gate a fresh BENCH_serve.json against baseline."""
    baseline = load_serve(args.baseline, "baseline")
    fresh = load_serve(args.fresh, "fresh")
    if baseline is None or fresh is None:
        return 2
    base_over = "overload" in baseline
    if base_over != ("overload" in fresh):
        print("FAIL: serve reports are not comparable — "
              f"{'baseline' if base_over else 'fresh'} has an overload "
              "section and the other does not (one was run with "
              "--overload, the other without)")
        return 2
    mismatches = [
        f"{k}: baseline={baseline.get('config', {}).get(k)!r} "
        f"fresh={fresh.get('config', {}).get(k)!r}"
        for k in SERVE_OPERATING_POINT_KEYS
        if baseline.get("config", {}).get(k) != fresh.get("config",
                                                          {}).get(k)]
    if mismatches:
        print("FAIL: serve reports are not comparable — operating-point "
              "config differs:")
        for m in mismatches:
            print(f"  {m}")
        return 2
    total = fresh.get("total", {})
    if base_over:
        # overloaded runs shed by design: completion == every request
        # reaching a *terminal* state, gated in compare_overload below
        served_ok = total.get("drained") is True
    else:
        served_ok = total.get("completed") == total.get("submitted") \
            and total.get("drained") is True
    failures, common, lines = compare_serve(
        baseline, fresh, args.tolerance, args.latency_factor)
    print(f"serve regression check (tolerance {args.tolerance:.0%}, "
          f"latency ceiling {args.latency_factor:.1f}x baseline p95):")
    for line in lines:
        print(line)
    print(f"  completion: {total.get('completed')}/"
          f"{total.get('submitted')} drained={total.get('drained')}  "
          f"{'OK' if served_ok else 'INCOMPLETE'}")
    over_failures = []
    if base_over:
        over_failures, over_lines = compare_overload(
            baseline, fresh, args.tolerance)
        print(f"overload gate (factor "
              f"{fresh['overload']['factor']:g}x, tolerance "
              f"{args.tolerance:.0%}):")
        for line in over_lines:
            print(line)
    if common == 0:
        print("FAIL: the two reports share no SLO classes — nothing to "
              "gate")
        return 2
    if not served_ok:
        print("FAIL: fresh serve run did not complete+drain every "
              "submitted request — scheduler bug, not runner noise")
        return 1
    if failures or over_failures:
        print(f"FAIL: serving regression in "
              f"{', '.join(failures + over_failures)}")
        return 1
    print("PASS")
    return 0


def run_sweep_acc(args) -> int:
    baseline = load_sweep(args.baseline, "baseline")
    fresh = load_sweep(args.fresh, "fresh")
    if baseline is None or fresh is None:
        return 2
    failures, unscored, common, lines = compare_sweep_acc(
        baseline, fresh, args.acc_tolerance)
    print(f"sweep accuracy-at-budget check (tolerance "
          f"{args.acc_tolerance:.2f} points):")
    for line in lines:
        print(line)
    if unscored:
        print(f"FAIL: unscored stage(s) at budget(s) "
              f"{', '.join(str(b) for b in unscored)} — pass stage_eval to "
              "the sweep (or wait for the reporting tail) before gating")
        return 2
    if common == 0:
        print("FAIL: the two curves share no budgets — nothing to gate")
        return 2
    if failures:
        print(f"FAIL: accuracy-at-budget drop at {', '.join(failures)}")
        return 1
    print("PASS")
    return 0


def main(argv=None):
    """CLI entry; returns the process exit code (see module docstring)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_bcd_eval.json")
    ap.add_argument("fresh", help="freshly produced report to check")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional candidates/sec drop (0.30 = "
                         "fail below 70%% of baseline)")
    ap.add_argument("--relative-to", default=None,
                    help="normalize by this backend's candidates/sec within "
                         "each report (hardware-robust cross-backend ratio "
                         "gate; e.g. 'sequential')")
    ap.add_argument("--gate-speedup", action="append", default=[],
                    metavar="KEY",
                    help="also gate this top-level speedup_* report key "
                         "(within-report ratio, so hardware-robust); "
                         "repeatable.  e.g. speedup_suffix_vs_batched_deep")
    ap.add_argument("--floor", action="append", default=[],
                    metavar="KEY=MIN", type=parse_floor,
                    help="absolute minimum for a top-level speedup_* key of "
                         "the FRESH report (no baseline); repeatable.  e.g. "
                         "speedup_suffix_vs_batched_mean=2.0")
    ap.add_argument("--sweep-acc", action="store_true",
                    help="treat the two positional paths as launch.sweep "
                         "artifacts and gate fresh test_acc >= baseline "
                         "test_acc - --acc-tolerance at every common "
                         "budget (accuracy-at-budget mode; the bench-report "
                         "flags are ignored)")
    ap.add_argument("--acc-tolerance", type=float, default=0.0,
                    help="allowed absolute test_acc drop per budget in "
                         "--sweep-acc mode (accuracy points, default 0)")
    ap.add_argument("--serve", action="store_true",
                    help="treat the two positional paths as "
                         "benchmarks.bench_serve reports and gate per-SLO-"
                         "class decode tok/s (--tolerance), p95 total "
                         "latency (--latency-factor x baseline), and "
                         "complete+drained totals (serving mode)")
    ap.add_argument("--latency-factor", type=float, default=3.0,
                    help="--serve mode: fresh p95 total latency must stay "
                         "under baseline p95 times this factor (absolute "
                         "ms are runner-noisy; default 3.0)")
    args = ap.parse_args(argv)
    if args.serve and args.sweep_acc:
        print("FAIL: --serve and --sweep-acc are mutually exclusive")
        return 2
    if args.serve:
        return run_serve(args)
    if args.sweep_acc:
        return run_sweep_acc(args)
    baseline = load_report(args.baseline, "baseline")
    fresh = load_report(args.fresh, "fresh")
    if baseline is None or fresh is None:
        return 2
    mismatches = config_mismatches(baseline, fresh)
    if mismatches:
        print("FAIL: reports are not comparable — operating-point config "
              "differs:")
        for m in mismatches:
            print(f"  {m}")
        print("Re-run the benchmark with the baseline's flags (or refresh "
              "the baseline).")
        return 2
    if args.relative_to:
        for which, rep in (("baseline", baseline), ("fresh", fresh)):
            if args.relative_to not in rep.get("backends", {}):
                print(f"FAIL: --relative-to backend {args.relative_to!r} "
                      f"missing from the {which} report")
                return 2
    failures, lines = compare(baseline, fresh, args.tolerance,
                              args.relative_to)
    mode = f"relative to {args.relative_to}" if args.relative_to \
        else "absolute"
    print(f"bench_bcd_eval regression check "
          f"({mode}, tolerance {args.tolerance:.0%}):")
    for line in lines:
        print(line)
    key_failures, key_missing = [], []
    if args.gate_speedup:
        key_failures, key_missing, key_lines = compare_speedup_keys(
            baseline, fresh, args.gate_speedup, args.tolerance)
        print(f"speedup-key gate (tolerance {args.tolerance:.0%}):")
        for line in key_lines:
            print(line)
    floor_failures, floor_missing = [], []
    if args.floor:
        floor_failures, floor_missing, floor_lines = check_floors(
            fresh, args.floor)
        print("absolute speedup floors (fresh report):")
        for line in floor_lines:
            print(line)
    if key_missing or floor_missing:
        print(f"FAIL: gated speedup key(s) missing from a report: "
              f"{', '.join(key_missing + floor_missing)} — regenerate with "
              "the current benchmarks.bench_bcd_eval (or drop the "
              "--gate-speedup/--floor flag)")
        return 2
    if failures or key_failures or floor_failures:
        print("FAIL: regression in "
              f"{', '.join(failures + key_failures + floor_failures)}")
        return 1
    print("PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
