"""Gate candidates/sec against the committed benchmark baseline.

Compares a fresh ``bench_bcd_eval`` report against the repo's committed
``BENCH_bcd_eval.json`` and exits non-zero when any backend's candidates/sec
dropped by more than ``--tolerance`` (default 30%).  Backends present in only
one of the two reports are reported but never fail the gate (so adding a
backend does not require a lockstep baseline refresh).  Faster-than-baseline
results print a note suggesting a refresh.

    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_bcd_eval.json BENCH_new.json [--tolerance 0.30]
"""
from __future__ import annotations

import argparse
import json
import sys

# Config keys that define the benchmark's operating point: two reports are
# only comparable when all of these match.  Timing-precision knobs
# (repeats, trials) and host identity deliberately excluded — but note the
# committed baseline must come from hardware comparable to where the gate
# runs; refresh it from the CI artifact if the fleet changes.
OPERATING_POINT_KEYS = ("rt", "chunk_size", "prefetch", "drc", "eval_batch",
                        "model", "n_devices", "backend")


def config_mismatches(baseline: dict, fresh: dict) -> list:
    """Operating-point keys whose values differ between the two reports."""
    base_c = baseline.get("config", {})
    new_c = fresh.get("config", {})
    return [f"{k}: baseline={base_c.get(k)!r} fresh={new_c.get(k)!r}"
            for k in OPERATING_POINT_KEYS
            if base_c.get(k) != new_c.get(k)]


def compare(baseline: dict, fresh: dict, tolerance: float,
            relative_to: str | None = None):
    """Returns (failures, lines): failed backend names + a report line per
    backend common to both reports.

    relative_to: normalize every backend's candidates/sec by the named
    backend *within the same report* before comparing.  Self-normalizing
    across hosts (a slower CI runner scales all backends alike), at the
    cost of missing a slowdown that hits the reference backend equally —
    pair with an occasional same-host absolute check.
    """
    base_b = baseline.get("backends", {})
    new_b = fresh.get("backends", {})

    def rate(backends, name):
        v = float(backends[name]["cands_per_s"])
        if relative_to:
            v /= float(backends[relative_to]["cands_per_s"])
        return v

    unit = f"x {relative_to}" if relative_to else "cands/s"
    failures, lines = [], []
    for name in sorted(set(base_b) | set(new_b)):
        if name not in base_b or name not in new_b:
            lines.append(f"  {name}: only in "
                         f"{'baseline' if name in base_b else 'fresh run'} "
                         "(skipped)")
            continue
        old, new = rate(base_b, name), rate(new_b, name)
        ratio = new / old if old > 0 else float("inf")
        status = "OK"
        if ratio < 1.0 - tolerance:
            status = "REGRESSION"
            failures.append(name)
        elif ratio > 1.0 + tolerance:
            status = "faster (consider refreshing the baseline)"
        lines.append(f"  {name}: {old:.2f} -> {new:.2f} {unit} "
                     f"({ratio:.2f}x)  {status}")
    return failures, lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_bcd_eval.json")
    ap.add_argument("fresh", help="freshly produced report to check")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional candidates/sec drop (0.30 = "
                         "fail below 70%% of baseline)")
    ap.add_argument("--relative-to", default=None,
                    help="normalize by this backend's candidates/sec within "
                         "each report (hardware-robust cross-backend ratio "
                         "gate; e.g. 'sequential')")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    mismatches = config_mismatches(baseline, fresh)
    if mismatches:
        print("FAIL: reports are not comparable — operating-point config "
              "differs:")
        for m in mismatches:
            print(f"  {m}")
        print("Re-run the benchmark with the baseline's flags (or refresh "
              "the baseline).")
        return 2
    if args.relative_to:
        for which, rep in (("baseline", baseline), ("fresh", fresh)):
            if args.relative_to not in rep.get("backends", {}):
                print(f"FAIL: --relative-to backend {args.relative_to!r} "
                      f"missing from the {which} report")
                return 2
    failures, lines = compare(baseline, fresh, args.tolerance,
                              args.relative_to)
    mode = f"relative to {args.relative_to}" if args.relative_to \
        else "absolute"
    print(f"bench_bcd_eval regression check "
          f"({mode}, tolerance {args.tolerance:.0%}):")
    for line in lines:
        print(line)
    if failures:
        print(f"FAIL: candidates/sec regression in {', '.join(failures)}")
        return 1
    print("PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
