"""Shared miniature pipeline for the paper-table benchmarks.

The paper's tables are accuracy-vs-budget on CIFAR; offline we run the same
pipeline on synthetic CIFAR at reduced scale (documented in EXPERIMENTS.md).
All benchmarks print ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcd, linearize, masks as M, snl
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


def tiny_cnn(n_classes=8, image_size=16):
    cfg = CNNConfig("tiny", n_classes, image_size,
                    ((8, 1, 1), (16, 1, 2)), stem_channels=8)
    return CNN(cfg)


def trained_pipeline(seed=0, steps=80, noise=2.5):
    """noise=2.5 keeps the dense model ~75-90% so budget cuts actually cost
    accuracy — otherwise every method saturates and comparisons degenerate."""
    model = tiny_cnn()
    data = SyntheticImages(ImageDatasetCfg(
        n_classes=8, image_size=16, n_train=256, n_test=64, seed=seed,
        noise=noise))
    params = model.init(jax.random.PRNGKey(seed))
    opt = opt_lib.sgd(lr=5e-2, momentum=0.9)
    step, loss_fn = train_lib.make_cnn_train_step(model, opt)
    batches_np = data.batches("train", 32)
    batches = lambda i: {k: jnp.asarray(v) for k, v in batches_np(i).items()}
    masks0 = linearize.init_masks(model.mask_sites())
    ostate = opt.init(params)
    mdev = M.as_device(masks0)
    for i in range(steps):
        params, ostate, _, _ = step(params, ostate, mdev, batches(i))
    return model, data, params, loss_fn, batches, masks0


def soft_loss_fn(model):
    def soft_loss(p, a, batch, soft):
        logits = model.forward(p, a, batch["images"], soft=soft)
        return train_lib.cross_entropy(logits, batch["labels"]), 0.0
    return soft_loss


def test_acc(model, params, masks, data, n=64):
    b = {k: jnp.asarray(v) for k, v in data.eval_set(n).items()}
    logits = model.forward(params, M.as_device(masks), b["images"])
    return float(jnp.mean((jnp.argmax(logits, -1) == b["labels"])
                          .astype(jnp.float32)) * 100)


def train_acc_fn(model, params_ref, data, n=128):
    b = {k: jnp.asarray(v) for k, v in data.train_eval_set(n).items()}

    @jax.jit
    def acc(params, masks):
        logits = model.forward(params, masks, b["images"])
        return jnp.mean((jnp.argmax(logits, -1) == b["labels"])
                        .astype(jnp.float32)) * 100
    return acc


def run_snl_to(model, params, loss_fn, batches, masks0, budget, *,
               epochs=5, lr=3e-2, finetune_steps=15, seed=0):
    alphas = {k: jnp.ones(v.shape) for k, v in masks0.items()}
    cfg = snl.SNLConfig(b_target=budget, lam0=5e-4, kappa=1.5, epochs=epochs,
                        steps_per_epoch=5, lr=lr,
                        finetune_steps=finetune_steps, seed=seed)
    return snl.run_snl(params, alphas, loss_fn, batches, cfg)


def run_bcd_from(model, data, params_holder, loss_fn, batches, masks_ref,
                 b_target, *, drc=None, rt=10, adt=0.3, ft_steps=25):
    b_ref = M.count(masks_ref)
    drc = drc or max(1, (b_ref - b_target) // 8)
    acc = train_acc_fn(model, None, data)

    def eval_acc(m):
        return float(acc(params_holder["params"], M.as_device(m)))

    def ft(m):
        params_holder["params"] = snl.finetune(
            params_holder["params"], m, loss_fn, batches,
            steps=ft_steps, lr=1e-2)

    cfg = bcd.BCDConfig(b_target=b_target, drc=drc, rt=rt, adt=adt)
    return bcd.run_bcd(masks_ref, cfg, eval_acc, finetune=ft,
                       keep_snapshots=True)


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return us, out


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
