"""Continuous-batching scheduler: admission, SLO routing, billing, drain.

Contracts under test (launch.serve_loop.ServeLoop):

- every submitted request completes through the loop, FIFO per class, and
  queue/prefill/decode latencies are measured per request;
- each request is billed exactly ``pi_cost`` of the mask set its SLO class
  routes to (ReLU-cost × tokens), with the set's fingerprint on record;
- a request's token stream is invariant to what the other slots are doing
  (continuous batching never changes results — exact, fixed-B rows);
- shutdown semantics: drain completes everything; no-drain cancels and
  never bills; submitting after shutdown fails.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core import pi_cost
from repro.launch import serve_loop
from repro.models.lm import LM
from repro.training import serve as serve_lib


@pytest.fixture(scope="module")
def served():
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = serve_loop.threshold_mask_sets(model, [1.0, 0.25], seed=0)
    return cfg, model, params, store


def _loop(served, max_new=3, slots=2, max_len=32, bucket=8, classes=None):
    cfg, model, params, store = served
    classes = classes or [
        serve_loop.SLOClass("premium", store.names[0], max_new),
        serve_loop.SLOClass("economy", store.names[1], max_new)]
    return serve_loop.ServeLoop(model, params, store, classes,
                                slots=slots, max_len=max_len,
                                prompt_bucket=bucket)


def _submit_n(loop, cfg, n, seed=0, classes=("premium", "economy")):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, 12))
        reqs.append(loop.submit(rng.integers(0, cfg.vocab, plen),
                                classes[i % len(classes)]))
    return reqs


def test_drains_and_measures_two_classes(served):
    cfg = served[0]
    loop = _loop(served)
    reqs = _submit_n(loop, cfg, 6)
    loop.shutdown(drain=True)
    assert loop.pending() == 0
    assert len(loop.completed) == 6
    for r in reqs:
        assert not r.cancelled
        assert len(r.tokens) == 3
        assert r.t_arrival <= r.t_admit <= r.t_first <= r.t_done
        assert r.queue_s >= 0 and r.prefill_s > 0 and r.decode_s > 0
    stats = loop.stats()
    for name in ("premium", "economy"):
        c = stats["classes"][name]
        assert c["requests"] == 3
        assert c["decode_tok_s"] > 0
        for key in ("queue", "prefill", "decode", "total"):
            assert c[f"{key}_ms_p50"] <= c[f"{key}_ms_p95"]
    # premium routes to the bigger budget -> strictly pricier per token
    assert stats["classes"]["premium"]["relu_cost"] > \
        stats["classes"]["economy"]["relu_cost"]


def test_fifo_admission_per_class(served):
    cfg = served[0]
    loop = _loop(served, slots=1)          # force queueing
    reqs = _submit_n(loop, cfg, 4, classes=("premium",))
    loop.shutdown(drain=True)
    admits = [r.t_admit for r in reqs]
    assert admits == sorted(admits)
    # with one slot, later arrivals must have measurably waited
    assert reqs[-1].queue_s > reqs[0].queue_s


def test_billing_is_pi_cost_of_served_mask_set(served):
    cfg, model, params, store = served
    loop = _loop(served, max_new=4)
    reqs = _submit_n(loop, cfg, 4)
    loop.shutdown(drain=True)
    n_sites = len(store.site_shapes)
    for r in reqs:
        info = store.info(loop.lanes[r.slo].slo.mask_set)
        assert r.mask_set == info.name
        assert r.mask_fingerprint == info.fingerprint
        tokens = len(r.prompt) + len(r.tokens)
        want = pi_cost.bill_request(info.relu_cost, n_sites, tokens=tokens)
        assert r.bill == want
        # and the bill is the per-token protocol cost scaled by tokens
        per_tok = pi_cost.cost_of_masks(store.host(r.mask_set), n_sites)
        assert r.bill["relus_billed"] == info.relu_cost * tokens
        assert r.bill["pi_online_s"] == pytest.approx(
            per_tok.online_latency_s * tokens)


def test_stream_invariant_to_neighbors(served):
    """The same prompt yields bitwise the same tokens whether it shares
    the lane with other requests or runs alone (fixed-B row independence
    through the whole scheduler path)."""
    cfg = served[0]
    prompt = np.arange(1, 8) % cfg.vocab

    solo = _loop(served, max_new=4)
    r_solo = solo.submit(prompt, "premium")
    solo.shutdown(drain=True)

    busy = _loop(served, max_new=4)
    rng = np.random.default_rng(7)
    busy.submit(rng.integers(0, cfg.vocab, 5), "premium")
    r_busy = busy.submit(prompt, "premium")
    busy.submit(rng.integers(0, cfg.vocab, 9), "economy")
    busy.shutdown(drain=True)
    assert r_busy.tokens == r_solo.tokens


def test_shutdown_without_drain_cancels(served):
    cfg = served[0]
    loop = _loop(served, slots=1)
    reqs = _submit_n(loop, cfg, 3, classes=("premium",))
    loop.step()                            # admit one, leave two queued
    done = loop.shutdown(drain=False)
    assert loop.pending() == 0
    cancelled = [r for r in reqs if r.cancelled]
    assert cancelled and all(r.bill is None for r in cancelled)
    assert all(not r.cancelled and r.bill for r in done)
    with pytest.raises(RuntimeError, match="shut down"):
        loop.submit(np.array([1, 2]), "premium")


def test_validation_errors_are_loud(served):
    cfg, model, params, store = served
    with pytest.raises(serve_lib.MaskSetError, match="routes to mask set"):
        serve_loop.ServeLoop(model, params, store,
                             [serve_loop.SLOClass("x", "nope", 2)])
    with pytest.raises(ValueError, match="at least one SLO"):
        serve_loop.ServeLoop(model, params, store, [])
    loop = _loop(served)
    with pytest.raises(KeyError, match="unknown SLO"):
        loop.submit(np.array([1]), "gold")
    with pytest.raises(ValueError, match="prompt length"):
        loop.submit(np.zeros(100, np.int32), "premium")
    with pytest.raises(ValueError, match="prompt length"):
        loop.submit(np.zeros(0, np.int32), "premium")
