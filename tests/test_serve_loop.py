"""Continuous-batching scheduler: admission, SLO routing, billing, drain.

Contracts under test (launch.serve_loop.ServeLoop):

- every submitted request completes through the loop, FIFO per class, and
  queue/prefill/decode latencies are measured per request;
- each request is billed exactly ``pi_cost`` of the mask set its SLO class
  routes to (ReLU-cost × tokens), with the set's fingerprint on record;
- a request's token stream is invariant to what the other slots are doing
  (continuous batching never changes results — exact, fixed-B rows);
- shutdown semantics: drain completes everything; no-drain cancels and
  never bills; submitting after shutdown fails.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core import pi_cost
from repro.launch import serve_loop
from repro.models.lm import LM
from repro.training import serve as serve_lib


@pytest.fixture(scope="module")
def served():
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = serve_loop.threshold_mask_sets(model, [1.0, 0.25], seed=0)
    return cfg, model, params, store


def _loop(served, max_new=3, slots=2, max_len=32, bucket=8, classes=None):
    cfg, model, params, store = served
    classes = classes or [
        serve_loop.SLOClass("premium", store.names[0], max_new),
        serve_loop.SLOClass("economy", store.names[1], max_new)]
    return serve_loop.ServeLoop(model, params, store, classes,
                                slots=slots, max_len=max_len,
                                prompt_bucket=bucket)


def _submit_n(loop, cfg, n, seed=0, classes=("premium", "economy")):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, 12))
        reqs.append(loop.submit(rng.integers(0, cfg.vocab, plen),
                                classes[i % len(classes)]))
    return reqs


def test_drains_and_measures_two_classes(served):
    cfg = served[0]
    loop = _loop(served)
    reqs = _submit_n(loop, cfg, 6)
    loop.shutdown(drain=True)
    assert loop.pending() == 0
    assert len(loop.completed) == 6
    for r in reqs:
        assert not r.cancelled
        assert len(r.tokens) == 3
        assert r.t_arrival <= r.t_admit <= r.t_first <= r.t_done
        assert r.queue_s >= 0 and r.prefill_s > 0 and r.decode_s > 0
    stats = loop.stats()
    for name in ("premium", "economy"):
        c = stats["classes"][name]
        assert c["requests"] == 3
        assert c["decode_tok_s"] > 0
        for key in ("queue", "prefill", "decode", "total"):
            assert c[f"{key}_ms_p50"] <= c[f"{key}_ms_p95"]
    # premium routes to the bigger budget -> strictly pricier per token
    assert stats["classes"]["premium"]["relu_cost"] > \
        stats["classes"]["economy"]["relu_cost"]


def test_fifo_admission_per_class(served):
    cfg = served[0]
    loop = _loop(served, slots=1)          # force queueing
    reqs = _submit_n(loop, cfg, 4, classes=("premium",))
    loop.shutdown(drain=True)
    admits = [r.t_admit for r in reqs]
    assert admits == sorted(admits)
    # with one slot, later arrivals must have measurably waited
    assert reqs[-1].queue_s > reqs[0].queue_s


def test_billing_is_pi_cost_of_served_mask_set(served):
    cfg, model, params, store = served
    loop = _loop(served, max_new=4)
    reqs = _submit_n(loop, cfg, 4)
    loop.shutdown(drain=True)
    n_sites = len(store.site_shapes)
    for r in reqs:
        info = store.info(loop.lanes[r.slo].slo.mask_set)
        assert r.mask_set == info.name
        assert r.mask_fingerprint == info.fingerprint
        tokens = len(r.prompt) + len(r.tokens)
        want = pi_cost.bill_request(info.relu_cost, n_sites, tokens=tokens,
                                    mask_set=info.name,
                                    fingerprint=info.fingerprint)
        assert r.bill == want
        # and the bill is the per-token protocol cost scaled by tokens
        per_tok = pi_cost.cost_of_masks(store.host(r.mask_set), n_sites)
        assert r.bill["relus_billed"] == info.relu_cost * tokens
        assert r.bill["pi_online_s"] == pytest.approx(
            per_tok.online_latency_s * tokens)


def test_stream_invariant_to_neighbors(served):
    """The same prompt yields bitwise the same tokens whether it shares
    the lane with other requests or runs alone (fixed-B row independence
    through the whole scheduler path)."""
    cfg = served[0]
    prompt = np.arange(1, 8) % cfg.vocab

    solo = _loop(served, max_new=4)
    r_solo = solo.submit(prompt, "premium")
    solo.shutdown(drain=True)

    busy = _loop(served, max_new=4)
    rng = np.random.default_rng(7)
    busy.submit(rng.integers(0, cfg.vocab, 5), "premium")
    r_busy = busy.submit(prompt, "premium")
    busy.submit(rng.integers(0, cfg.vocab, 9), "economy")
    busy.shutdown(drain=True)
    assert r_busy.tokens == r_solo.tokens


def test_shutdown_without_drain_cancels(served):
    cfg = served[0]
    loop = _loop(served, slots=1)
    reqs = _submit_n(loop, cfg, 3, classes=("premium",))
    loop.step()                            # admit one, leave two queued
    done = loop.shutdown(drain=False)
    assert loop.pending() == 0
    cancelled = [r for r in reqs if r.cancelled]
    assert cancelled and all(r.bill is None for r in cancelled)
    assert all(not r.cancelled and r.bill for r in done)
    with pytest.raises(RuntimeError, match="shut down"):
        loop.submit(np.array([1, 2]), "premium")


def test_validation_errors_are_loud(served):
    cfg, model, params, store = served
    with pytest.raises(serve_lib.MaskSetError, match="routes to mask set"):
        serve_loop.ServeLoop(model, params, store,
                             [serve_loop.SLOClass("x", "nope", 2)])
    with pytest.raises(ValueError, match="at least one SLO"):
        serve_loop.ServeLoop(model, params, store, [])
    loop = _loop(served)
    with pytest.raises(KeyError, match="unknown SLO"):
        loop.submit(np.array([1]), "gold")
    with pytest.raises(ValueError, match="prompt length"):
        loop.submit(np.zeros(100, np.int32), "premium")
    with pytest.raises(ValueError, match="prompt length"):
        loop.submit(np.zeros(0, np.int32), "premium")


# ---------------------------------------------------- overload robustness

def _wan():
    """Bandwidth-bound protocol: per-token cost scales with ReLU count, so
    the kf100/kf025 latency spread is ~4x and deadlines discriminate."""
    return pi_cost.PIProtocol(bandwidth_bytes_per_s=12.5e6, rtt_s=0.0)


def _deadline_loop(served, deadline_ms, *, ladder=False, queue_cap=None,
                   max_new=3):
    from repro.launch import faults
    cfg, model, params, store = served
    classes = [
        serve_loop.SLOClass("premium", store.names[0], max_new,
                            deadline_ms=deadline_ms, priority=1),
        serve_loop.SLOClass("economy", store.names[1], max_new,
                            deadline_ms=None)]
    lad = serve_loop.DegradationLadder.from_store(store) if ladder else None
    clock = faults.VirtualClock()
    loop = serve_loop.ServeLoop(model, params, store, classes, slots=2,
                                max_len=32, prompt_bucket=8, ladder=lad,
                                queue_cap=queue_cap, clock=clock,
                                proto=_wan())
    return loop, clock


def test_generous_deadline_is_served_and_hit(served):
    loop, _ = _deadline_loop(served, deadline_ms=5000.0)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "served" and req.deadline_hit
    stats = loop.stats()
    assert stats["classes"]["premium"]["deadline_hit_rate"] == 1.0
    assert stats["deadline_hit_rate"] == 1.0
    assert stats["goodput_tok_s"] > 0


def test_unmeetable_deadline_sheds_before_prefill(served):
    """Without a ladder, a deadline the estimate cannot meet is shed with
    a reason — no prefill compute is wasted and nothing is billed."""
    loop, _ = _deadline_loop(served, deadline_ms=150.0)
    est = loop.latency.estimate_s(loop.store.names[0], 5, 3)
    assert est > 0.150                       # premise of the test
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "shed"
    assert req.shed_reason == "deadline_unmeetable"
    assert req.bill is None and req.tokens == []
    assert loop.decision_log[-1]["decision"] == "shed"


def test_degradation_ladder_reroutes_and_bills_cheaper_set(served):
    """The tentpole: an unmeetable premium deadline degrades down the
    ladder to the cheaper set, serves within deadline, and is billed at
    the *degraded* set's ReLU cost with full provenance stamped."""
    cfg, model, params, store = served
    loop, _ = _deadline_loop(served, deadline_ms=150.0, ladder=True)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "degraded" and req.deadline_hit
    assert req.degraded_from == store.names[0]
    assert req.mask_set == store.names[1]
    info = store.info(store.names[1])
    tokens = len(req.prompt) + len(req.tokens)
    assert req.bill == pi_cost.bill_request(
        info.relu_cost, len(store.site_shapes), tokens=tokens,
        proto=_wan(), mask_set=info.name, fingerprint=info.fingerprint,
        degraded_from=store.names[0])
    stats = loop.stats()
    assert stats["degrade_rate"] == 1.0
    decisions = [d["decision"] for d in loop.decision_log
                 if d["rid"] == req.rid]
    assert decisions == ["degrade", "admit"]


def test_expired_request_cancelled_unbilled(served):
    """A request whose deadline passes while queued is cancelled before
    any prefill — terminal, un-billed, reason recorded."""
    loop, clock = _deadline_loop(served, deadline_ms=100.0)
    req = loop.submit(np.arange(1, 6), "premium")
    clock.advance(1.0)                       # deadline passes in the queue
    loop.shutdown(drain=True)
    assert req.state == "shed" and req.cancelled
    assert req.shed_reason == "deadline_expired"
    assert req.bill is None and req.tokens == []


def test_bounded_queue_sheds_overflow(served):
    loop, _ = _deadline_loop(served, deadline_ms=None, queue_cap=2)
    reqs = [loop.submit(np.arange(1, 6), "premium") for _ in range(4)]
    assert [r.state for r in reqs] == ["queued", "queued", "shed", "shed"]
    assert all(r.shed_reason == "queue_full" for r in reqs[2:])
    loop.shutdown(drain=True)
    assert loop.stats()["terminal"] == 4
    assert loop.stats()["classes"]["premium"]["shed_reasons"] == \
        {"queue_full": 2}


def test_edf_orders_admission_by_deadline_then_priority(served):
    """Queued requests admit earliest-deadline-first, not FIFO: a later
    arrival with a tighter deadline jumps the queue."""
    cfg, model, params, store = served
    from repro.launch import faults
    classes = [
        serve_loop.SLOClass("premium", store.names[0], 2,
                            deadline_ms=60000.0),
        serve_loop.SLOClass("rush", store.names[0], 2, deadline_ms=500.0)]
    loop = serve_loop.ServeLoop(model, params, store, classes, slots=1,
                                max_len=32, prompt_bucket=8,
                                clock=faults.VirtualClock(), proto=_wan())
    relaxed = loop.submit(np.arange(1, 6), "premium")
    rush = loop.submit(np.arange(1, 6), "rush")
    # same lane heap is per class; check cross-class via shared-set lane:
    # rush lives on its own lane, so instead assert within one class
    lane = loop.lanes["premium"]
    later_tight = serve_loop.Request(rid=99, slo="premium",
                                     prompt=np.arange(1, 4), max_new=2,
                                     deadline_s=0.1)
    lane.push(later_tight)
    assert lane.pop() is later_tight         # EDF beats FIFO order
    assert lane.pop() is relaxed
    assert rush.state == "queued"


def test_ladder_validation_is_loud(served):
    cfg, model, params, store = served
    with pytest.raises(ValueError, match="not in the mask-set store"):
        serve_loop.DegradationLadder(("nope",)).validate(store)
    with pytest.raises(ValueError, match="strictly descending"):
        serve_loop.DegradationLadder(
            (store.names[1], store.names[0])).validate(store)
    lad = serve_loop.DegradationLadder.from_store(store)
    assert lad.rungs == (store.names[0], store.names[1])
    assert lad.below(store, store.names[0]) == (store.names[1],)
    assert lad.below(store, store.names[1]) == ()


def test_recurrent_family_requires_exact_prefill():
    """Satellite bugfix: state-carrying caches (rwkv/mamba blocks) carry
    state through padded prompt positions, so bucketed prefill must be
    rejected at construction — and exact-length prefill must serve."""
    cfg = get_config("rwkv6_3b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = serve_loop.threshold_mask_sets(model, [1.0], seed=0)
    classes = [serve_loop.SLOClass("only", store.names[0], 2)]
    with pytest.raises(ValueError, match=r"prompt_bucket=None"):
        serve_loop.ServeLoop(model, params, store, classes,
                             slots=1, max_len=24, prompt_bucket=16)
    loop = serve_loop.ServeLoop(model, params, store, classes,
                                slots=1, max_len=24, prompt_bucket=None)
    req = loop.submit(np.arange(1, 7) % cfg.vocab, "only")
    loop.shutdown(drain=True)
    assert req.state == "served" and len(req.tokens) == 2


def test_no_drain_leaves_no_poisoned_state(served):
    """Satellite: after shutdown(drain=False) cancels in-flight work, a
    FRESH loop over the same store serves bit-identically to one that
    never saw the cancelled loop — no poisoned device state, all lanes
    released, nothing billed for cancelled work."""
    cfg = served[0]
    prompt = np.arange(1, 8) % cfg.vocab

    before = _loop(served, max_new=4)
    want = before.submit(prompt, "premium")
    before.shutdown(drain=True)

    victim = _loop(served, max_new=4, slots=1)
    reqs = _submit_n(victim, cfg, 3, classes=("premium",))
    victim.step()                            # one live, two queued
    victim.shutdown(drain=False)
    assert all(r.state == "cancelled" for r in reqs)
    assert all(r.bill is None for r in reqs)
    for lane in victim.lanes.values():       # lanes fully released
        assert not lane.live.any()
        assert all(r is None for r in lane.reqs)
        assert not lane.heap and not lane.cache_len.any()

    after = _loop(served, max_new=4)
    got = after.submit(prompt, "premium")
    after.shutdown(drain=True)
    assert got.tokens == want.tokens
