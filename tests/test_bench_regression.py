"""The CI candidates/sec gate (benchmarks.check_bench_regression).

The gate is the last line of defense for engine throughput, so its own
failure modes matter: a missing or malformed report must exit with a clear
FAIL message (code 2) rather than a traceback, and a backend sitting
*exactly* at the tolerance threshold must pass — only drops strictly beyond
it fail (code 1).
"""
import json
import sys

import pytest

sys.path.insert(0, "")   # repo root on path when pytest runs from it
from benchmarks import check_bench_regression as gate  # noqa: E402


def _report(rates, **config):
    cfg = {"rt": 8, "chunk_size": 4, "prefetch": 2, "drc": 16,
           "eval_batch": 128, "model": "tiny", "n_devices": 1,
           "backend": None}
    cfg.update(config)
    return {"config": cfg,
            "backends": {k: {"cands_per_s": v} for k, v in rates.items()}}


def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(obj if isinstance(obj, str) else json.dumps(obj))
    return str(p)


def _run(argv, capsys):
    rc = gate.main(argv)
    return rc, capsys.readouterr().out


def test_pass_and_regression(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _report({"seq": 100.0, "bat": 400.0}))
    ok = _write(tmp_path, "ok.json", _report({"seq": 95.0, "bat": 390.0}))
    rc, out = _run([base, ok], capsys)
    assert rc == 0 and "PASS" in out

    slow = _write(tmp_path, "slow.json", _report({"seq": 95.0, "bat": 200.0}))
    rc, out = _run([base, slow], capsys)
    assert rc == 1 and "REGRESSION" in out and "bat" in out


def test_exactly_at_threshold_passes(tmp_path, capsys):
    """ratio == 1 - tolerance must PASS: the gate fails only strictly
    beyond the tolerance, and float rounding (1.0 - 0.3 > 0.7) must not
    flip an at-threshold backend into a failure."""
    base = _write(tmp_path, "base.json", _report({"seq": 100.0}))
    at = _write(tmp_path, "at.json", _report({"seq": 70.0}))
    rc, out = _run([base, at, "--tolerance", "0.30"], capsys)
    assert rc == 0, out
    assert "PASS" in out

    below = _write(tmp_path, "below.json", _report({"seq": 69.9}))
    rc, out = _run([base, below, "--tolerance", "0.30"], capsys)
    assert rc == 1 and "REGRESSION" in out


def test_missing_baseline_is_clear_failure(tmp_path, capsys):
    fresh = _write(tmp_path, "fresh.json", _report({"seq": 100.0}))
    rc, out = _run([str(tmp_path / "nope.json"), fresh], capsys)
    assert rc == 2
    assert "FAIL" in out and "baseline report missing" in out
    assert "bench_bcd_eval" in out            # tells the reader what to run


def test_missing_fresh_is_clear_failure(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _report({"seq": 100.0}))
    rc, out = _run([base, str(tmp_path / "nope.json")], capsys)
    assert rc == 2 and "fresh report missing" in out


@pytest.mark.parametrize("blob,needle", [
    ("{not json", "not valid JSON"),
    ("[1, 2, 3]", "no 'backends'"),
    ('{"backends": {}}', "no 'backends'"),
    ('{"backends": {"seq": {"other": 1}}}', "cands_per_s"),
    ('{"backends": {"seq": {"cands_per_s": "fast"}}}', "cands_per_s"),
])
def test_malformed_reports_are_clear_failures(tmp_path, capsys, blob, needle):
    base = _write(tmp_path, "base.json", _report({"seq": 100.0}))
    bad = _write(tmp_path, "bad.json", blob)
    rc, out = _run([base, bad], capsys)
    assert rc == 2, out
    assert "FAIL" in out and needle in out
    assert "Traceback" not in out


def test_config_mismatch_refuses_comparison(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _report({"seq": 100.0}, rt=8))
    other = _write(tmp_path, "other.json", _report({"seq": 100.0}, rt=16))
    rc, out = _run([base, other], capsys)
    assert rc == 2 and "not comparable" in out and "rt" in out


def test_relative_mode_requires_reference_backend(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _report({"seq": 100.0}))
    fresh = _write(tmp_path, "fresh.json", _report({"bat": 100.0}))
    rc, out = _run([base, fresh, "--relative-to", "seq"], capsys)
    assert rc == 2 and "seq" in out


def test_one_sided_backends_never_fail(tmp_path, capsys):
    """Adding/removing a backend must not force a lockstep baseline
    refresh: one-sided entries are reported but skipped."""
    base = _write(tmp_path, "base.json", _report({"seq": 100.0, "old": 5.0}))
    fresh = _write(tmp_path, "fresh.json", _report({"seq": 95.0, "new": 9.0}))
    rc, out = _run([base, fresh], capsys)
    assert rc == 0 and "skipped" in out


def _speedup_report(rates, **keys):
    rep = _report(rates)
    rep.update(keys)
    return rep


def test_gate_speedup_key_pass_and_regression(tmp_path, capsys):
    """--gate-speedup compares a top-level within-report ratio (the
    prefix-reuse headline): fine within tolerance, fails strictly beyond."""
    base = _write(tmp_path, "base.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched=4.0))
    ok = _write(tmp_path, "ok.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched=3.0))
    rc, out = _run([base, ok, "--gate-speedup", "speedup_suffix_vs_batched"],
                   capsys)
    assert rc == 0, out
    assert "speedup-key gate" in out

    slow = _write(tmp_path, "slow.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched=2.0))
    rc, out = _run([base, slow, "--gate-speedup",
                    "speedup_suffix_vs_batched"], capsys)
    assert rc == 1, out
    assert "REGRESSION" in out and "speedup_suffix_vs_batched" in out


def test_gate_speedup_missing_key_is_loud(tmp_path, capsys):
    """A gated key that vanished from a report must fail the unusable-input
    way (exit 2) — silently skipping it would un-gate the very number the
    flag exists to protect."""
    base = _write(tmp_path, "base.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched=4.0))
    fresh = _write(tmp_path, "fresh.json", _speedup_report({"seq": 100.0}))
    rc, out = _run([base, fresh, "--gate-speedup",
                    "speedup_suffix_vs_batched"], capsys)
    assert rc == 2, out
    assert "missing" in out and "speedup_suffix_vs_batched" in out
    assert "Traceback" not in out


def test_floor_pass_at_exactly_floor_and_fail_below(tmp_path, capsys):
    """--floor gates the FRESH report absolutely: exactly at the floor
    passes, strictly below fails with exit 1."""
    base = _write(tmp_path, "base.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched_mean=2.5))
    at = _write(tmp_path, "at.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched_mean=2.0,
        speedup_suffix_vs_batched_shallow=1.0))
    rc, out = _run([base, at,
                    "--floor", "speedup_suffix_vs_batched_mean=2.0",
                    "--floor", "speedup_suffix_vs_batched_shallow=1.0"],
                   capsys)
    assert rc == 0, out
    assert "absolute speedup floors" in out

    below = _write(tmp_path, "below.json", _speedup_report(
        {"seq": 100.0}, speedup_suffix_vs_batched_mean=1.9))
    rc, out = _run([base, below,
                    "--floor", "speedup_suffix_vs_batched_mean=2.0"], capsys)
    assert rc == 1, out
    assert "BELOW FLOOR" in out and "speedup_suffix_vs_batched_mean" in out


def test_floor_missing_key_is_loud(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _speedup_report({"seq": 100.0}))
    fresh = _write(tmp_path, "fresh.json", _speedup_report({"seq": 100.0}))
    rc, out = _run([base, fresh, "--floor", "nope_key=1.0"], capsys)
    assert rc == 2, out
    assert "missing" in out and "nope_key" in out
    assert "Traceback" not in out


def test_floor_spec_parsing():
    assert gate.parse_floor("speedup_x=2.0") == ("speedup_x", 2.0)
    import argparse
    with pytest.raises(argparse.ArgumentTypeError, match="KEY=MIN"):
        gate.parse_floor("speedup_x")
    with pytest.raises(argparse.ArgumentTypeError, match="not a number"):
        gate.parse_floor("speedup_x=fast")


def test_provenance_block_does_not_break_comparability(tmp_path, capsys):
    """The config's nested provenance dict (jax version / device kind) is
    informational: two reports differing only there must still compare."""
    base = _write(tmp_path, "base.json", _report(
        {"seq": 100.0}, provenance={"jax": "0.4.1", "device_kind": "cpu"}))
    fresh = _write(tmp_path, "fresh.json", _report(
        {"seq": 100.0}, provenance={"jax": "0.9.9", "device_kind": "tpu"}))
    rc, out = _run([base, fresh], capsys)
    assert rc == 0, out


# ------------------------------------------------- --sweep-acc mode


def _sweep(accs):
    """A minimal launch.sweep artifact: {budget: test_acc | None}."""
    return {"stages": [
        {"stage": i, "budget": b,
         **({} if acc is None else {"test_acc": acc})}
        for i, (b, acc) in enumerate(sorted(accs.items(), reverse=True))]}


def test_sweep_acc_pass_and_drop(tmp_path, capsys):
    base = _write(tmp_path, "rm.json", _sweep({800: 91.0, 600: 85.0}))
    ok = _write(tmp_path, "mix_ok.json", _sweep({800: 91.0, 600: 86.5}))
    rc, out = _run([base, ok, "--sweep-acc"], capsys)
    assert rc == 0 and "PASS" in out

    drop = _write(tmp_path, "mix_drop.json", _sweep({800: 91.0, 600: 84.0}))
    rc, out = _run([base, drop, "--sweep-acc"], capsys)
    assert rc == 1 and "ACCURACY DROP" in out and "B=600" in out
    # ...but an explicit tolerance absorbs the same drop
    rc, out = _run([base, drop, "--sweep-acc", "--acc-tolerance", "1.0"],
                   capsys)
    assert rc == 0, out


def test_sweep_acc_one_sided_budgets_never_gate(tmp_path, capsys):
    """A longer fresh schedule (extra budgets) is reported, not failed."""
    base = _write(tmp_path, "rm.json", _sweep({800: 91.0}))
    fresh = _write(tmp_path, "mix.json", _sweep({800: 91.0, 600: 10.0}))
    rc, out = _run([base, fresh, "--sweep-acc"], capsys)
    assert rc == 0 and "only in fresh" in out


def test_sweep_acc_unscored_and_disjoint_are_loud(tmp_path, capsys):
    base = _write(tmp_path, "rm.json", _sweep({800: 91.0}))
    unscored = _write(tmp_path, "uns.json", _sweep({800: None}))
    rc, out = _run([base, unscored, "--sweep-acc"], capsys)
    assert rc == 2 and "unscored" in out

    disjoint = _write(tmp_path, "dis.json", _sweep({100: 50.0}))
    rc, out = _run([base, disjoint, "--sweep-acc"], capsys)
    assert rc == 2 and "no budgets" in out

    notsweep = _write(tmp_path, "ns.json", {"backends": {}})
    rc, out = _run([base, notsweep, "--sweep-acc"], capsys)
    assert rc == 2 and "stages" in out


# ------------------------------------------------- --serve mode


def _serve(classes, completed=None, submitted=None, drained=True, **config):
    """A minimal bench_serve report: {class: (tok_s, p95_ms)}."""
    cfg = {"model": "tiny:reduced", "slots": 2, "max_len": 48, "max_new": 6,
           "prompt_bucket": 16, "requests": 12, "budget_fracs": "1.0,0.25",
           "n_devices": 1}
    cfg.update(config)
    n = submitted if submitted is not None else 12
    return {"bench": "serve", "config": cfg,
            "classes": {k: {"decode_tok_s": v[0], "total_ms_p95": v[1],
                            "requests": 6}
                        for k, v in classes.items()},
            "total": {"submitted": n,
                      "completed": completed if completed is not None else n,
                      "drained": drained}}


def test_serve_pass_and_throughput_regression(tmp_path, capsys):
    base = _write(tmp_path, "b.json",
                  _serve({"premium": (10.0, 500.0), "economy": (30.0, 400.0)}))
    ok = _write(tmp_path, "ok.json",
                _serve({"premium": (8.0, 900.0), "economy": (25.0, 800.0)}))
    rc, out = _run([base, ok, "--serve"], capsys)
    assert rc == 0, out
    assert "PASS" in out and "completion: 12/12" in out

    slow = _write(tmp_path, "slow.json",
                  _serve({"premium": (5.0, 500.0), "economy": (30.0, 400.0)}))
    rc, out = _run([base, slow, "--serve"], capsys)
    assert rc == 1, out
    assert "REGRESSION" in out and "premium:decode_tok_s" in out


def test_serve_latency_ceiling(tmp_path, capsys):
    """p95 latency gates against baseline x --latency-factor: generous by
    default (runner noise), strict when asked."""
    base = _write(tmp_path, "b.json", _serve({"premium": (10.0, 500.0)}))
    slow = _write(tmp_path, "s.json", _serve({"premium": (10.0, 2000.0)}))
    rc, out = _run([base, slow, "--serve"], capsys)      # 3x ceiling: over
    assert rc == 1, out
    assert "OVER CEILING" in out and "premium:total_ms_p95" in out
    rc, out = _run([base, slow, "--serve", "--latency-factor", "5"], capsys)
    assert rc == 0, out


def test_serve_incomplete_or_undrained_fails(tmp_path, capsys):
    base = _write(tmp_path, "b.json", _serve({"premium": (10.0, 500.0)}))
    undrained = _write(tmp_path, "u.json",
                       _serve({"premium": (10.0, 500.0)}, drained=False))
    rc, out = _run([base, undrained, "--serve"], capsys)
    assert rc == 1 and "complete+drain" in out

    dropped = _write(tmp_path, "d.json",
                     _serve({"premium": (10.0, 500.0)}, completed=10))
    rc, out = _run([base, dropped, "--serve"], capsys)
    assert rc == 1 and "INCOMPLETE" in out


def test_serve_one_sided_classes_skip_but_disjoint_fails(tmp_path, capsys):
    base = _write(tmp_path, "b.json", _serve({"premium": (10.0, 500.0),
                                              "gold": (5.0, 100.0)}))
    fresh = _write(tmp_path, "f.json", _serve({"premium": (10.0, 500.0),
                                               "silver": (5.0, 100.0)}))
    rc, out = _run([base, fresh, "--serve"], capsys)
    assert rc == 0 and "skipped" in out

    disjoint = _write(tmp_path, "dj.json", _serve({"iron": (1.0, 1.0)}))
    rc, out = _run([base, disjoint, "--serve"], capsys)
    assert rc == 2 and "no SLO classes" in out


def test_serve_config_mismatch_and_malformed_are_loud(tmp_path, capsys):
    base = _write(tmp_path, "b.json", _serve({"premium": (10.0, 500.0)}))
    other = _write(tmp_path, "o.json",
                   _serve({"premium": (10.0, 500.0)}, slots=4))
    rc, out = _run([base, other, "--serve"], capsys)
    assert rc == 2 and "not comparable" in out and "slots" in out

    for blob, needle in [("{not json", "cannot load"),
                         ('{"classes": {}}', "no 'classes'"),
                         ('{"classes": {"p": {"requests": 1}}}',
                          "decode_tok_s")]:
        bad = _write(tmp_path, "bad.json", blob)
        rc, out = _run([base, bad, "--serve"], capsys)
        assert rc == 2, out
        assert "FAIL" in out and needle in out and "Traceback" not in out

    rc, out = _run([base, base, "--serve", "--sweep-acc"], capsys)
    assert rc == 2 and "mutually exclusive" in out


def test_serve_missing_baseline_names_the_generator(tmp_path, capsys):
    fresh = _write(tmp_path, "f.json", _serve({"premium": (10.0, 500.0)}))
    rc, out = _run([str(tmp_path / "nope.json"), fresh, "--serve"], capsys)
    assert rc == 2 and "bench_serve" in out


# ------------------------------------------------- --serve overload mode


def _overload(classes=None, requests=6, **over):
    """A minimal overloaded bench_serve report (completed < submitted is
    legal there: shed requests terminate without completing)."""
    rep = _serve(classes or {"premium": (4.0, 2000.0)},
                 submitted=38, completed=16,
                 overload=3.0, fault_plan="default", fault_seed=5,
                 deadline_slack=2.5, queue_cap=4)
    for rec in rep["classes"].values():
        rec["requests"] = requests
    section = {"factor": 3.0, "fault_plan": {"seed": 5, "specs": []},
               "submitted": 38, "terminal": 38, "all_terminal": True,
               "served": 14, "degraded": 2, "shed": 22, "expired": 3,
               "deadline_hit_rate": 0.30, "goodput_tok_s": 4.5,
               "degrade_rate": 0.05, "shed_rate": 0.55,
               "retries": {}, "faults_injected": {},
               "decisions_sha256": "deadbeef"}
    section.update(over)
    rep["overload"] = section
    return rep


def test_overload_pass_and_incomplete_is_legal(tmp_path, capsys):
    """Shedding under overload is by design: completed < submitted passes
    as long as every request reached a terminal state and queues drained."""
    base = _write(tmp_path, "b.json", _overload())
    fresh = _write(tmp_path, "f.json", _overload())
    rc, out = _run([base, fresh, "--serve"], capsys)
    assert rc == 0, out
    assert "overload gate" in out and "PASS" in out
    assert "completion: 16/38" in out


def test_overload_deadline_and_goodput_regressions(tmp_path, capsys):
    base = _write(tmp_path, "b.json", _overload())
    worse = _write(tmp_path, "w.json",
                   _overload(deadline_hit_rate=0.30 - 0.31))
    rc, out = _run([base, worse, "--serve"], capsys)
    assert rc == 1, out
    assert "deadline_hit_rate" in out and "REGRESSION" in out

    slow = _write(tmp_path, "s.json", _overload(goodput_tok_s=1.0))
    rc, out = _run([base, slow, "--serve"], capsys)
    assert rc == 1 and "goodput_tok_s" in out

    sheddy = _write(tmp_path, "sh.json", _overload(shed_rate=0.99))
    rc, out = _run([base, sheddy, "--serve"], capsys)
    assert rc == 1 and "shed_rate" in out and "OVER CEILING" in out


def test_overload_hung_request_fails_outright(tmp_path, capsys):
    base = _write(tmp_path, "b.json", _overload())
    hung = _write(tmp_path, "h.json",
                  _overload(all_terminal=False, terminal=37))
    rc, out = _run([base, hung, "--serve"], capsys)
    assert rc == 1, out
    assert "NOT ALL TERMINAL" in out and "all_terminal" in out


def test_overload_presence_mismatch_is_incomparable(tmp_path, capsys):
    over = _write(tmp_path, "o.json", _overload())
    plain = _write(tmp_path, "p.json",
                   _serve({"premium": (4.0, 2000.0)},
                          overload=3.0, fault_plan="default", fault_seed=5,
                          deadline_slack=2.5, queue_cap=4))
    rc, out = _run([over, plain, "--serve"], capsys)
    assert rc == 2, out
    assert "overload section" in out and "not comparable" in out


def test_zero_completed_class_is_unusable_input(tmp_path, capsys):
    """Satellite bugfix: a class that shed everything has no latency or
    throughput keys — exit 2 naming the class, not a KeyError."""
    base = _write(tmp_path, "b.json", _overload())
    starved = _overload()
    starved["classes"]["economy"] = {"requests": 0, "shed": 19,
                                     "shed_reasons": {"queue_full": 19}}
    bad = _write(tmp_path, "z.json", starved)
    rc, out = _run([base, bad, "--serve"], capsys)
    assert rc == 2, out
    assert "economy" in out and "zero requests" in out
    assert "Traceback" not in out


def test_malformed_overload_sections_are_loud(tmp_path, capsys):
    base = _write(tmp_path, "b.json", _overload())
    for mutate, needle in [
            (lambda r: r.__setitem__("overload", "3x"), "not an object"),
            (lambda r: r["overload"].pop("goodput_tok_s"), "goodput_tok_s"),
            (lambda r: r["overload"].__setitem__("shed_rate", "high"),
             "shed_rate"),
            (lambda r: r["overload"].__setitem__("deadline_hit_rate", True),
             "deadline_hit_rate")]:
        rep = _overload()
        mutate(rep)
        bad = _write(tmp_path, "m.json", rep)
        rc, out = _run([base, bad, "--serve"], capsys)
        assert rc == 2, out
        assert "FAIL" in out and needle in out and "Traceback" not in out
