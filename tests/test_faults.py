"""Deterministic fault injection: plans, retries, clocks, loop integration.

Contracts under test (launch.faults + ServeLoop crosspoints):

- a FaultPlan is bit-for-bit reproducible: same (specs, seed) -> same draw
  sequence, and each crosspoint's stream is independent of how often the
  other crosspoints are consulted;
- VirtualClock makes every ServeLoop timestamp model-derived, so two runs
  with the same seed + plan log identical admit/degrade/shed decisions;
- every injected fault terminates: retried to success, degraded, or shed —
  never a hung loop, and shed requests are never billed;
- a corrupted mask-set fingerprint is detected at admission
  (MaskSetStore.verify) and the request degrades or sheds, not serves.
"""
import json

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.launch import faults, serve_loop
from repro.models.lm import LM


@pytest.fixture(scope="module")
def served():
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = serve_loop.threshold_mask_sets(model, [1.0, 0.25], seed=0)
    return cfg, model, params, store


def _loop(served, *, plan=None, retries=None, ladder=False, max_new=3):
    cfg, model, params, store = served
    classes = [serve_loop.SLOClass("premium", store.names[0], max_new),
               serve_loop.SLOClass("economy", store.names[1], max_new)]
    lad = serve_loop.DegradationLadder.from_store(store) if ladder else None
    return serve_loop.ServeLoop(
        model, params, store, classes, slots=2, max_len=32, prompt_bucket=8,
        clock=faults.VirtualClock(), fault_plan=plan, retries=retries,
        ladder=lad)


# ---------------------------------------------------------------- FaultPlan

def test_spec_validation():
    with pytest.raises(ValueError, match="unknown crosspoint"):
        faults.FaultSpec("warp", "fail", 0.5)
    with pytest.raises(ValueError, match="outside"):
        faults.FaultSpec("prefill", "fail", 1.5)


def test_plan_draws_are_reproducible():
    specs = (faults.FaultSpec("prefill", "fail", 0.3),
             faults.FaultSpec("prefill", "slow", 0.3, delay_s=0.1),
             faults.FaultSpec("decode", "stall", 0.2, delay_s=0.05))
    a = faults.FaultPlan(specs, seed=11)
    b = faults.FaultPlan(specs, seed=11)
    seq_a = [a.draw("prefill") for _ in range(64)]
    seq_b = [b.draw("prefill") for _ in range(64)]
    assert seq_a == seq_b
    assert any(s is not None for s in seq_a)
    c = faults.FaultPlan(specs, seed=12)
    assert [c.draw("prefill") for _ in range(64)] != seq_a


def test_crosspoint_streams_are_independent():
    """Consulting one crosspoint more often must not shift another's
    schedule — that is what makes replay under retries exact."""
    specs = (faults.FaultSpec("prefill", "fail", 0.3),
             faults.FaultSpec("decode", "stall", 0.3, delay_s=0.01))
    a = faults.FaultPlan(specs, seed=3)
    b = faults.FaultPlan(specs, seed=3)
    for _ in range(50):                       # extra decode traffic on b
        b.draw("decode")
    assert [a.draw("prefill") for _ in range(32)] == \
        [b.draw("prefill") for _ in range(32)]


def test_rate_edges():
    always = faults.FaultPlan((faults.FaultSpec("prefill", "fail", 1.0),),
                              seed=0)
    never = faults.FaultPlan((faults.FaultSpec("prefill", "fail", 0.0),),
                             seed=0)
    assert all(always.draw("prefill") is not None for _ in range(16))
    assert all(never.draw("prefill") is None for _ in range(16))
    assert never.stats() == {}
    assert always.stats() == {"prefill": {"fail": 16}}


def test_plan_describe_is_json_ready():
    plan = faults.default_chaos_plan(seed=7)
    desc = json.loads(json.dumps(plan.describe()))
    assert desc["seed"] == 7
    assert {s["crosspoint"] for s in desc["specs"]} == set(faults.CROSSPOINTS)


def test_corrupt_fingerprint_never_matches():
    fp = "a" * 64
    bad = faults.corrupt_fingerprint(fp)
    assert bad != fp
    assert bad == faults.corrupt_fingerprint(fp)      # deterministic


def test_virtual_clock():
    clk = faults.VirtualClock(start=1.0)
    assert clk.now() == 1.0
    clk.advance(0.25)
    assert clk.now() == 1.25
    with pytest.raises(ValueError, match="advance"):
        clk.advance(-0.1)


# ------------------------------------------------------- loop integration

def test_prefill_faults_retry_to_success(served):
    """Sub-certain fail rate: some prefills need retries but every request
    still reaches a terminal state and every completion is billed."""
    plan = faults.FaultPlan((faults.FaultSpec("prefill", "fail", 0.4),),
                            seed=5)
    loop = _loop(served, plan=plan)
    rng = np.random.default_rng(0)
    for i in range(8):
        loop.submit(rng.integers(0, served[0].vocab, 6),
                    ("premium", "economy")[i % 2])
    loop.shutdown(drain=True)
    stats = loop.stats()
    assert stats["terminal"] == 8 and stats["pending"] == 0
    assert plan.stats().get("prefill", {}).get("fail", 0) > 0
    assert all(r.bill is not None for r in loop.completed)
    assert all(r.bill is None for r in loop.shed)


def test_certain_prefill_failure_sheds_with_reason(served):
    plan = faults.FaultPlan((faults.FaultSpec("prefill", "fail", 1.0),),
                            seed=0)
    loop = _loop(served, plan=plan)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "shed" and req.shed_reason == "prefill_failed"
    assert req.bill is None
    pol = loop.retries["prefill"]
    assert loop.fault_stats["prefill"]["injected"] == pol.max_attempts
    assert loop.fault_stats["prefill"]["gave_up"] == 1


def test_slow_prefill_absorbed_within_timeout(served):
    plan = faults.FaultPlan(
        (faults.FaultSpec("prefill", "slow", 1.0, delay_s=0.05),), seed=0)
    loop = _loop(served, plan=plan)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "served"                 # delay absorbed as latency
    assert loop.fault_stats["prefill"]["injected"] > 0
    assert loop.fault_stats["prefill"]["gave_up"] == 0


def test_slow_prefill_beyond_timeout_is_a_failure(served):
    plan = faults.FaultPlan(
        (faults.FaultSpec("prefill", "slow", 1.0, delay_s=0.5),), seed=0)
    retries = {"prefill": faults.RetryPolicy(max_attempts=2, backoff_s=0.0,
                                             timeout_s=0.1)}
    loop = _loop(served, plan=plan, retries=retries)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "shed" and req.shed_reason == "prefill_failed"


def test_decode_stall_is_retried_in_place(served):
    plan = faults.FaultPlan(
        (faults.FaultSpec("decode", "stall", 1.0, delay_s=0.02),), seed=0)
    loop = _loop(served, plan=plan)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "served" and len(req.tokens) == 3
    assert loop.fault_stats["decode"]["injected"] > 0


def test_corrupt_fingerprint_sheds_without_ladder(served):
    plan = faults.FaultPlan(
        (faults.FaultSpec("fingerprint", "corrupt", 1.0),), seed=0)
    loop = _loop(served, plan=plan)
    req = loop.submit(np.arange(1, 6), "premium")
    loop.shutdown(drain=True)
    assert req.state == "shed" and req.shed_reason == "mask_corrupt"
    assert req.bill is None and loop.fault_stats["fingerprint"]["gave_up"] > 0


def test_corrupt_fingerprint_recovers_via_retry(served):
    """50% corruption: verification retries succeed often enough that the
    load completes; nothing is served off an unverified set."""
    plan = faults.FaultPlan(
        (faults.FaultSpec("fingerprint", "corrupt", 0.5),), seed=1)
    loop = _loop(served, plan=plan, ladder=True)
    rng = np.random.default_rng(0)
    for i in range(8):
        loop.submit(rng.integers(0, served[0].vocab, 6),
                    ("premium", "economy")[i % 2])
    loop.shutdown(drain=True)
    stats = loop.stats()
    assert stats["terminal"] == 8 and stats["pending"] == 0
    for r in loop.completed:       # billed set is always the verified one
        assert r.bill["fingerprint"] == \
            loop.store.info(r.mask_set).fingerprint


def test_same_seed_replays_decisions_bitwise(served):
    """The acceptance criterion: same seed + plan -> identical
    admit/degrade/shed decision log, hash-equal."""
    def run():
        plan = faults.default_chaos_plan(seed=42)
        loop = _loop(served, plan=plan, ladder=True)
        rng = np.random.default_rng(9)
        for i in range(10):
            loop.submit(rng.integers(0, served[0].vocab,
                                     int(rng.integers(2, 12))),
                        ("premium", "economy")[i % 2])
        loop.shutdown(drain=True)
        return loop
    a, b = run(), run()
    assert a.decision_log == b.decision_log
    assert a.stats()["decisions_sha256"] == b.stats()["decisions_sha256"]
    assert [r.state for r in a.completed] == [r.state for r in b.completed]
