"""Block Coordinate Descent (the paper's algorithm) — behavioural tests on a
small masked CNN over synthetic CIFAR."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcd, linearize, masks as M
from repro.core.snl import finetune
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


@pytest.fixture(scope="module")
def small_setup():
    cfg = CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)), stem_channels=8)
    model = CNN(cfg)
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    step, loss_fn = train_lib.make_cnn_train_step(
        model, opt_lib.sgd(lr=5e-2, momentum=0.9))
    batches = data.batches("train", 32)
    masks0 = linearize.init_masks(model.mask_sites())
    ostate = opt_lib.sgd(lr=5e-2, momentum=0.9).init(params)
    mdev = M.as_device(masks0)
    opt = opt_lib.sgd(lr=5e-2, momentum=0.9)
    ostate = opt.init(params)
    st = step
    for i in range(60):
        params, ostate, loss, acc = st(params, ostate, mdev,
                                       {k: jnp.asarray(v)
                                        for k, v in batches(i).items()})
    return model, data, params, loss_fn, masks0


def _eval_fn(model, params, batch):
    b = {k: jnp.asarray(v) for k, v in batch.items()}

    @jax.jit
    def acc(masks):
        logits = model.forward(params, masks, b["images"])
        return jnp.mean((jnp.argmax(logits, -1) == b["labels"])
                        .astype(jnp.float32)) * 100.0
    return lambda m: float(acc(M.as_device(m)))


def test_bcd_reaches_exact_budget_and_only_removes(small_setup):
    model, data, params, loss_fn, masks0 = small_setup
    total = M.count(masks0)
    target = total - 3 * 16
    eval_acc = _eval_fn(model, params, data.train_eval_set(128))
    cfg = bcd.BCDConfig(b_target=target, drc=16, rt=4, adt=0.5,
                        finetune_every_step=False)
    res = bcd.run_bcd(masks0, cfg, eval_acc, keep_snapshots=True)
    assert M.count(res.masks) == target                 # sparse BY DESIGN
    assert M.is_subset(res.masks, masks0)               # eliminate-only
    # every snapshot is a subset of the previous (golden-set property)
    snaps = [masks0] + res.mask_snapshots
    for a, b in zip(snaps[1:], snaps[:-1]):
        assert M.is_subset(a, b)
        assert M.intersection_over_union(a, b) == 1.0
    assert len(res.history) == 3
    assert all(h.trials <= cfg.rt for h in res.history)


def test_bcd_beats_random_removal(small_setup):
    """The paper's core claim, miniaturized: BCD's chosen blocks degrade
    accuracy no more than uniformly random removal of the same size."""
    model, data, params, loss_fn, masks0 = small_setup
    eval_acc = _eval_fn(model, params, data.train_eval_set(128))
    total = M.count(masks0)
    target = int(total * 0.7)
    cfg = bcd.BCDConfig(b_target=target, drc=(total - target) // 4, rt=6,
                        adt=0.05, finetune_every_step=False, seed=1)
    res = bcd.run_bcd(masks0, cfg, eval_acc)
    acc_bcd = eval_acc(res.masks)
    rng = np.random.default_rng(2)
    accs_rand = [eval_acc(M.remove_random(rng, masks0, total - target))
                 for _ in range(5)]
    assert acc_bcd >= np.mean(accs_rand) - 1e-6, (acc_bcd, accs_rand)


def test_bcd_with_finetune_recovers_accuracy(small_setup):
    model, data, params, loss_fn, masks0 = small_setup
    eval_acc_of = lambda p: _eval_fn(model, p, data.train_eval_set(128))
    batches = data.batches("train", 32, seed=7)
    total = M.count(masks0)
    target = int(total * 0.8)
    state = {"params": params}

    def ft(hard_masks):
        state["params"] = finetune(
            state["params"], hard_masks,
            lambda p, m, b, soft: loss_fn(p, m, b, soft),
            lambda i: {k: jnp.asarray(v) for k, v in batches(i).items()},
            steps=10, lr=1e-2)

    cfg = bcd.BCDConfig(b_target=target, drc=(total - target) // 2, rt=4,
                        adt=0.3)
    res = bcd.run_bcd(masks0, cfg, lambda m: eval_acc_of(state["params"])(m),
                      finetune=ft)
    assert M.count(res.masks) == target
    final = eval_acc_of(state["params"])(res.masks)
    base = eval_acc_of(params)(masks0)
    assert final >= base - 25.0     # finetuned sparse model stays in range
