"""Prefix-reuse candidate evaluation: split-forward contract + suffix engine.

Three layers of guarantees under test:

1. **Model contract** — ``forward_suffix(p, m, forward_prefix(p, m, x, s), s)
   == forward(p, m, x)`` *bitwise* for every site ``s``, both model
   families.  Prefix/suffix fold the same segment list / reuse the same
   layer helpers as forward, so a composed trace emits identical
   primitives; this suite pins that down.
2. **Selection equivalence** — the suffix backend evaluates in site-major
   order (one cached prefix per group) but replays Alg. 2's sampling-order
   selection rules; ``run_bcd`` must pick bit-identical blocks vs the
   sequential reference at every prefetch depth, with identical trial
   counts and early-exit flags.
3. **Plumbing** — sited chunks never straddle a segment (coalesced
   fallback chunks may — they share no prefix), the cost model falls
   shallow cuts back to the full forward, and every prefix-trie entry is
   batch-sharded (never gathered) on a forced 4-device
   ``("cand", "batch")`` mesh.
"""
import numpy as np
import jax
import pytest

# hypothesis is an optional dev dep (pip extra: test) — bare environments
# must still collect/run the deterministic tests, so only the property
# tests below are guarded.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.analysis.roofline import SuffixCostModel
from repro.configs.base import ArchConfig, Block, get_config
from repro.core import bcd, engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.lm import LM
from repro.models.resnet import CNN, CNNConfig


# ------------------------------------------------------- model contract


def _assert_split_bitwise(model, params, masks, forward_args, sites):
    md = M.as_device(masks)
    full = np.asarray(jax.jit(model.forward)(params, md, *forward_args))
    for site in sites:
        def composed(p, m, x, site=site):
            return model.forward_suffix(
                p, m, model.forward_prefix(p, m, x, site), site)
        out = jax.jit(composed)(params, md, *forward_args)
        np.testing.assert_array_equal(
            np.asarray(out), full,
            err_msg=f"prefix∘suffix != forward at site {site}")


def test_cnn_split_forward_bitwise_per_site():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    # zero a few coordinates so masks are non-trivial
    rng = np.random.default_rng(0)
    masks = M.sample_removal_block(rng, masks, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    assert set(model.site_order()) == set(model.mask_sites())
    _assert_split_bitwise(model, params, masks, (x,), model.site_order())


def test_wide_cnn_split_forward_bitwise_per_site():
    model = CNN(CNNConfig("wrn-mini", 4, 16,
                          ((8, 1, 1), (16, 1, 2), (16, 1, 2)),
                          stem_channels=8, wide=True))
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    _assert_split_bitwise(model, params, masks, (x,), model.site_order())


def _tiny_lm():
    # 1 head block + scanned (2 patterns x 2 repeats) + 1 tail block: every
    # segment kind (head / stack / tail) gets a cut
    cfg = ArchConfig(
        name="tiny-split", family="dense", n_layers=6, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=48, vocab=64, head_dim=16,
        pattern=(Block("dense"), Block("dense")),
        head_blocks=(Block("dense"),), dtype="float32")
    assert cfg.n_repeats == 2 and len(cfg.tail) == 1
    return LM(cfg)


def test_lm_split_forward_bitwise_per_site():
    model = _tiny_lm()
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(0)
    masks = M.sample_removal_block(rng, masks, 16)
    tokens = np.asarray(rng.integers(0, model.cfg.vocab, (2, 17),
                                     dtype=np.int32))
    md = M.as_device(masks)
    full = np.asarray(
        jax.jit(lambda p, m, t: model.forward(p, m, t)[0])(params, md,
                                                           tokens))
    # stack sites are addressed by virtual per-repeat names, one per
    # (site, repeat); repeat-0 cuts sort at the same segment
    assert model.site_order() == ("h0.ffn", "s0.ffn@0", "s1.ffn@0",
                                  "s0.ffn@1", "s1.ffn@1", "t0.ffn")
    assert model.site_repeats() == {"s0.ffn": 2, "s1.ffn": 2}
    for site in model.site_order():
        def composed(p, m, t, site=site):
            return model.forward_suffix(
                p, m, model.forward_prefix(p, m, t, site), site)
        out = np.asarray(jax.jit(composed)(params, md, tokens))
        np.testing.assert_array_equal(out, full, err_msg=site)


# ----------------------------------------- SSM / MoE family contract


def _family_setup(arch_id, seed=0, B=2, S=17):
    """Reduced-config LM + non-trivial masks + a token batch."""
    model = LM(get_config(arch_id).reduced())
    params = model.init(jax.random.PRNGKey(seed))
    masks = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(seed)
    masks = M.sample_removal_block(rng, masks, 16)
    tokens = np.asarray(rng.integers(0, model.cfg.vocab, (B, S),
                                     dtype=np.int32))
    return model, params, masks, tokens


def _assert_lm_split_bitwise(model, params, masks, tokens):
    """prefix∘suffix == forward bitwise at every site, with prefix and
    suffix compiled as SEPARATE jits (the engine's program boundaries)."""
    md = M.as_device(masks)
    full = np.asarray(jax.jit(
        lambda p, m, t: model.forward(p, m, t)[0])(params, md, tokens))
    for site in model.site_order():
        pj = jax.jit(lambda p, m, t, s=site: model.forward_prefix(p, m, t, s))
        sj = jax.jit(lambda p, m, c, s=site: model.forward_suffix(p, m, c, s))
        out = np.asarray(sj(params, md, pj(params, md, tokens)))
        np.testing.assert_array_equal(
            out, full, err_msg=f"prefix∘suffix != forward at site {site}")
    return md, full


def test_ssm_split_forward_bitwise_per_site_including_mid_scan():
    """rwkv6 reduced is a pure scanned stack (no head/tail): every cut is a
    carry checkpoint, and the repeat-1 cut resumes the scan mid-stack."""
    model, params, masks, tokens = _family_setup("rwkv6_3b")
    assert model.site_order() == ("s0.rwkv@0", "s0.rwkv@1")
    assert model.site_repeats() == {"s0.rwkv": 2}
    _assert_lm_split_bitwise(model, params, masks, tokens)


def test_moe_split_forward_bitwise_per_site_including_mid_scan():
    """deepseek-moe reduced: dense head + scanned MoE stack with routed +
    shared-expert sites; capacity-overflow token dropping is live at this
    sequence length, so routing determinism is part of the contract."""
    model, params, masks, tokens = _family_setup("deepseek_moe_16b")
    assert model.site_order() == ("h0.ffn", "s0.moe@0", "s0.moe_shared@0",
                                  "s0.moe@1", "s0.moe_shared@1")
    _assert_lm_split_bitwise(model, params, masks, tokens)


@pytest.mark.parametrize("arch_id", ["rwkv6_3b", "deepseek_moe_16b"])
def test_carry_checkpoint_prefix_extension_roundtrip(arch_id):
    """Trie-extension contract along repeats: ``prefix_ext(a, b, m,
    prefix(a)) == prefix(b)`` bitwise for consecutive cuts — the carry
    checkpoint at repeat r resumes the scan instead of re-running it."""
    model, params, masks, tokens = _family_setup(arch_id, seed=1)
    md = M.as_device(masks)
    order, segs = model.site_order(), model.site_segments()
    pairs = [(order[i], order[i + 1]) for i in range(len(order) - 1)
             if segs[order[i]] < segs[order[i + 1]]]
    assert pairs, "no consecutive cut pair to extend across"
    for a, b in pairs:
        pa = jax.jit(lambda m, a=a: model.forward_prefix(
            params, m, tokens, a))(md)
        pe = jax.jit(lambda m, c, a=a, b=b: model.forward_prefix(
            params, m, tokens, b, from_site=a, cached=c))(md, pa)
        pb = jax.jit(lambda m, b=b: model.forward_prefix(
            params, m, tokens, b))(md)
        np.testing.assert_array_equal(
            np.asarray(pe), np.asarray(pb),
            err_msg=f"prefix_ext({a} -> {b}) != prefix({b})")


def test_suffix_trie_extends_along_repeats_and_row_diff_invalidation():
    """Carry-aware prefix caching: a repeat-0 checkpoint is EXTENDED to the
    repeat-1 cut (one more scan repeat, no recompute from tokens), and
    ``begin_step`` diffing is per repeat row — editing only repeat-1 rows
    of the stacked base mask keeps every checkpoint warm, editing repeat-0
    rows drops the mid-scan one."""
    model = LM(get_config("rwkv6_3b").reduced())
    params = model.init(jax.random.PRNGKey(2))
    masks0 = linearize.init_masks(model.mask_sites())
    tokens = np.asarray(np.random.default_rng(2).integers(
        0, model.cfg.vocab, (2, 17), dtype=np.int32))
    ctx = {"params": params, "batch": {"tokens": tokens}}
    ev = engine.make_evaluator("suffix", split=model.make_suffix_eval_fns(),
                               context=ctx, pad_to=4)
    seq = engine.SequentialEvaluator(
        model.make_eval_acc(params, {"tokens": tokens}))
    segs, reps = model.site_segments(), model.site_repeats()
    rng = np.random.default_rng(0)
    idx0 = M.sample_removal_indices_within(rng, masks0, 8, 4, ["s0.rwkv@0"],
                                           repeat_sites=reps)
    idx1 = M.sample_removal_indices_within(rng, masks0, 8, 4, ["s0.rwkv@1"],
                                           repeat_sites=reps)
    st0 = M.materialize_candidates(masks0, idx0)
    st1 = M.materialize_candidates(masks0, idx1)
    ev.begin_step(masks0)
    a0 = ev.evaluate(engine.SitedChunk("s0.rwkv@0", st0))
    np.testing.assert_allclose(a0, seq.evaluate(st0), atol=1e-4)
    a1 = ev.evaluate(engine.SitedChunk("s0.rwkv@1", st1))
    np.testing.assert_allclose(a1, seq.evaluate(st1), atol=1e-4)
    assert ev.trie.extensions == 1 and ev.trie.misses == 1, \
        (ev.trie.extensions, ev.trie.misses)
    assert ev.trie.depths() == (segs["s0.rwkv@0"], segs["s0.rwkv@1"])
    # repeat-1-only base edit: prefixes fold repeats strictly BEFORE their
    # cut, so both carry checkpoints stay warm
    edited = {k: np.array(v) for k, v in masks0.items()}
    edited["s0.rwkv"][1].flat[0] = 0.0
    ev.begin_step(edited)
    assert ev.trie.depths() == (segs["s0.rwkv@0"], segs["s0.rwkv@1"])
    # a repeat-0 edit invalidates the mid-scan checkpoint (it folded that
    # repeat) but keeps the embed-only depth
    edited2 = {k: np.array(v) for k, v in masks0.items()}
    edited2["s0.rwkv"][0].flat[0] = 0.0
    ev.begin_step(edited2)
    assert ev.trie.depths() == (segs["s0.rwkv@0"],)


if HAS_HYPOTHESIS:
    _PROP_LM = {}

    def _prop_lm():
        if not _PROP_LM:
            cfg = ArchConfig(
                name="tiny-repeats", family="dense", n_layers=6, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=48, vocab=64, head_dim=16,
                pattern=(Block("dense"),), head_blocks=(Block("dense"),),
                dtype="float32")
            model = LM(cfg)
            assert cfg.n_repeats == 4
            _PROP_LM["model"] = model
            _PROP_LM["params"] = model.init(jax.random.PRNGKey(0))
        return _PROP_LM["model"], _PROP_LM["params"]

    @settings(deadline=None, max_examples=10)
    @given(r=st.integers(min_value=0, max_value=3),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_property_cut_at_any_repeat_matches_unsegmented(r, seed):
        """Cutting the scanned stack at an arbitrary repeat r is bitwise
        the unsegmented forward, for arbitrary masks and token batches."""
        model, params = _prop_lm()
        rng = np.random.default_rng(seed)
        masks = linearize.init_masks(model.mask_sites())
        masks = M.sample_removal_block(rng, masks, 8)
        tokens = np.asarray(rng.integers(0, model.cfg.vocab, (2, 9),
                                         dtype=np.int32))
        md = M.as_device(masks)
        site = f"s0.ffn@{r}"
        full = np.asarray(jax.jit(
            lambda m, t: model.forward(params, m, t)[0])(md, tokens))
        out = np.asarray(jax.jit(
            lambda m, t, s=site: model.forward_suffix(
                params, m, model.forward_prefix(params, m, t, s), s))(
                    md, tokens))
        np.testing.assert_array_equal(out, full, err_msg=site)
else:
    def test_property_cut_at_any_repeat_matches_unsegmented():
        pytest.skip("hypothesis not installed (pip extra: test)")


def _assert_pre_contract(split, ctx, masks):
    """SplitEval.pre contract: ``full(m, {**ctx, "pre": pre(ctx)})`` is
    bitwise ``full(m, ctx)`` — the depth-0 analogue of prefix∘suffix."""
    md = M.as_device(masks)
    pre = jax.jit(split.pre)(ctx)
    base = np.asarray(jax.jit(split.full)(md, ctx))
    folded = np.asarray(jax.jit(split.full)(md, {**ctx, "pre": pre}))
    np.testing.assert_array_equal(folded, base)


def test_cnn_pre_fold_bitwise():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(0)
    masks = M.sample_removal_block(rng, masks, 16)
    ctx = {"params": params,
           "batch": {"images": np.asarray(
                         rng.standard_normal((2, 16, 16, 3)), np.float32),
                     "labels": np.asarray(rng.integers(0, 4, (2,)),
                                          np.int32)}}
    _assert_pre_contract(model.make_suffix_eval_fns(), ctx, masks)


def test_wide_cnn_pre_fold_bitwise():
    model = CNN(CNNConfig("wrn-mini", 4, 16,
                          ((8, 1, 1), (16, 1, 2), (16, 1, 2)),
                          stem_channels=8, wide=True))
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(1)
    ctx = {"params": params,
           "batch": {"images": np.asarray(
                         rng.standard_normal((2, 16, 16, 3)), np.float32),
                     "labels": np.asarray(rng.integers(0, 4, (2,)),
                                          np.int32)}}
    _assert_pre_contract(model.make_suffix_eval_fns(), ctx, masks)


def test_lm_pre_fold_bitwise():
    model = _tiny_lm()
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(2)
    masks = M.sample_removal_block(rng, masks, 16)
    ctx = {"params": params,
           "batch": {"tokens": np.asarray(
               rng.integers(0, model.cfg.vocab, (2, 17)), np.int32)}}
    _assert_pre_contract(model.make_suffix_eval_fns(), ctx, masks)


def test_suffix_evaluator_context_carries_pre(setup):
    """Construction computes the mask-independent head fold once and ships
    it as ``context["pre"]``; set_context recomputes it."""
    model, params, batch, masks0 = setup
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    ev = engine.SuffixEvaluator(model.make_suffix_eval_fns(), context=ctx)
    assert "pre" in ev.context
    expect = np.asarray(jax.jit(
        lambda c: model.forward_pre(c["params"], c["batch"]["images"]))(ctx))
    np.testing.assert_array_equal(np.asarray(ev.context["pre"]), expect)
    # swapping the context recomputes the fold from the new params
    params2 = model.init(jax.random.PRNGKey(9))
    ev.set_context({"params": params2, "batch": ctx["batch"]})
    expect2 = np.asarray(jax.jit(
        lambda c: model.forward_pre(c["params"], c["batch"]["images"]))(
            {"params": params2, "batch": ctx["batch"]}))
    np.testing.assert_array_equal(np.asarray(ev.context["pre"]), expect2)
    assert not np.array_equal(expect, expect2)


def test_suffix_sites_and_fractions_are_monotone():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    order = model.site_order()
    fr = model.site_prefix_fractions()
    segs = model.site_segments()
    prev = -1.0
    for site in order:
        # suffix consumes exactly the sites in segments >= the cut
        assert model.suffix_sites(site) == tuple(
            s for s in order if segs[s] >= segs[site])
        assert fr[site] >= prev - 1e-12     # deeper cut, larger prefix
        prev = fr[site]
    assert fr[order[0]] == 0.0
    assert fr[order[-1]] > 0.5
    lm = _tiny_lm()
    lfr = lm.site_prefix_fractions()
    assert lfr["h0.ffn"] == 0.0
    assert lfr["t0.ffn"] > lfr["s0.ffn"] > lfr["h0.ffn"]
    assert lm.suffix_sites("s1.ffn") == ("s0.ffn", "s1.ffn", "t0.ffn")
    # per-repeat cuts: deeper repeats reuse a larger prefix; the REAL mask
    # name maps to its repeat-0 segment (the shallowest cut its
    # coordinates can force)
    assert lfr["s0.ffn@1"] > lfr["s0.ffn@0"] == lfr["s0.ffn"]
    # a mid-scan cut still ships the full (R, ·) stack arrays: a stack
    # site's deepest repeat is always at/after any stack cut
    assert lm.suffix_sites("s0.ffn@1") == ("s0.ffn", "s1.ffn", "t0.ffn")
    assert lm.suffix_sites("t0.ffn") == ("t0.ffn",)


# -------------------------------------------------- grouping / planning


def test_group_blocks_by_site():
    masks = {"a": np.ones((4,), np.float32), "b": np.ones((4,), np.float32),
             "c": np.ones((4,), np.float32)}
    _, layout = M._flatten(masks)       # a:[0,4) b:[4,8) c:[8,12)
    rank = {"a": 0, "b": 1, "c": 2}
    indices = np.array([[9, 10],        # earliest c -> rank 2
                        [5, 11],        # earliest b -> rank 1
                        [1, 9],         # earliest a -> rank 0
                        [6, 7],         # rank 1
                        [8, 11]])       # rank 2
    order, groups = M.group_blocks_by_site(indices, layout, rank)
    np.testing.assert_array_equal(order, [2, 1, 3, 0, 4])  # stable in-group
    assert groups == [(0, 0, 1), (1, 1, 3), (2, 3, 5)]
    # empty-candidate edge
    order0, groups0 = M.group_blocks_by_site(
        np.zeros((0, 2), np.int64), layout, rank)
    assert order0.size == 0 and groups0 == []


def test_group_blocks_by_site_repeat_aware():
    """With ``repeat_sites``, a stack coordinate's rank is its repeat-0
    rank plus its repeat row — candidates touching only deep repeats group
    at deeper segments (larger reusable prefixes)."""
    masks = {"h0.ffn": np.ones((4,), np.float32),
             "s0.ffn": np.ones((2, 4), np.float32)}   # R=2, 4 per repeat
    _, layout = M._flatten(masks)      # h0:[0,4) s0:[4,12) repeat-major
    rank = {"h0.ffn": 0, "s0.ffn": 1}
    reps = {"s0.ffn": 2}
    indices = np.array([[8, 9],        # repeat 1 only -> rank 2
                        [4, 10],       # earliest repeat 0 -> rank 1
                        [0, 11],       # head coord -> rank 0
                        [10, 11]])     # repeat 1 -> rank 2
    order, groups = M.group_blocks_by_site(indices, layout, rank,
                                           repeat_sites=reps)
    np.testing.assert_array_equal(order, [2, 1, 0, 3])
    assert groups == [(0, 0, 1), (1, 1, 2), (2, 2, 4)]
    # without repeat_sites every stack coordinate collapses to rank 1
    _, flat_groups = M.group_blocks_by_site(indices, layout, rank)
    assert [g[0] for g in flat_groups] == [0, 1]
    # move_site_ranks agrees coordinate-wise (swap ranks by its shallowest
    # touched coordinate across off ∪ on)
    moves = [M.Move.remove(np.array([8, 9])),
             M.Move.swap(np.array([4]), np.array([10])),
             M.Move.remove(np.array([0, 11])),
             M.Move.remove(np.array([10, 11]))]
    np.testing.assert_array_equal(
        M.move_site_ranks(moves, layout, rank, repeat_sites=reps),
        [2, 1, 0, 2])


def test_sample_removal_indices_within_virtual_repeat_sites():
    """Virtual ``site@r`` names restrict sampling to that repeat's rows of
    the stacked (R, ·) mask array."""
    masks = {"h0.ffn": np.ones((6,), np.float32),
             "s0.ffn": np.ones((2, 6), np.float32)}
    _, layout = M._flatten(masks)      # h0:[0,6) s0:[6,18)
    rng = np.random.default_rng(0)
    idx = M.sample_removal_indices_within(rng, masks, 3, 4, ["s0.ffn@1"],
                                          repeat_sites={"s0.ffn": 2})
    assert idx.shape == (4, 3)
    assert ((idx >= 12) & (idx < 18)).all(), idx    # repeat-1 rows only
    idx0 = M.sample_removal_indices_within(rng, masks, 3, 4, ["s0.ffn@0"],
                                           repeat_sites={"s0.ffn": 2})
    assert ((idx0 >= 6) & (idx0 < 12)).all(), idx0
    # the bare real name still spans every repeat
    idx_all = M.sample_removal_indices_within(rng, masks, 3, 16, ["s0.ffn"],
                                              repeat_sites={"s0.ffn": 2})
    assert ((idx_all >= 6) & (idx_all < 18)).all()
    assert (idx_all < 12).any() and (idx_all >= 12).any()


def test_coalesce_fallback_chunks():
    raw = [("deep", 0, 2), (None, 2, 3), (None, 3, 5), (None, 5, 6),
           ("mid", 6, 8), (None, 8, 9)]
    out = M.coalesce_fallback_chunks(raw, chunk_size=2)
    # the 3 adjacent fallback tails merge into ceil(3/2) chunks; sited
    # chunks and the trailing singleton pass through
    assert out == [("deep", 0, 2), (None, 2, 4), (None, 4, 6),
                   ("mid", 6, 8), (None, 8, 9)]
    # all-fallback plan collapses to chunk_size-sized spans
    assert M.coalesce_fallback_chunks(
        [(None, 0, 2), (None, 2, 4), (None, 4, 5)], 4) == \
        [(None, 0, 4), (None, 4, 5)]
    assert M.coalesce_fallback_chunks([], 4) == []


def test_plan_sited_chunks_never_straddles_and_respects_cost_model():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    masks = linearize.init_masks(model.mask_sites())
    flat, layout = M._flatten(masks)
    order_sites = model.site_order()
    deep, shallow = order_sites[-1], order_sites[0]
    rng = np.random.default_rng(0)
    idx = np.concatenate([
        M.sample_removal_indices_within(rng, masks, 8, 5, [deep]),
        M.sample_removal_indices_within(rng, masks, 8, 3, [shallow])])
    # a real (tiny) context: construction computes the mask-independent
    # head fold (SplitEval.pre) from it, so it must be evaluable
    ctx = {"params": model.init(jax.random.PRNGKey(0)),
           "batch": {"images": np.zeros((1, 16, 16, 3), np.float32),
                     "labels": np.zeros((1,), np.int32)}}
    ev = engine.SuffixEvaluator(model.make_suffix_eval_fns(), context=ctx,
                                cost_model=SuffixCostModel(
                                    min_prefix_fraction=0.05, min_chunk=2))
    order, chunks = engine.plan_sited_chunks(ev, idx, layout, chunk_size=2)
    segs = model.site_segments()
    cand_seg = [min(segs[s] for s in (deep,)) for _ in range(5)] + \
               [segs[shallow]] * 3
    for site, s, e in chunks:
        grp = {cand_seg[i] for i in order[s:e]}
        if site is not None:
            # sited chunks share one prefix -> must stay inside a group;
            # coalesced fallback chunks may straddle (no shared prefix)
            assert len(grp) == 1, "sited chunk straddles a segment group"
            assert segs[site] == grp.pop()
    # shallow group (prefix fraction 0) must fall back to the full forward
    shallow_chunks = [c for c in chunks
                     if all(cand_seg[i] == segs[shallow]
                            for i in order[c[1]:c[2]])]
    assert shallow_chunks and all(c[0] is None for c in shallow_chunks)
    # deep group runs in suffix mode except any cost-model-undersized tail
    deep_chunks = [c for c in chunks
                   if all(cand_seg[i] == segs[deep]
                          for i in order[c[1]:c[2]])]
    assert deep_chunks
    for site, s, e in deep_chunks:
        # plan labels chunks with the segment's representative site
        assert (site is not None and segs[site] == segs[deep]) \
            == (e - s >= 2)
    # a prohibitive cost model sends everything down the fallback
    ev_off = engine.SuffixEvaluator(
        model.make_suffix_eval_fns(), context=ctx,
        cost_model=SuffixCostModel(min_prefix_fraction=1.1))
    _, chunks_off = engine.plan_sited_chunks(ev_off, idx, layout, 2)
    assert all(site is None for site, _, _ in chunks_off)


def test_suffix_cost_model_formula():
    cm = SuffixCostModel(min_prefix_fraction=0.05, min_chunk=2)
    assert cm.speedup(0.0, 8) == pytest.approx(1.0)
    assert cm.speedup(1.0, 8) == pytest.approx(8.0)
    assert cm.speedup(0.5, 8) == pytest.approx(8 / 4.5)
    assert not cm.use_suffix(0.9, 1)        # nothing to reuse across n=1
    assert not cm.use_suffix(0.01, 8)       # shallow cut
    assert cm.use_suffix(0.5, 2)


# --------------------------------------------- selection equivalence


@pytest.fixture(scope="module")
def setup():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(128)
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def _run(model, params, batch, masks0, evaluator, chunk_size=4, adt=0.5):
    total = M.count(masks0)
    cfg = bcd.BCDConfig(b_target=total - 3 * 16, drc=16, rt=8, adt=adt,
                        finetune_every_step=False, seed=3,
                        chunk_size=chunk_size)
    eval_acc = model.make_eval_acc(params, batch)
    return bcd.run_bcd(masks0, cfg, eval_acc, evaluator=evaluator)


def _assert_same_result(a, b):
    for k in a.masks:
        np.testing.assert_array_equal(a.masks[k], b.masks[k])
    assert len(a.history) == len(b.history)
    for ha, hb in zip(a.history, b.history):
        assert (ha.trials, ha.found_early) == (hb.trials, hb.found_early)
        assert ha.best_drop == pytest.approx(hb.best_drop, abs=1e-4)
        assert (ha.budget_before, ha.budget_after) == \
            (hb.budget_before, hb.budget_after)


def _suffix_ev(model, params, batch, **kw):
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    return engine.make_evaluator("suffix",
                                 split=model.make_suffix_eval_fns(),
                                 context=ctx, **kw)


@pytest.mark.parametrize("prefetch", [0, 1, 2])
def test_suffix_matches_sequential_bitwise(setup, prefetch):
    """Site-major evaluation + sampling-order selection replay: the suffix
    backend selects bit-identical blocks (and identical trial counts /
    early-exit flags) at every prefetch depth.  chunk_size=3 vs rt=8 forces
    ragged chunks."""
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)),
               chunk_size=3)
    suf = _run(model, params, batch, masks0,
               _suffix_ev(model, params, batch, pad_to=3, prefetch=prefetch),
               chunk_size=3)
    _assert_same_result(seq, suf)


def test_suffix_matches_batched_without_early_exit(setup):
    """adt=-1 disables the ADT exit: the full RT argmin path, where every
    candidate is evaluated — suffix vs batched must agree exactly."""
    model, params, batch, masks0 = setup
    bat = _run(model, params, batch, masks0,
               engine.BatchedEvaluator(model.make_eval_fn(params, batch),
                                       pad_to=4), adt=-1.0)
    suf = _run(model, params, batch, masks0,
               _suffix_ev(model, params, batch, pad_to=4, prefetch=1),
               adt=-1.0)
    _assert_same_result(bat, suf)


def test_suffix_cost_model_fallback_is_still_equivalent(setup):
    """min_prefix_fraction > 1 sends every chunk down the inner
    full-forward pipeline — selection must be unchanged (the cost model is
    a pure performance policy)."""
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)))
    suf = _run(model, params, batch, masks0,
               _suffix_ev(model, params, batch, pad_to=4, prefetch=1,
                          cost_model=SuffixCostModel(
                              min_prefix_fraction=1.1)))
    _assert_same_result(seq, suf)


def test_suffix_site_local_candidates_use_prefix_cache(setup):
    """Deep-site-local chunks run in suffix mode: accuracies match the
    sequential reference and the trie holds a cached prefix for the deep
    segment afterwards.  Unchanged base masks keep the trie warm across
    ``begin_step``; a shallow-site edit drops every deeper entry."""
    model, params, batch, masks0 = setup
    deep = model.site_order()[-1]
    shallow = model.site_order()[0]
    segs = model.site_segments()
    idx = M.sample_removal_indices_within(
        np.random.default_rng(0), masks0, 16, 6, [deep])
    stacked = M.materialize_candidates(masks0, idx)
    ev = _suffix_ev(model, params, batch, pad_to=6)
    ev.begin_step(masks0)
    accs = ev.evaluate(engine.SitedChunk(deep, stacked))
    seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    np.testing.assert_allclose(accs, seq.evaluate(stacked), atol=1e-4)
    assert segs[deep] in ev.trie and ev.trie.misses == 1
    # unchanged base masks: entries survive the next begin_step
    ev.begin_step({k: np.array(v) for k, v in masks0.items()})
    assert segs[deep] in ev.trie
    accs2 = ev.evaluate(engine.SitedChunk(deep, stacked))
    np.testing.assert_array_equal(np.asarray(accs2), np.asarray(accs))
    assert ev.trie.hits >= 1 and ev.trie.misses == 1
    # a shallow-site mask edit invalidates every deeper cached prefix
    edited = {k: np.array(v) for k, v in masks0.items()}
    edited[shallow] = np.array(edited[shallow])
    edited[shallow].flat[0] = 0.0
    ev.begin_step(edited)
    assert len(ev.trie) == 0


def test_suffix_set_context_invalidates_prefix_cache(setup):
    model, params, batch, masks0 = setup
    ev = _suffix_ev(model, params, batch, pad_to=4)
    deep = model.site_order()[-1]
    idx = M.sample_removal_indices_within(
        np.random.default_rng(0), masks0, 16, 4, [deep])
    ev.begin_step(masks0)
    a = ev.evaluate(engine.SitedChunk(
        deep, M.materialize_candidates(masks0, idx)))
    assert len(ev.trie)
    # perturb params through the shared context: results must change and
    # the stale prefix must be dropped
    new_params = jax.tree.map(lambda v: v * 0.5, params)
    ev.set_context({"params": new_params,
                    "batch": {k: np.asarray(v) for k, v in batch.items()}})
    assert len(ev.trie) == 0
    b = ev.evaluate(engine.SitedChunk(
        deep, M.materialize_candidates(masks0, idx)))
    seq = engine.SequentialEvaluator(
        model.make_eval_acc(new_params, batch))
    np.testing.assert_allclose(
        b, seq.evaluate(M.materialize_candidates(masks0, idx)), atol=1e-4)
    assert a.shape == b.shape


def test_suffix_evaluator_validates_inputs(setup):
    model, params, batch, masks0 = setup
    split = model.make_suffix_eval_fns()
    with pytest.raises(ValueError, match="context"):
        engine.SuffixEvaluator(split, context=None)
    with pytest.raises(ValueError, match="context"):
        engine.SuffixEvaluator(split, context={"params": params})
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    with pytest.raises(ValueError, match="prefetch"):
        engine.SuffixEvaluator(split, context=ctx, prefetch="turbo")
    with pytest.raises(ValueError, match="split"):
        engine.make_evaluator("suffix", context=ctx)
    ev = engine.SuffixEvaluator(split, context=ctx)
    with pytest.raises(RuntimeError, match="begin_step"):
        ev.evaluate(engine.SitedChunk(
            model.site_order()[-1],
            M.sample_removal_blocks(np.random.default_rng(0), masks0,
                                    4, 2)))


def test_suffix_auto_prefetch_tunes_and_matches_sequential(setup):
    """prefetch="auto" on the suffix backend: the inner pipeline's tuner
    probes the first chunks, locks a depth, and results stay bit-identical
    to the sequential reference throughout (the probe changes timing
    only)."""
    model, params, batch, masks0 = setup
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    ev = engine.SuffixEvaluator(model.make_suffix_eval_fns(), context=ctx,
                                pad_to=2, prefetch="auto")
    assert ev.auto_tuner is not None and not ev.auto_tuner.done
    seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    idx = M.sample_removal_indices(np.random.default_rng(3), masks0, 6, 12)
    flat, layout = M._flatten(masks0)
    ev.begin_step(masks0)
    order, chunks = engine.plan_sited_chunks(ev, idx, layout, chunk_size=2)
    gen = engine.materialize_sited(flat, layout, idx, order, chunks)
    accs = np.concatenate(list(engine.evaluate_prefetched(ev, gen)))
    # un-permute the site-major evaluation back to sampling order
    accs_s = np.empty_like(accs)
    accs_s[order] = accs
    ref = seq.evaluate(M.materialize_candidates(masks0, idx))
    np.testing.assert_array_equal(accs_s, ref)
    # enough chunks to finish the probe: the tuner locked a depth
    assert ev.auto_tuner.done
    assert ev.prefetch_depth >= 0 and ev.auto_report is not None


# ----------------------------------------- forced multi-device sharding


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig

model = CNN(CNNConfig("tiny", 4, 8, ((4, 1, 1), (8, 1, 2)),
                      stem_channels=4))
data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=8,
                                       n_train=64, n_test=32))
params = model.init(jax.random.PRNGKey(0))
batch = data.train_eval_set(16)
masks0 = linearize.init_masks(model.mask_sites())
mesh = mesh_lib.make_cand_batch_mesh(cand=2, batch=2)
ctx = {"params": params,
       "batch": {k: np.asarray(v) for k, v in batch.items()}}
ev = engine.SuffixEvaluator(model.make_suffix_eval_fns(), context=ctx,
                            context_specs=engine.context_batch_specs(ctx),
                            mesh=mesh, pad_to=4, prefetch=1)
seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))

order, segs = model.site_order(), model.site_segments()
deep = order[-1]
mid = max((s for s in order if segs[s] < segs[deep]), key=lambda s: segs[s])
rng = np.random.default_rng(0)
idx_mid = M.sample_removal_indices_within(rng, masks0, 8, 6, [mid])
idx = M.sample_removal_indices_within(rng, masks0, 8, 6, [deep])
stacked_mid = M.materialize_candidates(masks0, idx_mid)
stacked = M.materialize_candidates(masks0, idx)
ev.begin_step(masks0)
accs_mid = ev.evaluate(engine.SitedChunk(mid, stacked_mid))
np.testing.assert_allclose(accs_mid, seq.evaluate(stacked_mid), atol=1e-4)
# deep chunk extends the cached mid-depth ancestor (segments in between
# only), never recomputing from the input
accs = ev.evaluate(engine.SitedChunk(deep, stacked))
np.testing.assert_allclose(accs, seq.evaluate(stacked), atol=1e-4)
assert ev.trie.extensions == 1 and ev.trie.misses == 1, \
    (ev.trie.extensions, ev.trie.misses)
assert ev.trie.depths() == (segs[mid], segs[deep]), ev.trie.depths()

# every trie entry is batch-sharded (never gathered across "batch"),
# including the one produced by the extension path
for depth, cached in ev.trie.items():
    assert "batch" in str(cached.sharding.spec), (depth, cached.sharding)
    assert not cached.sharding.is_fully_replicated, depth
# the mask-independent head fold rides the context batch-sharded too
pre = ev.context["pre"]
assert "batch" in str(pre.sharding.spec), pre.sharding
assert not pre.sharding.is_fully_replicated, pre.sharding
# fallback (un-sited) chunks ride the inner sharded pipeline (and consume
# the sharded "pre" without gathering)
accs2 = ev.evaluate(engine.SitedChunk(None, stacked))
np.testing.assert_allclose(accs2, seq.evaluate(stacked), atol=1e-4)
print("SUFFIX_MESH_OK")
"""


def test_suffix_prefix_cache_batch_sharded_on_forced_mesh():
    """4 forced host devices, ("cand", "batch") = (2, 2): suffix chunks
    shard candidates over "cand" while every trie entry — including one
    built by the ancestor-extension path — stays batch-sharded and never
    gathers; results match the sequential reference."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SUFFIX_MESH_OK" in out.stdout


# -------------------------------------------------------- compile cache


def test_compile_cache_enable_and_hit_counter(tmp_path):
    """enable() + clear_caches() round trip: the second compile of an
    identical program is served from the persistent cache and the counter
    sees it."""
    from repro.launch import compile_cache
    d = str(tmp_path / "cc")
    compile_cache.enable(d)
    ctr = compile_cache.hit_counter()
    import jax.numpy as jnp
    f = jax.jit(lambda x: (x * 3).sum())
    f(jnp.ones((4, 4)))
    assert compile_cache.entry_count(d) > 0
    before_hits = ctr.hits
    jax.clear_caches()
    jax.jit(lambda x: (x * 3).sum())(jnp.ones((4, 4)))
    assert ctr.hits > before_hits
    assert "served from the persistent cache" in ctr.log_line()
    assert set(ctr.summary()) == {"hits", "misses"}
    assert compile_cache.entry_count(None) == 0
