"""Docs hygiene: every in-repo Markdown link must resolve.

Runs the same checker the CI ``docs`` job runs (tools/check_docs_links.py),
so a renamed module or deleted doc page fails tier-1 locally — docs cannot
silently rot between doc-focused PRs.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECKER = os.path.join(REPO, "tools", "check_docs_links.py")


def _run(*args):
    return subprocess.run([sys.executable, CHECKER, *args],
                          capture_output=True, text=True, timeout=120)


def test_repo_markdown_links_resolve():
    out = _run(REPO)
    assert out.returncode == 0, f"\n{out.stdout}{out.stderr}"
    assert "0 broken" in out.stdout


def test_checker_flags_broken_and_absolute_links(tmp_path):
    (tmp_path / "ok.md").write_text("see [real](other.md) and "
                                    "[web](https://example.com) and "
                                    "[anchor](#sec)\n")
    (tmp_path / "other.md").write_text("see [gone](nope/missing.md) and "
                                       "[abs](/etc/hosts)\n"
                                       "```\n[not a link](ignored.md)\n```\n")
    out = _run(str(tmp_path))
    assert out.returncode == 1
    assert "nope/missing.md" in out.stdout
    assert "absolute path" in out.stdout
    assert "ignored.md" not in out.stdout       # fenced block skipped
    assert "2 broken" in out.stdout


def test_checker_handles_anchored_file_links(tmp_path):
    (tmp_path / "a.md").write_text("[sec](b.md#some-section)\n")
    (tmp_path / "b.md").write_text("# some section\n")
    out = _run(str(tmp_path))
    assert out.returncode == 0, out.stdout
