"""Selective baselines (SNL / AutoReP) — behaviour on a tiny masked CNN."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import autorep, linearize, masks as M, snl
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.resnet import CNN, CNNConfig
from repro.training import train as train_lib, optimizer as opt_lib


@pytest.fixture(scope="module")
def setup():
    cfg = CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)), stem_channels=8)
    model = CNN(cfg)
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    _, loss_fn = train_lib.make_cnn_train_step(
        model, opt_lib.sgd(lr=1e-2))
    batches_np = data.batches("train", 32)
    batches = lambda i: {k: jnp.asarray(v) for k, v in batches_np(i).items()}
    return model, data, params, loss_fn, batches


def test_snl_reaches_budget_and_masks_binary(setup):
    model, data, params, loss_fn, batches = setup
    sites = model.mask_sites()
    alphas = {k: jnp.ones(s.shape) for k, s in sites.items()}
    total = sum(int(np.prod(s.shape)) for s in sites.values())
    target = total // 2

    def soft_loss(p, a, batch, soft):
        logits = model.forward(p, a, batch["images"], soft=soft)
        loss = train_lib.cross_entropy(logits, batch["labels"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                       .astype(jnp.float32)) * 100
        return loss, acc

    cfg = snl.SNLConfig(b_target=target, lam0=5e-4, kappa=1.5,
                        epochs=6, steps_per_epoch=5, lr=5e-2,
                        finetune_steps=10)
    res = snl.run_snl(params, alphas, soft_loss, batches, cfg)
    assert M.count(res.masks) == target               # exact after threshold
    for v in res.masks.values():
        assert set(np.unique(v)).issubset({0.0, 1.0})  # binary
    assert len(res.budget_per_epoch) >= 1
    # λ grows when sparsification stalls
    assert res.lam_per_epoch[-1] >= res.lam_per_epoch[0]
    # snapshots recorded for the IoU (Fig. 6) analysis
    assert len(res.snapshots) == len(res.budget_per_epoch)


def test_autorep_reaches_budget_with_poly_replacement(setup):
    model, data, params, loss_fn, batches = setup
    sites = {k: linearize.MaskSite(s.shape, "relu", "poly2")
             for k, s in model.mask_sites().items()}
    alphas = {k: jnp.full(s.shape, 0.5) for k, s in sites.items()}
    poly = linearize.init_poly(sites)
    assert poly  # poly2 coefficients exist
    total = sum(int(np.prod(s.shape)) for s in sites.values())
    target = total // 2

    def loss3(p, m, q, batch, soft):
        logits = model.forward(p, m, batch["images"], poly=q, soft=soft)
        loss = train_lib.cross_entropy(logits, batch["labels"])
        return loss, 0.0

    cfg = autorep.AutoRepConfig(b_target=target, epochs=4, steps_per_epoch=5,
                                lr=5e-2, finetune_steps=8)
    res = autorep.run_autorep(params, alphas, poly, loss3, batches, cfg)
    assert M.count(res.masks) == target
    # poly coefficients were trained (moved off identity init)
    moved = sum(float(jnp.sum(jnp.abs(res.poly[k][0]))) for k in res.poly)
    assert np.isfinite(moved)


def test_hysteresis_indicator():
    a = jnp.asarray([0.2, -0.2, 0.01, -0.01])
    m_prev = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    m = autorep._ste_indicator(a, m_prev, h=0.05)
    got = np.asarray(jax.lax.stop_gradient(m))
    # >h -> 1; <-h -> 0; in the hysteresis band -> keep previous
    np.testing.assert_array_equal(got, [1.0, 0.0, 0.0, 1.0])


def test_snl_finetune_improves_thresholded_model(setup):
    """The paper's motivation: hard thresholding costs accuracy; finetuning
    recovers (some of) it."""
    model, data, params, loss_fn, batches = setup
    sites = model.mask_sites()
    rng = np.random.default_rng(0)
    soft = {k: rng.random(s.shape).astype(np.float32)
            for k, s in sites.items()}
    total = sum(int(np.prod(s.shape)) for s in sites.values())
    hard = M.threshold(soft, total // 3)
    eval_batch = {k: jnp.asarray(v)
                  for k, v in data.train_eval_set(128).items()}

    def acc_of(p):
        logits = model.forward(p, M.as_device(hard), eval_batch["images"])
        return float(jnp.mean((jnp.argmax(logits, -1) ==
                               eval_batch["labels"]).astype(jnp.float32)))
    before = acc_of(params)
    p2 = snl.finetune(params, hard,
                      lambda p, m, b, soft: loss_fn(p, m, b, soft),
                      batches, steps=30, lr=3e-2)
    after = acc_of(p2)
    assert after >= before - 1e-9
