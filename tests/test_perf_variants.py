"""§Perf optimization variants must be numerically equivalent to baseline:
gather vs scatter MoE dispatch, remat grouping, chunked CE, seq-sharded acts.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import linearize, masks as M
from repro.models import moe
from repro.models.lm import LM
from repro.training import optimizer as opt_lib, train as train_lib


@pytest.mark.parametrize("E,k,S", [(4, 2, 32), (8, 3, 64)])
def test_gather_dispatch_equals_scatter(E, k, S):
    rng = np.random.default_rng(2)
    c_s = moe.MoECfg(d_model=16, n_experts=E, top_k=k, d_ff_expert=24,
                     capacity_factor=4.0, dispatch="scatter")
    c_g = dataclasses.replace(c_s, dispatch="gather")
    p = moe.moe_init(jax.random.PRNGKey(0), c_s, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, S, 16)).astype(np.float32))
    site = linearize.MaskSite((E, 24), "silu")
    mask = jnp.ones((E, 24))
    ys = moe.moe_ffn(p, c_s, x, mask, site)
    yg = moe.moe_ffn(p, c_g, x, mask, site)
    np.testing.assert_allclose(ys, yg, rtol=1e-4, atol=1e-4)
    gs = jax.grad(lambda p: jnp.sum(moe.moe_ffn(p, c_s, x, mask, site) ** 2)
                  )(p)
    gg = jax.grad(lambda p: jnp.sum(moe.moe_ffn(p, c_g, x, mask, site) ** 2)
                  )(p)
    for a, b in zip(jax.tree.leaves(gs), jax.tree.leaves(gg)):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_decode_capacity_is_one_slot_per_expert():
    c = moe.MoECfg(d_model=8, n_experts=64, top_k=6, d_ff_expert=8)
    assert moe._capacity(c, 1) == 1          # §Perf: 8x less dispatch traffic
    assert moe._capacity(c, 4096) % 8 == 0


def test_remat_group_equivalence():
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(get_config("stablelm_1p6b").reduced(),
                              n_layers=4)
    cfg2 = dataclasses.replace(cfg, remat_group=2)
    m1, m2 = LM(cfg), LM(cfg2)
    params = m1.init(jax.random.PRNGKey(0))
    masks = M.as_device(linearize.init_masks(m1.mask_sites()))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16), dtype=np.int32))
    l1, _ = m1.forward(params, masks, toks, remat=True)
    l2, _ = m2.forward(params, masks, toks, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)

    def loss(m):
        def f(p):
            lg, _ = m.forward(p, masks, toks, remat=True)
            return jnp.sum(lg.astype(jnp.float32) ** 2) * 1e-6
        return f
    g1 = jax.grad(loss(m1))(params)
    g2 = jax.grad(loss(m2))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


def test_loss_chunk_equals_whole_sequence():
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    opt = opt_lib.adamw(lr=1e-3)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32),
                                                dtype=np.int32)),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32),
                                                dtype=np.int32))}
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    state = train_lib.make_state(model, opt, jax.random.PRNGKey(2))
    s0 = train_lib.make_train_step(
        model, opt, train_lib.TrainStepCfg(remat=True, dp_axes=()))
    s1 = train_lib.make_train_step(
        model, opt, train_lib.TrainStepCfg(remat=True, dp_axes=(),
                                           loss_chunk=8))
    _, m0 = jax.jit(s0)(jax.tree.map(jnp.copy, state), batch, masks)
    _, m1 = jax.jit(s1)(jax.tree.map(jnp.copy, state), batch, masks)
    assert float(m0["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-5)
    assert float(m0["grad_norm"]) == pytest.approx(float(m1["grad_norm"]),
                                                   rel=1e-3)
