"""tools/bench_history_summary.py: trajectory print + schema validation.

The history file is append-only across tool versions, so the validator
must accept legacy (pre-calibration) lines while rejecting malformed ones
— otherwise the weekly CI job would force a rewrite of the log the cost
model calibrates from.
"""
import importlib.util
import json
import os

import pytest

_TOOL = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools", "bench_history_summary.py")
_spec = importlib.util.spec_from_file_location("bench_history_summary",
                                               _TOOL)
summary = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(summary)


def _entry(**kw):
    e = {"utc": "2026-08-08T00:00:00Z", "git": "abc1234",
         "config": {"chunk_size": 8},
         "cands_per_s": {"sequential": 200.0, "batched": 600.0,
                         "suffix": 900.0},
         "per_site_depth": {"deep": {
             "site": "g1b1.relu2", "prefix_fraction": 0.75,
             "mode": "suffix", "speedup_suffix_vs_batched": 4.0}},
         "speedup_suffix_vs_batched_deep": 4.0,
         "speedup_suffix_vs_batched_mean": 2.5}
    e.update(kw)
    return e


def _write(tmp_path, lines):
    p = tmp_path / "h.jsonl"
    p.write_text("".join(
        (line if isinstance(line, str) else json.dumps(line)) + "\n"
        for line in lines))
    return str(p)


def test_validate_entry_accepts_current_and_legacy():
    assert summary.validate_entry(_entry()) == []
    legacy = _entry()
    del legacy["per_site_depth"]          # PR-5-era line
    legacy["speedup_suffix_vs_batched"] = 4.0
    assert summary.validate_entry(legacy) == []


def test_validate_entry_rejects_bad_shapes():
    assert summary.validate_entry([1, 2]) == ["entry is not a JSON object"]
    bad = _entry(utc=12345)
    assert any("utc" in e for e in summary.validate_entry(bad))
    bad = _entry(cands_per_s={"seq": "fast"})
    assert any("cands_per_s" in e for e in summary.validate_entry(bad))
    bad = _entry()
    bad["per_site_depth"]["deep"]["mode"] = "turbo"
    assert any(".mode" in e for e in summary.validate_entry(bad))
    bad = _entry(speedup_suffix_vs_batched_mean="2.5")
    assert any("speedup_suffix_vs_batched_mean" in e
               for e in summary.validate_entry(bad))


def test_main_prints_trajectory_and_validates(tmp_path, capsys):
    path = _write(tmp_path, [_entry(), _entry(git="def5678")])
    assert summary.main([path, "--validate"]) == 0
    out = capsys.readouterr().out
    assert "abc1234" in out and "def5678" in out
    assert "4.00" in out and "2.50" in out
    assert "history schema: OK" in out

    # --last truncates the table, not the count line
    assert summary.main([path, "--last", "1"]) == 0
    out = capsys.readouterr().out
    assert "def5678" in out and "abc1234" not in out
    assert "2 run(s)" in out


def test_main_flags_malformed_lines(tmp_path, capsys):
    path = _write(tmp_path, [_entry(), "{truncated",
                             _entry(utc=None)])
    # without --validate: report but exit 0 (informational mode)
    assert summary.main([path]) == 0
    assert "INVALID" in capsys.readouterr().out
    assert summary.main([path, "--validate"]) == 1
    out = capsys.readouterr().out
    assert "not valid JSON" in out and "FAIL" in out


def test_main_legacy_lines_pass_validation(tmp_path, capsys):
    legacy = _entry(speedup_suffix_vs_batched=4.0)
    del legacy["per_site_depth"]
    del legacy["speedup_suffix_vs_batched_deep"]
    path = _write(tmp_path, [legacy, _entry()])
    assert summary.main([path, "--validate"]) == 0
    out = capsys.readouterr().out
    assert "1 legacy" in out
    # legacy deep speedup still shown via the old key spelling
    assert out.count("4.00") == 2


def test_main_missing_file(tmp_path, capsys):
    assert summary.main([str(tmp_path / "none.jsonl"), "--validate"]) == 1
    assert "cannot read" in capsys.readouterr().out


def test_main_empty_file(tmp_path, capsys):
    p = tmp_path / "e.jsonl"
    p.write_text("")
    assert summary.main([str(p), "--validate"]) == 0
    assert "empty history" in capsys.readouterr().out
