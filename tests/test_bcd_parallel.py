"""Candidate-evaluation engine: backend equivalence + chunk semantics.

The contract under test (core.engine / core.bcd._select_block): for the same
seed and config, every backend — sequential reference, vmapped batched,
mesh-sharded — selects bit-identical blocks, because (a) candidate sampling
burns exactly RT rng draws per outer step regardless of backend/chunking,
(b) candidates are scanned in sampling order with first-occurrence argmin
tie-breaking, and (c) the ADT early exit accepts the first candidate below
tolerance and never looks past its chunk.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcd, engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


@pytest.fixture(scope="module")
def setup():
    cfg = CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)), stem_channels=8)
    model = CNN(cfg)
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    step, _ = train_lib.make_cnn_train_step(
        model, opt_lib.sgd(lr=5e-2, momentum=0.9))
    opt = opt_lib.sgd(lr=5e-2, momentum=0.9)
    ostate = opt.init(params)
    batches = data.batches("train", 32)
    masks0 = linearize.init_masks(model.mask_sites())
    mdev = M.as_device(masks0)
    for i in range(40):
        params, ostate, _, _ = step(params, ostate, mdev,
                                    {k: jnp.asarray(v)
                                     for k, v in batches(i).items()})
    batch = data.train_eval_set(128)
    return model, params, batch, masks0


def _run(model, params, batch, masks0, evaluator, chunk_size=4):
    total = M.count(masks0)
    cfg = bcd.BCDConfig(b_target=total - 3 * 16, drc=16, rt=8, adt=0.5,
                        finetune_every_step=False, seed=3,
                        chunk_size=chunk_size)
    eval_acc = model.make_eval_acc(params, batch)
    return bcd.run_bcd(masks0, cfg, eval_acc, evaluator=evaluator)


def _assert_same_result(a, b):
    for k in a.masks:
        np.testing.assert_array_equal(a.masks[k], b.masks[k])
    assert len(a.history) == len(b.history)
    for ha, hb in zip(a.history, b.history):
        assert (ha.trials, ha.found_early) == (hb.trials, hb.found_early)
        assert ha.best_drop == pytest.approx(hb.best_drop, abs=1e-4)
        assert (ha.budget_before, ha.budget_after) == \
            (hb.budget_before, hb.budget_after)


def test_batched_matches_sequential_bitwise(setup):
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)))
    bat = _run(model, params, batch, masks0,
               engine.BatchedEvaluator(model.make_eval_fn(params, batch),
                                       pad_to=4))
    _assert_same_result(seq, bat)


def test_sharded_matches_sequential_bitwise(setup):
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)))
    shd = _run(model, params, batch, masks0,
               engine.ShardedEvaluator(model.make_eval_fn(params, batch),
                                       mesh_lib.make_candidate_mesh(),
                                       pad_to=4))
    _assert_same_result(seq, shd)


@pytest.mark.parametrize("prefetch", [0, 1, 2])
def test_pipelined_matches_sequential_bitwise(setup, prefetch):
    """Double-buffered staging is a pure latency optimization: for every
    prefetch depth — including 0, the strict materialize→evaluate
    degradation — the pipelined backend selects bit-identical blocks.
    chunk_size=3 against rt=8 forces ragged final chunks (3, 3, 2)."""
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)),
               chunk_size=3)
    pip = _run(model, params, batch, masks0,
               engine.PipelinedEvaluator(model.make_eval_fn(params, batch),
                                         pad_to=3, prefetch=prefetch),
               chunk_size=3)
    _assert_same_result(seq, pip)


def test_pipelined_on_mesh_matches_sequential_bitwise(setup):
    """Prefetch pipeline layered over sharded placement (1-D local mesh)."""
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)))
    pip = _run(model, params, batch, masks0,
               engine.PipelinedEvaluator(model.make_eval_fn(params, batch),
                                         pad_to=4, prefetch=2,
                                         mesh=mesh_lib.make_candidate_mesh()))
    _assert_same_result(seq, pip)


def test_chunk_size_does_not_change_selection(setup):
    """rng burns RT draws per step regardless of chunking, so chunk_size is
    a pure performance knob: selections are identical."""
    model, params, batch, masks0 = setup
    ev = engine.BatchedEvaluator(model.make_eval_fn(params, batch))
    a = _run(model, params, batch, masks0, ev, chunk_size=1)
    b = _run(model, params, batch, masks0, ev, chunk_size=8)
    for k in a.masks:
        np.testing.assert_array_equal(a.masks[k], b.masks[k])


def test_evaluator_accs_agree(setup):
    """Raw per-candidate accuracies: vmapped batch == sequential loop."""
    model, params, batch, masks0 = setup
    stacked = M.sample_removal_blocks(
        np.random.default_rng(0), masks0, 16, 6)
    seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    bat = engine.BatchedEvaluator(model.make_eval_fn(params, batch))
    np.testing.assert_allclose(bat.evaluate(stacked), seq.evaluate(stacked),
                               atol=1e-4)


def test_lm_eval_closures_batched_matches_sequential():
    """The LM path: masks ride the scanned stack as stacked xs; vmapping the
    candidate axis over the scan must agree with the sequential loop."""
    from repro.configs import get_config
    from repro.models.lm import LM
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": np.asarray(
        rng.integers(0, cfg.vocab, (2, 33), dtype=np.int32))}
    masks0 = linearize.init_masks(model.mask_sites())
    stacked = M.sample_removal_blocks(
        np.random.default_rng(1), masks0, 16, 5)
    seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    bat = engine.BatchedEvaluator(model.make_eval_fn(params, batch),
                                  pad_to=3)
    np.testing.assert_allclose(bat.evaluate(stacked), seq.evaluate(stacked),
                               atol=1e-4)


def test_context_swap_is_visible_without_retrace():
    """Params ride as evaluator *context* (a jit input): set_context must
    change results — a closure-captured param tree would silently go stale
    after finetuning."""
    eval_fn = lambda masks, scale: scale * jnp.sum(masks["s"])
    ev = engine.BatchedEvaluator(eval_fn, context=jnp.asarray(1.0))
    stacked = M.sample_removal_blocks(
        np.random.default_rng(0), {"s": np.ones((8,), np.float32)}, 2, 3)
    before = ev.evaluate(stacked)
    np.testing.assert_allclose(before, [6.0, 6.0, 6.0])
    ev.set_context(jnp.asarray(2.0))
    np.testing.assert_allclose(ev.evaluate(stacked), 2 * before)
    with pytest.raises(ValueError):
        engine.BatchedEvaluator(lambda m: jnp.sum(m["s"])).set_context(1.0)
    # the meshless pipelined backend must support the same swap (finetune
    # between outer steps while chunks are staged)
    pip = engine.PipelinedEvaluator(eval_fn, context=jnp.asarray(1.0),
                                    prefetch=2)
    np.testing.assert_allclose(pip.evaluate(stacked), before)
    pip.set_context(jnp.asarray(3.0))
    np.testing.assert_allclose(pip.evaluate(stacked), 3 * before)


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig

model = CNN(CNNConfig("tiny", 4, 8, ((4, 1, 1),), stem_channels=4))
data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=8,
                                       n_train=64, n_test=32))
params = model.init(jax.random.PRNGKey(0))
batch = data.train_eval_set(16)
masks0 = linearize.init_masks(model.mask_sites())
stacked = M.sample_removal_blocks(np.random.default_rng(0), masks0, 8, 6)
mesh = mesh_lib.make_candidate_mesh()
assert len(mesh.devices.reshape(-1)) == 4, mesh
seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
shd = engine.ShardedEvaluator(model.make_eval_fn(params, batch), mesh)
np.testing.assert_allclose(shd.evaluate(stacked), seq.evaluate(stacked),
                           atol=1e-4)
print("SHARDED_OK")
"""


def test_sharded_on_forced_multi_device_mesh():
    """Real candidate-axis sharding: 4 forced host devices, padding 6
    candidates up to 8 — results identical to the sequential reference."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


# ------------------------------------------------- chunked ADT semantics


class _ScriptedEvaluator:
    """Returns scripted accuracies in candidate order; records chunk sizes."""

    name = "scripted"

    def __init__(self, accs):
        self._accs = list(accs)
        self._next = 0
        self.chunks = []

    def evaluate(self, stacked):
        n = M.stacked_len(stacked)
        self.chunks.append(n)
        out = self._accs[self._next:self._next + n]
        self._next += n
        return np.asarray(out, dtype=np.float64)


def _tiny_masks(n=24):
    return {"s": np.ones((n,), np.float32)}


def test_early_exit_stops_at_first_chunk_with_hit():
    """Candidate drops: [1.0, 0.9 | 0.8, 0.1 | ...] with adt=0.3 — the
    second chunk contains the first sub-ADT drop; the third chunk must never
    be evaluated, and the winner is candidate index 3 (trials=4)."""
    masks = _tiny_masks()
    cfg = bcd.BCDConfig(b_target=M.count(masks) - 4, drc=4, rt=6, adt=0.3,
                        chunk_size=2, seed=0)
    acc_base = 90.0
    ev = _ScriptedEvaluator(acc_base - np.array([1.0, 0.9, 0.8, 0.1,
                                                 0.0, 0.0]))
    rng = np.random.default_rng(cfg.seed)
    cand, idx, drop, trials, found, _moves = bcd._select_block(
        masks, cfg, rng, ev, 4, acc_base)
    assert ev.chunks == [2, 2]                  # third chunk never evaluated
    assert (idx, trials, found) == (3, 4, True)
    assert drop == pytest.approx(0.1)
    # the returned tree is candidate 3 of the same sampling stream
    want = M.index_stacked(M.sample_removal_blocks(
        np.random.default_rng(cfg.seed), masks, 4, cfg.rt), 3)
    for k in want:
        np.testing.assert_array_equal(cand[k], want[k])


def test_no_early_exit_takes_first_occurrence_argmin():
    masks = _tiny_masks()
    cfg = bcd.BCDConfig(b_target=M.count(masks) - 4, drc=4, rt=6, adt=-1.0,
                        chunk_size=4, seed=0)
    drops = np.array([1.0, 0.7, 0.9, 0.7, 0.8, 0.7])   # tie at 0.7
    ev = _ScriptedEvaluator(90.0 - drops)
    _, idx, drop, trials, found, _moves = bcd._select_block(
        masks, cfg, np.random.default_rng(0), ev, 4, 90.0)
    assert ev.chunks == [4, 2]                  # all chunks evaluated
    assert (idx, trials, found) == (1, 6, False)
    assert drop == pytest.approx(0.7)


# ------------------------------------------------- prefetch-loop semantics


class _StagedScriptedEvaluator(_ScriptedEvaluator):
    """Scripted accuracies with the staging protocol; logs the event order
    so tests can pin down exactly when chunks are staged vs consumed."""

    name = "scripted-staged"

    def __init__(self, accs, prefetch):
        super().__init__(accs)
        self.prefetch_depth = prefetch
        self.events = []

    def stage(self, stacked):
        n = M.stacked_len(stacked)
        accs = super().evaluate(stacked)
        self.events.append(("stage", self._next - n))
        return engine.StagedChunk(n, accs)

    def evaluate_staged(self, staged):
        # accs were scripted at stage() time; this is the blocking read
        self.events.append(("consume",))
        return staged.accs

    def evaluate(self, stacked):
        self.events.append(("evaluate",))
        return super().evaluate(stacked)


def test_prefetch_loop_stages_ahead_and_consumes_in_order():
    """depth=1: chunk k+1 is staged before chunk k's results are consumed,
    and chunk k+2 is only committed after chunk k was checked."""
    ev = _StagedScriptedEvaluator(90.0 - np.arange(8, dtype=np.float64),
                                  prefetch=1)
    chunks = [M.sample_removal_blocks(np.random.default_rng(i),
                                      _tiny_masks(), 2, 2)
              for i in range(4)]
    out = []
    for accs in engine.evaluate_prefetched(ev, iter(chunks)):
        out.append(accs)
    kinds = [e[0] for e in ev.events]
    assert kinds == ["stage", "stage", "consume", "stage", "consume",
                     "stage", "consume", "consume"]
    np.testing.assert_array_equal(np.concatenate(out),
                                  90.0 - np.arange(8))


def test_prefetch_loop_early_exit_wastes_at_most_depth_chunks():
    """Closing the result generator (the ADT exit) drops staged chunks and
    never materializes chunks beyond the staging horizon."""
    ev = _StagedScriptedEvaluator(np.zeros(12), prefetch=2)
    pulled = []

    def produce():
        for i in range(6):
            pulled.append(i)
            yield M.sample_removal_blocks(np.random.default_rng(i),
                                          _tiny_masks(), 2, 2)

    results = engine.evaluate_prefetched(ev, produce())
    next(results)                 # consume chunk 0; chunks 0..2 are staged
    results.close()
    assert pulled == [0, 1, 2]    # chunks 3..5 never even materialized
    assert [e[0] for e in ev.events] == ["stage", "stage", "stage",
                                         "consume"]


def test_prefetch_depth_zero_degrades_to_strict_alternation():
    ev = _StagedScriptedEvaluator(np.zeros(4), prefetch=0)
    chunks = [M.sample_removal_blocks(np.random.default_rng(i),
                                      _tiny_masks(), 2, 2) for i in range(2)]
    list(engine.evaluate_prefetched(ev, iter(chunks)))
    assert [e[0] for e in ev.events] == ["evaluate", "evaluate"]


# -------------------------------------------- joint candidate×batch sharding


_JOINT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig

model = CNN(CNNConfig("tiny", 4, 8, ((4, 1, 1),), stem_channels=4))
data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=8,
                                       n_train=64, n_test=32))
params = model.init(jax.random.PRNGKey(0))
batch = data.train_eval_set(16)
masks0 = linearize.init_masks(model.mask_sites())
stacked = M.sample_removal_blocks(np.random.default_rng(0), masks0, 8, 6)

mesh = mesh_lib.make_cand_batch_mesh(cand=2, batch=2)
assert tuple(mesh.axis_names) == ("cand", "batch"), mesh
assert mesh.devices.size == 4, mesh
ctx = {"params": params, "batch": {k: np.asarray(v) for k, v in batch.items()}}
ev = engine.ShardedEvaluator(model.make_joint_eval_fn(), mesh, context=ctx,
                             context_specs=engine.context_batch_specs(ctx))
seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))

# per-call PartitionSpec selection: a 2-candidate chunk (< 4 devices) must
# take the cand-only layout (batch axis splits the forward); a full chunk
# takes the joint layout over both axes
n2, s2 = ev._chunk_sharding(2)
assert (n2, tuple(s2.spec)) == (2, (("cand",),)), (n2, s2.spec)
n8, s8 = ev._chunk_sharding(8)
assert (n8, tuple(s8.spec)) == (8, (("cand", "batch"),)), (n8, s8.spec)

small = M.slice_stacked(stacked, 0, 2)
np.testing.assert_allclose(ev.evaluate(small), seq.evaluate(small), atol=1e-4)
np.testing.assert_allclose(ev.evaluate(stacked), seq.evaluate(stacked),
                           atol=1e-4)

# pipelined over the same joint mesh, with a context swap (re-sharded)
pip = engine.PipelinedEvaluator(model.make_joint_eval_fn(), mesh=mesh,
                                prefetch=2, context=ctx,
                                context_specs=engine.context_batch_specs(ctx))
np.testing.assert_allclose(pip.evaluate(small), seq.evaluate(small),
                           atol=1e-4)
pip.set_context(ctx)
np.testing.assert_allclose(pip.evaluate(stacked), seq.evaluate(stacked),
                           atol=1e-4)
print("JOINT_OK")
"""


def test_joint_cand_batch_sharding_on_forced_multi_device_mesh():
    """4 forced host devices on a ("cand", "batch") = (2, 2) mesh: small
    chunks shard candidates over "cand" while the batch-sharded context
    splits each forward over "batch"; results match the sequential
    reference bit-for-bit at evaluation tolerance."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _JOINT_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "JOINT_OK" in out.stdout


# ------------------------------------------------- prefetch auto-tuning


def test_auto_prefetch_picks_depth_and_matches_sequential(setup):
    """prefetch='auto': the first chunks probe producer vs consumer rates
    in strict alternation, then the depth locks in for the rest of the run
    — and selection stays bit-identical to the sequential reference."""
    model, params, batch, masks0 = setup
    seq = _run(model, params, batch, masks0,
               engine.SequentialEvaluator(model.make_eval_acc(params, batch)),
               chunk_size=2)
    ev = engine.PipelinedEvaluator(model.make_eval_fn(params, batch),
                                   pad_to=2, prefetch="auto")
    assert ev.prefetch_depth == 0 and not ev.auto_tuner.done
    pip = _run(model, params, batch, masks0, ev, chunk_size=2)
    _assert_same_result(seq, pip)
    assert ev.auto_tuner.done
    assert 1 <= ev.prefetch_depth <= ev.auto_tuner.max_depth
    assert set(ev.auto_report) == {"producer_s", "consumer_s", "prefetch",
                                   "samples"}
    assert ev.auto_report["prefetch"] == ev.prefetch_depth


def test_auto_tuner_depth_formula():
    t = engine.PrefetchAutoTuner(n_probe=2, max_depth=4)
    t.add_sample(1.0, 1.0)              # warm-up (compile) — discarded
    t.add_sample(0.001, 0.0095)
    assert not t.done
    t.add_sample(0.001, 0.0105)
    assert t.done
    assert t.depth() == 4               # floor(10) capped at max_depth
    slow_prod = engine.PrefetchAutoTuner(n_probe=1, max_depth=4)
    slow_prod.add_sample(1.0, 1.0)
    slow_prod.add_sample(0.05, 0.001)   # producer-bound: still overlap once
    assert slow_prod.done and slow_prod.depth() == 1


def test_make_evaluator_accepts_auto_prefetch():
    ev = engine.make_evaluator("pipelined",
                               eval_fn=lambda m: jnp.sum(m["s"]),
                               prefetch="auto")
    assert ev.auto_tuner is not None and ev.prefetch_depth == 0
    with pytest.raises(ValueError):
        engine.make_evaluator("pipelined", eval_fn=lambda m: 0.0,
                              prefetch="bogus")
    # backends without a staging pipeline must reject 'auto' loudly rather
    # than silently running untuned
    for backend in ("sequential", "batched", "sharded"):
        with pytest.raises(ValueError, match="pipelined"):
            engine.make_evaluator(backend, eval_acc=lambda m: 0.0,
                                  eval_fn=lambda m: 0.0, prefetch="auto")


# ------------------------------------------------------------- hardening


def test_invalid_configs_raise_upfront():
    masks = _tiny_masks()
    eval_acc = lambda m: 90.0
    for bad in (dict(rt=0), dict(drc=0), dict(chunk_size=0),
                dict(b_target=-1), dict(adt=float("nan"))):
        kw = {"b_target": 8, "drc": 4, "rt": 4, **bad}
        cfg = bcd.BCDConfig(**kw)
        with pytest.raises(ValueError):
            bcd.run_bcd(masks, cfg, eval_acc)


def test_target_at_or_above_start_is_noop():
    masks = _tiny_masks()
    cfg = bcd.BCDConfig(b_target=M.count(masks), drc=4, rt=4)
    res = bcd.run_bcd(masks, cfg, lambda m: 90.0)
    assert res.history == [] and M.count(res.masks) == M.count(masks)


def test_make_evaluator_factory_validates():
    with pytest.raises(ValueError):
        engine.make_evaluator("sequential")
    with pytest.raises(ValueError):
        engine.make_evaluator("batched")
    with pytest.raises(ValueError):
        engine.make_evaluator("pipelined")
    with pytest.raises(ValueError):
        engine.make_evaluator("nope", eval_acc=lambda m: 0.0)
    with pytest.raises(ValueError):        # negative prefetch
        engine.make_evaluator("pipelined", eval_fn=lambda m: 0.0,
                              prefetch=-1)
    with pytest.raises(ValueError):        # context_specs needs a mesh
        engine.PipelinedEvaluator(lambda m: 0.0, context={"batch": {}},
                                  context_specs={"batch": {}})
    ev = engine.make_evaluator("pipelined",
                               eval_fn=lambda m: jnp.sum(m["s"]),
                               prefetch=2)
    assert ev.prefetch_depth == 2 and ev.name == "pipelined"
    ev = engine.make_evaluator("sequential", eval_acc=lambda m: 42.0)
    accs = ev.evaluate(M.sample_removal_blocks(
        np.random.default_rng(0), _tiny_masks(), 2, 3))
    np.testing.assert_array_equal(accs, [42.0, 42.0, 42.0])
