"""Fused suffix megakernels: gate folded into the adjacent matmul/conv.

Contract under test (ISSUE: "bitwise parity against the unfused pair"):

* **Kernel level** — Pallas interpret mode vs the pure-jnp oracles
  (``ref.masked_act_matmul_ref`` / ``ref.masked_act_conv3x3_ref``), swept
  over strides / activation kinds / ragged shapes (stride-2 SAME padding
  is asymmetric — the geometry the im2col taps must reproduce exactly).
* **Routing level** — the custom-vmap rule lowers a candidate-axis vmap
  to the stacked fused kernel, broadcasting the unbatched cached prefix.
* **Model level** — a full forward traced under
  ``linearize.fused_suffix_route(interpret=True)`` matches the plain
  forward: bitwise for matmul sites (LM FFN) and the non-wide CNN, and to
  float tolerance for the wide CNN (im2col accumulation order differs
  from ``lax.conv`` at larger channel counts).
* **Engine level** — a ``SuffixEvaluator`` whose dispatch is forced onto
  the fused interpret kernels still matches the sequential reference.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig, Block
from repro.core import engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.kernels import ops, ref
from repro.kernels.masked_act import (
    _same_pads, masked_act_conv3x3, masked_act_conv3x3_batched,
    masked_act_matmul_2d, masked_act_matmul_2d_batched)
from repro.models.lm import LM
from repro.models.resnet import CNN, CNNConfig

KINDS = ["relu", "gelu", "silu", "sqrelu"]


# ------------------------------------------------------------ same pads


def test_same_pads_matches_xla_geometry():
    # SAME output size is ceil(size/stride); stride-2 padding is asymmetric
    assert _same_pads(16, 1) == (16, 1, 1)
    assert _same_pads(16, 2) == (8, 0, 1)
    assert _same_pads(17, 2) == (9, 1, 1)
    assert _same_pads(5, 2) == (3, 1, 1)


# -------------------------------------------------------- matmul kernel


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("with_mul", [False, True])
def test_fused_matmul_matches_oracle(kind, with_mul):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(37, 48)).astype(np.float32))
    m = jnp.asarray((rng.random(48) > 0.5).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(48, 24)).astype(np.float32))
    mul = jnp.asarray(rng.normal(size=(37, 48)).astype(np.float32)) \
        if with_mul else None
    want = ref.masked_act_matmul_ref(x, m, w, mul, kind=kind)
    got = masked_act_matmul_2d(x, m, w, mul, kind=kind, block_rows=16,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_fused_matmul_batched_matches_per_candidate():
    rng = np.random.default_rng(1)
    n = 3
    x = jnp.asarray(rng.normal(size=(n, 10, 32)).astype(np.float32))
    ms = jnp.asarray((rng.random((n, 32)) > 0.5).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    mul = jnp.asarray(rng.normal(size=(n, 10, 32)).astype(np.float32))
    got = masked_act_matmul_2d_batched(x, ms, w, mul, kind="silu",
                                       block_rows=8, interpret=True)
    for i in range(n):
        one = masked_act_matmul_2d(x[i], ms[i], w, mul[i], kind="silu",
                                   block_rows=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


# ---------------------------------------------------------- conv kernel


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("hw", [(8, 8), (9, 7), (16, 16)])
def test_fused_conv3x3_matches_oracle(stride, hw):
    rng = np.random.default_rng(2)
    h, wd = hw
    x = jnp.asarray(rng.normal(size=(2, h, wd, 6)).astype(np.float32))
    m = jnp.asarray((rng.random((h, wd, 6)) > 0.5).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 6, 5)).astype(np.float32))
    want = ref.masked_act_conv3x3_ref(x, m, w, stride=stride)
    got = masked_act_conv3x3(x, m, w, stride=stride, interpret=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_conv3x3_batched_matches_per_candidate():
    rng = np.random.default_rng(3)
    n = 3
    x = jnp.asarray(rng.normal(size=(n, 2, 8, 8, 4)).astype(np.float32))
    ms = jnp.asarray((rng.random((n, 8, 8, 4)) > 0.5).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 6)).astype(np.float32))
    got = masked_act_conv3x3_batched(x, ms, w, stride=2, interpret=True)
    for i in range(n):
        one = masked_act_conv3x3(x[i], ms[i], w, stride=2, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


# ----------------------------------------------------------- routing


def test_routed_matmul_vmap_broadcasts_unbatched_prefix():
    """Candidate vmap over masks only (x = the shared cached prefix, mul =
    shared up-branch): the custom-vmap rule must broadcast and lower to the
    stacked kernel, matching the per-candidate fused op exactly."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    ms = jnp.asarray((rng.random((3, 32)) > 0.5).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    mul = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    got = jax.vmap(
        lambda m: ops.masked_act_matmul_routed(x, m, w, mul, kind="gelu",
                                               interpret=True),
        in_axes=0)(ms)
    for i in range(3):
        one = ops.masked_act_matmul_routed(x, ms[i], w, mul, kind="gelu",
                                           interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


def test_routed_conv_vmap_broadcasts_unbatched_prefix():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 4)).astype(np.float32))
    ms = jnp.asarray((rng.random((3, 8, 8, 4)) > 0.5).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)).astype(np.float32))
    got = jax.vmap(
        lambda m: ops.masked_act_conv3x3_routed(x, m, w, stride=2,
                                                interpret=True),
        in_axes=0)(ms)
    for i in range(3):
        one = ops.masked_act_conv3x3_routed(x, ms[i], w, stride=2,
                                            interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


def test_routed_rejects_batched_weights():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    m = jnp.ones((8,), jnp.float32)
    ws = jnp.asarray(rng.normal(size=(2, 8, 4)).astype(np.float32))
    with pytest.raises(NotImplementedError, match="candidate-shared"):
        jax.vmap(lambda w: ops.masked_act_matmul_routed(
            x, m, w, interpret=True))(ws)


def test_fused_route_hint_is_scoped():
    assert linearize.fused_route_mode() is None
    with linearize.fused_suffix_route(interpret=True):
        assert linearize.fused_route_mode() == "interpret"
        with linearize.fused_suffix_route():
            assert linearize.fused_route_mode() == "device"
        assert linearize.fused_route_mode() == "interpret"
    assert linearize.fused_route_mode() is None


# --------------------------------------------------------- model level


def _masked(model, n_zero, seed=0):
    masks = linearize.init_masks(model.mask_sites())
    return M.sample_removal_block(np.random.default_rng(seed), masks,
                                  n_zero)


def test_cnn_forward_fused_route_bitwise():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    params = model.init(jax.random.PRNGKey(0))
    md = M.as_device(_masked(model, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    plain = np.asarray(jax.jit(model.forward)(params, md, x))
    with linearize.fused_suffix_route(interpret=True):
        fused = np.asarray(jax.jit(model.forward)(params, md, x))
    np.testing.assert_array_equal(fused, plain)


def test_wide_cnn_forward_fused_route_close():
    # wide blocks fuse relu2 -> conv2 only (relu1 feeds conv1 AND the
    # projection shortcut); im2col accumulation order differs from
    # lax.conv, so parity is float-level, not bitwise
    model = CNN(CNNConfig("wrn-mini", 4, 16,
                          ((8, 1, 1), (16, 1, 2), (16, 1, 2)),
                          stem_channels=8, wide=True))
    params = model.init(jax.random.PRNGKey(0))
    md = M.as_device(_masked(model, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    plain = np.asarray(jax.jit(model.forward)(params, md, x))
    with linearize.fused_suffix_route(interpret=True):
        fused = np.asarray(jax.jit(model.forward)(params, md, x))
    np.testing.assert_allclose(fused, plain, rtol=1e-5, atol=1e-5)


def _tiny_lm():
    cfg = ArchConfig(
        name="tiny-fused", family="dense", n_layers=6, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=48, vocab=64, head_dim=16,
        pattern=(Block("dense"), Block("dense")),
        head_blocks=(Block("dense"),), dtype="float32")
    return LM(cfg)


def test_lm_forward_fused_route_bitwise():
    model = _tiny_lm()
    params = model.init(jax.random.PRNGKey(0))
    md = M.as_device(_masked(model, 16))
    rng = np.random.default_rng(0)
    tokens = np.asarray(rng.integers(0, model.cfg.vocab, (2, 9),
                                     dtype=np.int32))
    fwd = jax.jit(lambda p, m, t: model.forward(p, m, t)[0])
    plain = np.asarray(fwd(params, md, tokens))
    with linearize.fused_suffix_route(interpret=True):
        fused = np.asarray(
            jax.jit(lambda p, m, t: model.forward(p, m, t)[0])(
                params, md, tokens))
    np.testing.assert_array_equal(fused, plain)


def test_cnn_split_forward_fused_route_per_site():
    """prefix∘suffix == forward with fusion armed — the composition the
    suffix engine actually traces."""
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    params = model.init(jax.random.PRNGKey(0))
    md = M.as_device(_masked(model, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    plain = np.asarray(jax.jit(model.forward)(params, md, x))
    with linearize.fused_suffix_route(interpret=True):
        for site in model.site_order():
            def composed(p, m, x, site=site):
                return model.forward_suffix(
                    p, m, model.forward_prefix(p, m, x, site), site)
            out = np.asarray(jax.jit(composed)(params, md, x))
            np.testing.assert_array_equal(out, plain, err_msg=site)


# -------------------------------------------------------- engine level


def test_suffix_evaluator_fused_dispatch_matches_sequential(monkeypatch):
    """Force the fused dispatch on (as on TPU) — the routed ops then run
    the interpret-mode Pallas megakernels inside the suffix vmap; the
    evaluator must still match the sequential reference, and flipping
    ``fused_kernels=False`` must too (fresh jit caches per instance)."""
    monkeypatch.setattr(ops, "fused_dispatch_enabled", lambda: True)
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=64, n_test=32))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(32)
    masks0 = linearize.init_masks(model.mask_sites())
    deep = model.site_order()[-1]
    idx = M.sample_removal_indices_within(
        np.random.default_rng(0), masks0, 16, 4, [deep])
    stacked = M.materialize_candidates(masks0, idx)
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    want = seq.evaluate(stacked)
    for fused in (True, False):
        ev = engine.SuffixEvaluator(model.make_suffix_eval_fns(),
                                    context=ctx, pad_to=4,
                                    fused_kernels=fused)
        ev.begin_step(masks0)
        accs = ev.evaluate(engine.SitedChunk(deep, stacked))
        np.testing.assert_allclose(accs, want, atol=1e-4,
                                   err_msg=f"fused_kernels={fused}")
