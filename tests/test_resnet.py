"""Paper backbones: ReLU counts (Table 1 convention) + training sanity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


def test_relu_counts_match_paper_table1_convention():
    """Paper Table 1: ResNet18@32 = 570K, WRN22-8@32 = 1359K.  Our counting
    convention (every post-BN ReLU site) lands within 2.5% — the deltas are
    documented in EXPERIMENTS.md."""
    r18 = CNN(CNNConfig.resnet18(10, 32)).relu_count()
    wrn = CNN(CNNConfig.wrn22_8(10, 32)).relu_count()
    assert abs(r18 - 570_000) / 570_000 < 0.025, r18
    assert abs(wrn - 1_359_000) / 1_359_000 < 0.025, wrn
    r18_64 = CNN(CNNConfig.resnet18(200, 64)).relu_count()
    assert r18_64 == 4 * r18                      # conv scaling, 64x64


def test_mask_sites_cover_every_relu():
    m = CNN(CNNConfig.resnet18(10, 32))
    sites = m.mask_sites()
    assert sum(int(np.prod(s.shape)) for s in sites.values()) \
        == m.relu_count()
    # per-pixel masks: site shapes are (H, W, C)
    assert all(len(s.shape) == 3 for s in sites.values())


@pytest.mark.parametrize("make", [CNNConfig.resnet18, CNNConfig.wrn22_8])
def test_cnn_trains_on_synthetic(make):
    cfg = make(4, 16)   # tiny images for speed; structure identical
    model = CNN(cfg)
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=128, n_test=32))
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_lib.sgd(lr=2e-2, momentum=0.9)
    step, _ = train_lib.make_cnn_train_step(model, opt)
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    batches = data.batches("train", 16)
    ostate = opt.init(params)
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in batches(i).items()}
        params, ostate, loss, acc = step(params, ostate, masks, b)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])   # learning happens


def test_masked_forward_differs_but_stays_finite():
    cfg = CNNConfig.resnet18(10, 16)
    model = CNN(cfg)
    params = model.init(jax.random.PRNGKey(1))
    masks0 = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(0)
    half = M.threshold({k: rng.random(v.shape).astype(np.float32)
                        for k, v in masks0.items()},
                       M.count(masks0) // 2)
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 3)), jnp.float32)
    l_full = model.forward(params, M.as_device(masks0), x)
    l_half = model.forward(params, M.as_device(half), x)
    assert bool(jnp.isfinite(l_half).all())
    assert not np.allclose(np.asarray(l_full), np.asarray(l_half))
