"""End-to-end system behaviour: the paper's full pipeline, miniaturized.

SNL (B_ref) -> BCD (B_target) on a masked CNN over synthetic CIFAR, asserting
the paper's qualitative claims: exact sparsity at every stage, BCD >= SNL at
the same budget (train-set acc), PI latency drops proportionally.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcd, linearize, masks as M, pi_cost, snl, analysis
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


@pytest.fixture(scope="module")
def pipeline():
    cfg = CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)), stem_channels=8)
    model = CNN(cfg)
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_lib.sgd(lr=5e-2, momentum=0.9)
    step, loss_fn = train_lib.make_cnn_train_step(model, opt)
    batches_np = data.batches("train", 32)
    batches = lambda i: {k: jnp.asarray(v) for k, v in batches_np(i).items()}
    masks0 = linearize.init_masks(model.mask_sites())
    ostate = opt.init(params)
    mdev = M.as_device(masks0)
    for i in range(80):
        params, ostate, loss, acc = step(params, ostate, mdev, batches(i))
    return model, data, params, loss_fn, batches, masks0


def _acc(model, params, masks, batch):
    logits = model.forward(params, M.as_device(masks), batch["images"])
    return float(jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                          .astype(jnp.float32)) * 100)


def test_full_pipeline_snl_then_bcd(pipeline):
    model, data, params, loss_fn, batches, masks0 = pipeline
    total = M.count(masks0)
    b_ref, b_target = int(total * 0.6), int(total * 0.4)
    eval_b = {k: jnp.asarray(v) for k, v in data.train_eval_set(128).items()}

    def soft_loss(p, a, batch, soft):
        logits = model.forward(p, a, batch["images"], soft=soft)
        return train_lib.cross_entropy(logits, batch["labels"]), 0.0

    # ---- SNL to B_ref (the paper's starting point)
    alphas = {k: jnp.ones(v.shape) for k, v in masks0.items()}
    res_ref = snl.run_snl(params, alphas, soft_loss, batches,
                          snl.SNLConfig(b_target=b_ref, lam0=5e-4, kappa=1.5,
                                        epochs=5, steps_per_epoch=5, lr=3e-2,
                                        finetune_steps=15))
    assert M.count(res_ref.masks) == b_ref

    # ---- SNL straight to B_target (the baseline comparison)
    res_tgt = snl.run_snl(params, alphas, soft_loss, batches,
                          snl.SNLConfig(b_target=b_target, lam0=5e-4,
                                        kappa=1.5, epochs=5,
                                        steps_per_epoch=5, lr=3e-2,
                                        finetune_steps=15))
    acc_snl = _acc(model, res_tgt.params, res_tgt.masks, eval_b)

    # ---- BCD from the SNL B_ref checkpoint down to B_target (ours)
    state = {"params": res_ref.params}

    def eval_acc(m):
        return _acc(model, state["params"], m, eval_b)

    def ft(m):
        state["params"] = snl.finetune(
            state["params"], m, soft_loss, batches, steps=12, lr=1e-2)

    res_bcd = bcd.run_bcd(
        res_ref.masks,
        bcd.BCDConfig(b_target=b_target, drc=max(
            1, (b_ref - b_target) // 4), rt=5, adt=0.3),
        eval_acc, finetune=ft, keep_snapshots=True)
    acc_bcd = eval_acc(res_bcd.masks)

    assert M.count(res_bcd.masks) == b_target
    assert M.is_subset(res_bcd.masks, res_ref.masks)
    # the paper's headline claim, miniaturized (train-set acc, synthetic):
    assert acc_bcd >= acc_snl - 5.0, (acc_bcd, acc_snl)

    # golden-set analysis machinery (Fig. 6 analog) runs on the snapshots
    snaps = [res_ref.masks] + res_bcd.mask_snapshots
    ious = analysis.consecutive_iou(snaps)
    assert all(v == 1.0 for v in ious)       # BCD is eliminate-only
    assert analysis.golden_set_fraction(snaps) == 1.0


def test_pi_latency_scales_with_budget(pipeline):
    model, *_ = pipeline
    total = model.relu_count()
    l_ref, l_tgt, speedup = pi_cost.saving(total, total // 4,
                                           len(model.mask_sites()))
    assert l_tgt < l_ref
    assert speedup > 1.0
    c = pi_cost.cost(total, len(model.mask_sites()))
    assert c.online_bytes == total * pi_cost.PIProtocol().online_bytes_per_relu
