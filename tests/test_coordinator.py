"""Multi-host coordination (launch.coordinator + coordinated runner).

The contract under test: N ranks run the same deterministic BCD loop against
ONE checkpoint directory; only rank 0 (the writer) commits checkpoints,
reader ranks block on each commit, and every restore is rank-agreed (barrier
+ broadcast of the resume step and its manifest fingerprint).  SIGKILL any
rank — reader or writer — relaunch all ranks with a fresh session, and the
job resumes from a single checkpoint lineage, replaying bit-identically
against an uninterrupted run.
"""
import dataclasses
import json
import os
import subprocess
import sys
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bcd, masks as M, runner
from repro.launch import coordinator as coord_lib
from repro.training import checkpoint


# ------------------------------------------------------------ primitives


def test_local_coordinator_is_trivial():
    c = coord_lib.LocalCoordinator()
    assert (c.rank, c.world_size, c.is_writer) == (0, 1, True)
    c.barrier("anything")
    assert c.broadcast("x", {"a": 1}) == {"a": 1}
    assert c.describe()["backend"] == "local"
    c.close()


def test_file_coordinator_barrier_and_broadcast_across_threads(tmp_path):
    """Two 'ranks' (threads, same syscalls as processes) rendezvous: the
    barrier releases both, the broadcast hands rank 1 the writer's payload,
    and repeated tags stay distinct via the per-tag use counter."""
    root = str(tmp_path / "coord")
    got = {}

    def rank_main(r):
        c = coord_lib.FileCoordinator(root, r, 2, session="s0",
                                      poll_s=0.005, timeout_s=30)
        c.barrier("start")
        for round_i in range(3):                # tag reuse
            payload = c.broadcast(
                "step", {"round": round_i} if c.is_writer else None)
            got.setdefault(r, []).append(payload)
            c.barrier("round")

    ts = [threading.Thread(target=rank_main, args=(r,)) for r in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert got[0] == got[1] == [{"round": 0}, {"round": 1}, {"round": 2}]


def test_file_coordinator_barrier_timeout_names_missing_rank(tmp_path):
    c = coord_lib.FileCoordinator(str(tmp_path), 0, 2, timeout_s=0.2,
                                  poll_s=0.01)
    with pytest.raises(coord_lib.CoordinatorError, match=r"rank\(s\) \[1\]"):
        c.barrier("lonely")


def test_file_coordinator_broadcast_timeout_on_dead_writer(tmp_path):
    c = coord_lib.FileCoordinator(str(tmp_path), 1, 2, timeout_s=0.2,
                                  poll_s=0.01)
    with pytest.raises(coord_lib.CoordinatorError, match="writer"):
        c.broadcast("nothing")


def test_sessions_are_isolated(tmp_path):
    """Leftover rendezvous files from a crashed attempt must not satisfy a
    relaunch: the same barrier in a fresh session blocks again."""
    root = str(tmp_path)
    a = coord_lib.FileCoordinator(root, 0, 2, session="a", timeout_s=0.2)
    with pytest.raises(coord_lib.CoordinatorError):
        a.barrier("x")                           # rank 0's file now exists
    b0 = coord_lib.FileCoordinator(root, 0, 2, session="b", timeout_s=0.2)
    with pytest.raises(coord_lib.CoordinatorError):
        b0.barrier("x")                          # session a's file is inert


def test_from_env(tmp_path, monkeypatch):
    monkeypatch.delenv(coord_lib.ENV_WORLD, raising=False)
    assert isinstance(coord_lib.from_env(), coord_lib.LocalCoordinator)
    monkeypatch.setenv(coord_lib.ENV_WORLD, "1")
    assert isinstance(coord_lib.from_env(), coord_lib.LocalCoordinator)

    monkeypatch.setenv(coord_lib.ENV_WORLD, "2")
    monkeypatch.delenv(coord_lib.ENV_RANK, raising=False)
    monkeypatch.delenv(coord_lib.ENV_DIR, raising=False)
    monkeypatch.delenv(coord_lib.ENV_SESSION, raising=False)
    with pytest.raises(coord_lib.CoordinatorError, match=coord_lib.ENV_RANK):
        coord_lib.from_env()
    monkeypatch.setenv(coord_lib.ENV_RANK, "1")
    with pytest.raises(coord_lib.CoordinatorError, match=coord_lib.ENV_DIR):
        coord_lib.from_env()
    # a session is mandatory: defaulting one would let a relaunch
    # rendezvous against a dead attempt's leftover files
    with pytest.raises(coord_lib.CoordinatorError,
                       match=coord_lib.ENV_SESSION):
        coord_lib.from_env(default_root=str(tmp_path))
    monkeypatch.setenv(coord_lib.ENV_SESSION, "s7")
    c = coord_lib.from_env(default_root=str(tmp_path))
    assert isinstance(c, coord_lib.FileCoordinator)
    assert (c.rank, c.world_size, c.is_writer) == (1, 2, False)
    assert c.session == "s7"
    monkeypatch.setenv(coord_lib.ENV_DIR, str(tmp_path / "explicit"))
    c = coord_lib.from_env()
    assert c.session == "s7"


def test_rank_bounds_rejected(tmp_path):
    with pytest.raises(coord_lib.CoordinatorError):
        coord_lib.FileCoordinator(str(tmp_path), 2, 2)


# ------------------------------------------------ writer-exclusive commits


def test_checkpoint_save_refuses_non_writer(tmp_path):
    reader = coord_lib.FileCoordinator(str(tmp_path / "c"), 1, 2)
    with pytest.raises(checkpoint.CheckpointError, match="writer"):
        checkpoint.save({"x": np.ones(3)}, str(tmp_path / "ck"), 0,
                        coordinator=reader)
    assert not os.path.exists(str(tmp_path / "ck"))   # refused before I/O


def test_wait_for_step(tmp_path):
    d = str(tmp_path / "ck")
    with pytest.raises(checkpoint.CheckpointError, match="timed out"):
        checkpoint.wait_for_step(d, 1, timeout_s=0.2, poll_s=0.01)
    checkpoint.save({"x": np.ones(3)}, d, 2)
    assert checkpoint.wait_for_step(d, 1, timeout_s=0.2) == 2


def test_manifest_fingerprint_tracks_content(tmp_path):
    d = str(tmp_path / "ck")
    checkpoint.save({"x": np.ones(3)}, d, 0, meta={"tag": "a"})
    fp_a = checkpoint.manifest_fingerprint(d, 0)
    assert fp_a == checkpoint.manifest_fingerprint(d, 0)   # stable
    checkpoint.save({"x": np.zeros(3)}, d, 0, meta={"tag": "a"})
    assert checkpoint.manifest_fingerprint(d, 0) != fp_a   # leaves changed


# --------------------------------------------- coordinated restore checks


def _toy_masks(n=48):
    return {"a": np.ones((n // 2,), np.float32),
            "b": np.ones((n // 2,), np.float32)}


def _toy_eval_acc(m):
    md = M.as_device(m)
    wa = jnp.arange(md["a"].shape[-1], dtype=jnp.float32)
    return float(95.0 - 0.02 * (jnp.sum((1 - md["a"]) * wa) +
                                jnp.sum((1 - md["b"]) * wa[::-1])))


def _toy_cfg(masks, steps=4):
    return bcd.BCDConfig(b_target=M.count(masks) - 4 * steps, drc=4, rt=6,
                         adt=-1.0, chunk_size=2, seed=0)


class _StubCoordinator:
    """Writer rank of a fake 2-rank world whose broadcast replays a
    scripted resume point (as if agreed with a peer)."""

    def __init__(self, point):
        self.rank, self.world_size, self._point = 0, 2, point

    @property
    def is_writer(self):
        return True

    def barrier(self, tag, timeout_s=None):
        pass

    def broadcast(self, tag, payload=None):
        return self._point

    def describe(self):
        return {"backend": "stub", "rank": 0, "world_size": 2}


def test_restore_verifies_broadcast_fingerprint(tmp_path):
    """A reader rank whose directory disagrees with the writer's broadcast
    fingerprint must refuse to resume (divergent lineages)."""
    masks = _toy_masks()
    cfg = _toy_cfg(masks)
    d = str(tmp_path / "ck")
    part = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d, max_steps=2),
                            _toy_eval_acc)
    part.run(masks)
    step = checkpoint.latest_valid_step(d)
    good_fp = checkpoint.manifest_fingerprint(d, step)

    ok = runner.BCDRunner(
        cfg, runner.RunnerConfig(ckpt_dir=d), _toy_eval_acc,
        coordinator=_StubCoordinator({"step": step, "fingerprint": good_fp}))
    res = ok.run(masks)
    assert ok.resumed_from == step and M.count(res.masks) == cfg.b_target

    bad = runner.BCDRunner(
        cfg, runner.RunnerConfig(ckpt_dir=d), _toy_eval_acc,
        coordinator=_StubCoordinator({"step": step, "fingerprint": "0" * 64}))
    with pytest.raises(runner.CheckpointError, match="divergent"):
        bad.run(masks)


# ------------------------------------- the drill (acceptance criterion)
#
# 2 ranks over a FileCoordinator against one checkpoint directory.  Three
# launches of the same job: (a) SIGKILL the non-writer mid-run, (b) relaunch
# under a fresh session and SIGKILL the WRITER mid-run (the reader times out
# on the missing commit and exits too), (c) relaunch again and run to
# completion.  The final masks/logs must be bit-identical to an
# uninterrupted single-process run, and every checkpoint ever committed must
# come from rank 0 (single lineage).

_DRILL = r"""
import dataclasses, json, sys
import numpy as np
import jax.numpy as jnp
from repro.core import bcd, masks as M, runner
from repro.launch import coordinator as coord_lib

ckpt_dir, coord_dir, session, rank, world = sys.argv[1:6]
coord = coord_lib.FileCoordinator(coord_dir, int(rank), int(world),
                                  session=session, poll_s=0.01, timeout_s=60)
masks = {"a": np.ones((24,), np.float32), "b": np.ones((24,), np.float32)}
wa = jnp.arange(24, dtype=jnp.float32)
eval_fn = lambda m: 95.0 - 0.02 * (jnp.sum((1 - m["a"]) * wa) +
                                   jnp.sum((1 - m["b"]) * wa[::-1]))
eval_acc = lambda m: float(eval_fn(M.as_device(m)))
cfg = bcd.BCDConfig(b_target=28, drc=4, rt=6, adt=-1.0, chunk_size=2, seed=0)
run = runner.BCDRunner(
    cfg, runner.RunnerConfig(ckpt_dir=ckpt_dir, wait_timeout_s=8.0),
    eval_acc, coordinator=coord)
res = run.run(masks)
hist = []
for h in res.history:
    d = dataclasses.asdict(h); d.pop("wall_s"); hist.append(d)
print(f"R{coord.rank}_FP=" + M.fingerprint(res.masks))
print(f"R{coord.rank}_HIST=" + json.dumps(hist))
"""


def _launch_ranks(ckpt_dir, coord_dir, session, world=2, kill_rank=None,
                  kill_after=2):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    procs = []
    for r in range(world):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.pop(runner.KILL_ENV, None)
        if kill_rank is not None and r == kill_rank:
            env[runner.KILL_ENV] = str(kill_after)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", _DRILL, ckpt_dir, coord_dir, session,
             str(r), str(world)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True))
    done = [p.communicate(timeout=600) for p in procs]
    return [(p.returncode, out, err) for p, (out, err) in zip(procs, done)]


def _parse(out):
    got = {}
    for ln in out.splitlines():
        if "_FP=" in ln or "_HIST=" in ln:
            k, v = ln.split("=", 1)
            got[k.split("_", 1)[1]] = json.loads(v) if "HIST" in k else v
    return got


def _assert_single_lineage(ckpt_dir):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert steps, "no checkpoints committed"
    for s in steps:
        meta = checkpoint.read_manifest(ckpt_dir, s).get("meta", {})
        assert meta.get("writer", {}).get("rank") == 0, \
            (s, meta.get("writer"))


_SWEEP_DRILL = r"""
import json, sys
import numpy as np
import jax.numpy as jnp
from repro.core import bcd, masks as M
from repro.launch import coordinator as coord_lib
from repro.launch import sweep as sweep_lib

out_dir, coord_dir, session, rank, world = sys.argv[1:6]
coord = coord_lib.FileCoordinator(coord_dir, int(rank), int(world),
                                  session=session, poll_s=0.01, timeout_s=60)
masks = {"a": np.ones((24,), np.float32), "b": np.ones((24,), np.float32)}
wa = jnp.arange(24, dtype=jnp.float32)
eval_fn = lambda m: 95.0 - 0.02 * (jnp.sum((1 - m["a"]) * wa) +
                                   jnp.sum((1 - m["b"]) * wa[::-1]))
eval_acc = lambda m: float(eval_fn(M.as_device(m)))
holder = {"params": {"w": np.arange(4, dtype=np.float32)}}
pio = (lambda: holder["params"], lambda p: holder.__setitem__("params", p))
cfg = sweep_lib.SweepConfig(budgets=[36, 28], out_dir=out_dir, name="mh",
                            wait_timeout_s=8.0)
mk = lambda b: bcd.BCDConfig(b_target=b, drc=4, rt=6, adt=-1.0,
                             chunk_size=2, seed=0)
init = {"kind": "snl", "masks": masks, "params": holder["params"]}
res = sweep_lib.run_sweep(cfg, mk, eval_acc, init=init, params_io=pio,
                          stage_eval=lambda m, p: eval_acc(m),
                          coordinator=coord)
print(f"R{coord.rank}_SWEEPFPS="
      + json.dumps([s["mask_fingerprint"] for s in res["stages"]]))
"""


def _launch_sweep_ranks(out_dir, coord_dir, session, world=2,
                        kill_rank=None, kill_after=4):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    procs = []
    for r in range(world):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.pop(runner.KILL_ENV, None)
        if kill_rank is not None and r == kill_rank:
            env[runner.KILL_ENV] = str(kill_after)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", _SWEEP_DRILL, out_dir, coord_dir,
             session, str(r), str(world)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True))
    done = [p.communicate(timeout=600) for p in procs]
    return [(p.returncode, out, err) for p, (out, err) in zip(procs, done)]


def test_multihost_sweep_drill(tmp_path):
    """The full multi-rank sweep rendezvous: 2 ranks descend a 2-stage
    schedule, the WRITER is SIGKILLed mid-stage-1, and the relaunch (fresh
    session) broadcast-skips the completed stage 0, resumes stage 1 from
    rank 0's lineage, and both ranks finish with stage fingerprints
    identical to a single-process sweep of the same schedule."""
    # single-process reference
    from repro.launch import sweep as sweep_lib
    masks = {"a": np.ones((24,), np.float32),
             "b": np.ones((24,), np.float32)}
    holder = {"params": {"w": np.arange(4, dtype=np.float32)}}
    pio = (lambda: holder["params"],
           lambda p: holder.__setitem__("params", p))
    ref = sweep_lib.run_sweep(
        sweep_lib.SweepConfig(budgets=[36, 28],
                              out_dir=str(tmp_path / "ref"), name="mh"),
        lambda b: bcd.BCDConfig(b_target=b, drc=4, rt=6, adt=-1.0,
                                chunk_size=2, seed=0),
        _toy_eval_acc, init={"kind": "snl", "masks": masks,
                             "params": holder["params"]},
        params_io=pio, stage_eval=lambda m, p: _toy_eval_acc(m))
    ref_fps = [s["mask_fingerprint"] for s in ref["stages"]]

    out = str(tmp_path / "mh")
    coord = str(tmp_path / "coord")
    # stage 0 is 3 accepted blocks; kill the writer after 4 → mid-stage-1.
    res = _launch_sweep_ranks(out, coord, "a1", kill_rank=0)
    assert res[0][0] == -9, res[0][2][-2000:]
    assert res[1][0] not in (0, -9), res[1][2][-2000:]

    res = _launch_sweep_ranks(out, coord, "a2")
    assert all(rc == 0 for rc, _, _ in res), \
        [e[-1500:] for _, _, e in res]
    for rc, stdout, _ in res:
        fps = json.loads(stdout.split("_SWEEPFPS=", 1)[1])
        assert fps == ref_fps
    art = json.load(open(os.path.join(out, "SWEEP_mh.json")))
    assert art["complete"]
    assert [s["mask_fingerprint"] for s in art["stages"]] == ref_fps
    assert all("test_acc" in s for s in art["stages"])


@pytest.fixture(scope="module")
def drill_reference():
    """The uninterrupted single-process reference run (masks + logs)."""
    masks = _toy_masks(48)
    ref = bcd.run_bcd(masks, bcd.BCDConfig(b_target=28, drc=4, rt=6,
                                           adt=-1.0, chunk_size=2, seed=0),
                      _toy_eval_acc)
    hist = []
    for h in ref.history:
        d = dataclasses.asdict(h)
        d.pop("wall_s")
        hist.append(d)
    return M.fingerprint(ref.masks), hist


def test_multihost_drill_sigkill_non_writer(tmp_path, drill_reference):
    """SIGKILL a reader rank mid-run: the writer owns every commit and
    never waits on readers, so it finishes; a full relaunch (fresh session)
    restores the completed lineage on both ranks, fingerprint-verified and
    bit-identical to the uninterrupted reference."""
    ref_fp, ref_hist = drill_reference
    ckpt = str(tmp_path / "ckpt")
    coord = str(tmp_path / "coord")

    res = _launch_ranks(ckpt, coord, "attempt1", kill_rank=1)
    assert res[1][0] == -9, res[1][2][-2000:]          # reader SIGKILLed
    assert res[0][0] == 0, res[0][2][-2000:]           # writer completed
    assert _parse(res[0][1])["FP"] == ref_fp
    _assert_single_lineage(ckpt)

    res = _launch_ranks(ckpt, coord, "attempt2")
    assert all(rc == 0 for rc, _, _ in res), \
        [e[-1000:] for _, _, e in res]
    got0, got1 = _parse(res[0][1]), _parse(res[1][1])
    assert got0["FP"] == got1["FP"] == ref_fp
    assert got0["HIST"] == got1["HIST"] == ref_hist
    _assert_single_lineage(ckpt)


def test_multihost_drill_sigkill_writer(tmp_path, drill_reference):
    """SIGKILL the WRITER mid-run: the reader's wait_for_step times out on
    the dead writer and exits with a CheckpointError (no hang, no takeover
    — a reader must never start a second lineage).  Relaunching all ranks
    under a fresh session resumes rank 0's lineage where it stopped and
    replays bit-identically."""
    ref_fp, ref_hist = drill_reference
    ckpt = str(tmp_path / "ckpt")
    coord = str(tmp_path / "coord")

    res = _launch_ranks(ckpt, coord, "attempt1", kill_rank=0)
    assert res[0][0] == -9, res[0][2][-2000:]          # writer SIGKILLed
    assert res[1][0] not in (0, -9), res[1][2][-2000:]
    assert "CheckpointError" in res[1][2] or "timed out" in res[1][2]
    _assert_single_lineage(ckpt)
    resumed_at = checkpoint.latest_valid_step(ckpt)
    assert resumed_at is not None and resumed_at < 5   # genuinely partial

    res = _launch_ranks(ckpt, coord, "attempt2")
    assert all(rc == 0 for rc, _, _ in res), \
        [e[-1000:] for _, _, e in res]
    got0, got1 = _parse(res[0][1]), _parse(res[1][1])
    assert got0["FP"] == got1["FP"] == ref_fp
    assert got0["HIST"] == got1["HIST"] == ref_hist
    _assert_single_lineage(ckpt)


# ------------------------------------------------------------- liveness


# Child rank: loads coordinator.py by path (no package import — keeps the
# subprocess light), reaches the start barrier, then blocks forever in a
# broadcast wait, refreshing its lease the whole time.
_CHILD = """
import importlib.util, sys
spec = importlib.util.spec_from_file_location("coord", sys.argv[1])
mod = importlib.util.module_from_spec(spec)
spec.loader.exec_module(mod)
c = mod.FileCoordinator(sys.argv[2], 1, 2, session="liv", poll_s=0.01,
                        timeout_s=60, lease_interval_s=0.05,
                        lease_ttl_s=0.5)
c.barrier("start")
c.broadcast("never")          # parent never publishes: wait + heartbeat
"""


def test_sigkilled_rank_is_reported_dead_by_lease(tmp_path):
    """SIGKILL a peer mid-wait: the survivor's next barrier timeout names
    the rank DEAD via its expired lease, not just 'missing'."""
    root = str(tmp_path / "coord")
    child = subprocess.Popen([sys.executable, "-c", _CHILD,
                              coord_lib.__file__.replace(".pyc", ".py"),
                              root])
    try:
        parent = coord_lib.FileCoordinator(root, 0, 2, session="liv",
                                           poll_s=0.01, timeout_s=60,
                                           lease_interval_s=0.05,
                                           lease_ttl_s=0.5)
        parent.barrier("start", timeout_s=30)   # child is up and waiting
        child.kill()                            # SIGKILL: no cleanup runs
        child.wait(timeout=10)
        import time
        time.sleep(0.8)                         # let the lease expire
        with pytest.raises(coord_lib.CoordinatorError,
                           match=r"rank 1 dead \(lease expired"):
            parent.barrier("probe", timeout_s=0.3)
    finally:
        if child.poll() is None:
            child.kill()


def test_never_started_rank_has_no_lease(tmp_path):
    c = coord_lib.FileCoordinator(str(tmp_path), 0, 2, timeout_s=0.2,
                                  poll_s=0.01)
    with pytest.raises(coord_lib.CoordinatorError,
                       match=r"rank 1 never started \(no lease\)"):
        c.barrier("lonely")


def test_wedged_rank_reads_alive_not_dead(tmp_path):
    """A peer stuck in a DIFFERENT wait keeps refreshing its lease: the
    timeout must call it alive/wedged, not dead — that distinction is what
    tells the operator whether to relaunch or to debug a divergent call
    sequence."""
    root = str(tmp_path / "coord")
    stop = threading.Event()

    def wedged_rank():
        c = coord_lib.FileCoordinator(root, 1, 2, session="s0",
                                      poll_s=0.01, timeout_s=30,
                                      lease_interval_s=0.05,
                                      lease_ttl_s=5.0)
        c.barrier("start")
        try:
            c.broadcast("elsewhere", timeout_s=10)   # wrong wait: wedged
        except coord_lib.CoordinatorError:
            pass
        stop.set()

    t = threading.Thread(target=wedged_rank)
    t.start()
    try:
        parent = coord_lib.FileCoordinator(root, 0, 2, session="s0",
                                           poll_s=0.01, timeout_s=30,
                                           lease_interval_s=0.05,
                                           lease_ttl_s=5.0)
        parent.barrier("start", timeout_s=30)
        with pytest.raises(coord_lib.CoordinatorError,
                           match=r"rank 1 alive .* wedged"):
            parent.barrier("probe", timeout_s=0.4)
    finally:
        # unblock the wedged thread's broadcast so the test exits cleanly
        parent.broadcast("elsewhere", {"bye": True})
        t.join(timeout=15)
    assert stop.is_set()


def test_lease_ttl_must_exceed_interval(tmp_path):
    with pytest.raises(coord_lib.CoordinatorError, match="lease_ttl_s"):
        coord_lib.FileCoordinator(str(tmp_path), 0, 1,
                                  lease_interval_s=2.0, lease_ttl_s=1.0)
