"""Coverage for the mask-tree utilities.

Hand-built trees pin down threshold's exact-budget/tie-breaking behavior,
IoU / is_subset semantics, and the stacked-tree helpers the candidate engine
is built on (round-trips through _flatten/_unflatten layouts); hypothesis
property tests (optional dep, skipped when absent) sweep the pad/slice/index
round-trips over arbitrary tree shapes and candidate counts.
"""
import numpy as np
import pytest

# hypothesis is an optional dev dep (pip extra: test) — bare environments
# must still collect/run the deterministic tests, so only the property
# tests below are guarded.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import masks as M


def _tree():
    return {"a": np.array([[1, 0], [1, 1]], np.float32),
            "b": np.array([1, 0, 1], np.float32)}


# ------------------------------------------------------------- threshold


def test_threshold_exact_budget_and_largest_kept():
    soft = {"a": np.array([0.9, 0.1, 0.5], np.float32),
            "b": np.array([0.8, 0.3], np.float32)}
    hard = M.threshold(soft, 3)
    assert M.count(hard) == 3
    assert hard["a"].tolist() == [1.0, 0.0, 1.0]   # 0.9, 0.5 kept
    assert hard["b"].tolist() == [1.0, 0.0]        # 0.8 kept


def test_threshold_budget_zero_and_overfull():
    soft = {"a": np.array([0.2, 0.7], np.float32)}
    assert M.count(M.threshold(soft, 0)) == 0
    full = M.threshold(soft, 99)                   # clamped to total size
    assert M.count(full) == 2


def test_threshold_tie_breaking_keeps_exact_budget():
    """All-equal scores: budget must still be exact (argpartition picks an
    arbitrary but valid subset — the cliff the paper cares about is the
    count, not which tied coordinate survives)."""
    soft = {"a": np.full((5,), 0.5, np.float32),
            "b": np.full((4,), 0.5, np.float32)}
    for budget in (0, 1, 4, 9):
        assert M.count(M.threshold(soft, budget)) == budget


# ------------------------------------------------------- IoU / is_subset


def test_iou_and_subset_hand_built():
    small = {"a": np.array([[1, 0], [0, 0]], np.float32),
             "b": np.array([0, 0, 1], np.float32)}
    big = _tree()
    assert M.is_subset(small, big)
    assert not M.is_subset(big, small)
    assert M.intersection_over_union(small, big) == 1.0
    # big ∩ small = 2 active of big's 5 actives
    assert M.intersection_over_union(big, small) == pytest.approx(2 / 5)


def test_iou_empty_small_tree_is_zero_not_nan():
    empty = {"a": np.zeros((2, 2), np.float32),
             "b": np.zeros((3,), np.float32)}
    assert M.intersection_over_union(empty, _tree()) == 0.0
    assert M.is_subset(empty, _tree())


# ------------------------------------------------------- stacked helpers


def test_stack_and_index_roundtrip():
    trees = [_tree() for _ in range(3)]
    trees[1]["a"][0, 0] = 0.0
    stacked = M.stack_trees(trees)
    assert M.stacked_len(stacked) == 3
    for i, t in enumerate(trees):
        got = M.index_stacked(stacked, i)
        for k in t:
            np.testing.assert_array_equal(got[k], t[k])


def test_stacked_flatten_roundtrip_matches_single_layout():
    """flatten_stacked/unflatten_stacked agree with the single-tree
    _flatten/_unflatten layout (site order, offsets, shapes)."""
    trees = [_tree(), _tree()]
    stacked = M.stack_trees(trees)
    flat2, layout2 = M.flatten_stacked(stacked)
    flat1, layout1 = M._flatten(trees[0])
    np.testing.assert_array_equal(flat2[0], flat1)
    assert [(k, off, n) for k, off, n, _ in layout2] == \
        [(k, off, n) for k, off, n, _ in layout1]
    back = M.unflatten_stacked(flat2, layout2)
    for k in trees[0]:
        np.testing.assert_array_equal(back[k], stacked[k])
    # and each row unflattens to the original tree via the 1-tree path
    single = M._unflatten(flat2[1], layout1)
    for k in trees[1]:
        np.testing.assert_array_equal(single[k], trees[1][k])


def test_slice_pad_and_counts():
    masks = _tree()                                # 5 active of 7
    stacked = M.sample_removal_blocks(
        np.random.default_rng(0), masks, 2, 5)
    np.testing.assert_array_equal(M.stacked_counts(stacked),
                                  np.full(5, M.count(masks) - 2))
    sl = M.slice_stacked(stacked, 1, 3)
    assert M.stacked_len(sl) == 2
    padded = M.pad_stacked(sl, 4)
    assert M.stacked_len(padded) == 4
    for k in padded:                               # pad repeats the last row
        np.testing.assert_array_equal(padded[k][2], sl[k][1])
        np.testing.assert_array_equal(padded[k][3], sl[k][1])


def test_materialize_candidates_zeroes_exactly_the_indices():
    masks = _tree()
    flat, layout = M._flatten(masks)
    active = np.nonzero(flat > 0.5)[0]
    idx = np.stack([active[:2], active[-2:]])
    stacked = M.materialize_candidates(masks, idx)
    for i in range(2):
        row = M.flatten_stacked(M.slice_stacked(stacked, i, i + 1))[0][0]
        removed = np.nonzero((flat > 0.5) & ~(row > 0.5))[0]
        np.testing.assert_array_equal(np.sort(removed), np.sort(idx[i]))


# ------------------------------------------------- hypothesis properties
#
# The stacked-tree helpers back every evaluator backend: padding must be
# invisible below the original length, indexing must round-trip through
# stacking, and stacked_len/stacked_counts must stay consistent under
# slice/pad for ANY tree geometry — not just the hand-built cases above.

if HAS_HYPOTHESIS:
    @st.composite
    def _stacked_trees(draw, max_sites=3, max_candidates=5):
        n = draw(st.integers(1, max_candidates))
        n_sites = draw(st.integers(1, max_sites))
        tree = {}
        for s in range(n_sites):
            shape = tuple(draw(st.lists(st.integers(1, 4), min_size=1,
                                        max_size=3)))
            bits = draw(st.lists(st.integers(0, 1),
                                 min_size=n * int(np.prod(shape)),
                                 max_size=n * int(np.prod(shape))))
            tree[f"site{s}"] = np.asarray(bits, np.float32).reshape(
                (n,) + shape)
        return tree

    @given(stacked=_stacked_trees(), pad_to=st.integers(0, 9))
    @settings(max_examples=40, deadline=None)
    def test_pad_index_roundtrip_identity(stacked, pad_to):
        """index_stacked(pad_stacked(t, m), i) == index_stacked(t, i) for
        every real candidate i; padded rows repeat the last candidate."""
        n = M.stacked_len(stacked)
        padded = M.pad_stacked(stacked, pad_to)
        assert M.stacked_len(padded) == max(n, pad_to)
        for i in range(n):
            a, b = M.index_stacked(padded, i), M.index_stacked(stacked, i)
            for k in stacked:
                np.testing.assert_array_equal(a[k], b[k])
        last = M.index_stacked(stacked, n - 1)
        for i in range(n, max(n, pad_to)):
            got = M.index_stacked(padded, i)
            for k in stacked:
                np.testing.assert_array_equal(got[k], last[k])

    @given(stacked=_stacked_trees())
    @settings(max_examples=40, deadline=None)
    def test_stack_of_indexed_is_identity(stacked):
        """stack_trees([index_stacked(t, i) for i]) == t."""
        n = M.stacked_len(stacked)
        back = M.stack_trees(M.index_stacked(stacked, i) for i in range(n))
        for k in stacked:
            np.testing.assert_array_equal(back[k], stacked[k])

    @given(stacked=_stacked_trees(), start=st.integers(0, 6),
           stop=st.integers(0, 6))
    @settings(max_examples=40, deadline=None)
    def test_slice_len_and_counts_invariants(stacked, start, stop):
        """stacked_len/stacked_counts agree with per-candidate count() and
        survive slicing; flatten/unflatten round-trips the sliced tree."""
        n = M.stacked_len(stacked)
        counts = M.stacked_counts(stacked)
        assert counts.shape == (n,)
        for i in range(n):
            assert counts[i] == M.count(M.index_stacked(stacked, i))
        sl = M.slice_stacked(stacked, start, stop)
        want = len(range(*slice(start, stop).indices(n)))
        assert M.stacked_len(sl) == want
        if want:
            flat, layout = M.flatten_stacked(sl)
            back = M.unflatten_stacked(flat, layout)
            for k in sl:
                np.testing.assert_array_equal(back[k], sl[k])
        else:                              # empty slice stays a valid tree
            assert all(v.shape[0] == 0 for v in sl.values())
else:
    def test_pad_index_roundtrip_identity():
        pytest.skip("hypothesis not installed (pip extra: test)")

    def test_stack_of_indexed_is_identity():
        pytest.skip("hypothesis not installed (pip extra: test)")

    def test_slice_len_and_counts_invariants():
        pytest.skip("hypothesis not installed (pip extra: test)")
