"""Dry-run machinery on a small forced-device mesh (subprocess — XLA device
count must be set before jax init).  The full 512-device × 80-cell sweep runs
via ``python -m repro.launch.dryrun`` (results in reports/dryrun)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses, json, sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config, ShapeCell, input_specs
from repro.models.lm import LM
from repro.training import optimizer as opt_lib, train as train_lib
from repro.analysis import roofline as rl

mesh = Mesh(np.array(jax.devices()).reshape(4, 4), ("data", "model"))
cfg = dataclasses.replace(
    get_config(sys.argv[1]), n_layers=None, d_model=512, n_heads=8,
    n_kv_heads=4, head_dim=64, d_ff=1024, vocab=2048)
cfg = dataclasses.replace(cfg, n_layers=len(cfg.head_blocks) + 2*len(cfg.pattern) + 0)
if cfg.n_experts:
    cfg = dataclasses.replace(cfg, n_experts=8, top_k=2, d_ff_expert=256,
                              d_ff_shared=256 if cfg.n_shared_experts else 0)
if cfg.ssm_state:
    cfg = dataclasses.replace(cfg, ssm_state=16)
model = LM(cfg)
shape = ShapeCell("mini", 256, 16, "train")
opt = opt_lib.adamw(lr=1e-4)
tcfg = train_lib.TrainStepCfg(remat=True, dp_axes=("data",))
with mesh:
    step = train_lib.jit_train_step(model, opt, mesh, tcfg)
    state_sds = jax.eval_shape(lambda: train_lib.make_state(model, opt, jax.random.PRNGKey(0)))
    m_sds = {k: jax.ShapeDtypeStruct(s.shape, jnp.float32)
             for k, s in model.mask_sites().items()}
    lowered = step.lower(state_sds, input_specs(cfg, shape), m_sds)
    compiled = lowered.compile()
ca = rl.xla_cost(compiled)
st = rl.parse_collectives(compiled.as_text(), 16, loop_trip_count=cfg.n_repeats)
out = {"flops": float(ca.get("flops", 0)),
       "collective_bytes": st.bytes_moved_global,
       "counts": st.counts,
       "mem": compiled.memory_analysis().temp_size_in_bytes}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.parametrize("arch", ["stablelm_1p6b", "mixtral_8x22b",
                                  "zamba2_2p7b"])
def test_mini_dryrun_lowers_compiles_and_analyzes(arch):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", _SCRIPT, arch], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-2000:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line.split(" ", 1)[1])
    assert out["flops"] > 0
    assert out["collective_bytes"] > 0      # sharded step must communicate
    assert out["mem"] > 0


def test_production_mesh_shapes():
    script = (
        "import os; "
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'; "
        "from repro.launch.mesh import make_production_mesh; "
        "m1 = make_production_mesh(); m2 = make_production_mesh(multi_pod=True); "
        "assert m1.shape == {'data': 16, 'model': 16}, m1.shape; "
        "assert m2.shape == {'pod': 2, 'data': 16, 'model': 16}, m2.shape; "
        "assert m1.size == 256 and m2.size == 512; print('MESH OK')")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "MESH OK" in p.stdout


def test_sweep_results_if_present():
    """If the full sweep has run, every non-skipped cell must be error-free
    and applicable cells must cover all 10 archs × 4 shapes × 2 meshes."""
    d = os.path.join(ROOT, "reports", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("full sweep not run in this environment")
    base = [f for f in os.listdir(d)
            if f.endswith(".json") and f.count(".") == 3]
    recs = [json.load(open(os.path.join(d, f))) for f in base]
    errs = [r for r in recs if "error" in r]
    assert not errs, [e["arch"] + ":" + e.get("shape", "") for e in errs]
    ok = [r for r in recs if "skipped" not in r]
    for r in ok:
        assert r["roofline_fraction"] > 0
        assert r["t_compute_s"] > 0
