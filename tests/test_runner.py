"""Resumable run orchestration (core.runner / launch.sweep).

The contract under test: a BCD run checkpointed after every accepted block
and resumed — after a clean stop, a corrupted newest checkpoint, or a real
SIGKILL — replays bit-identically against an uninterrupted run: same masks,
same step logs (``wall_s`` excepted, which is wall-clock), same finetuned
params.  Plus the shared stage-init warm-start format and the multi-budget
sweep driver built on top.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcd, engine, linearize, masks as M, runner
from repro.core.snl import finetune as snl_finetune
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import sweep as sweep_lib
from repro.training import checkpoint, optimizer as opt_lib, train as train_lib


# ------------------------------------------------------------ helpers


def _hist_identity(history):
    """Step logs minus wall_s — the deterministic replay identity."""
    out = []
    for h in history:
        d = dataclasses.asdict(h)
        d.pop("wall_s")
        out.append(d)
    return out


def _assert_same_run(a_masks, a_hist, b_masks, b_hist):
    for k in a_masks:
        np.testing.assert_array_equal(a_masks[k], b_masks[k])
    assert _hist_identity(a_hist) == _hist_identity(b_hist)


def _toy_masks(n=48):
    return {"a": np.ones((n // 2,), np.float32),
            "b": np.ones((n // 2,), np.float32)}


def _toy_eval_fn(m):
    # deterministic, coordinate-sensitive accuracy surrogate
    wa = jnp.arange(m["a"].shape[-1], dtype=jnp.float32)
    wb = jnp.arange(m["b"].shape[-1], dtype=jnp.float32)[::-1]
    return 95.0 - 0.02 * (jnp.sum((1 - m["a"]) * wa) +
                          jnp.sum((1 - m["b"]) * wb))


def _toy_eval_acc(m):
    return float(_toy_eval_fn(M.as_device(m)))


def _toy_cfg(masks, steps=4, **kw):
    total = M.count(masks)
    kw.setdefault("b_target", total - 4 * steps)
    kw.setdefault("drc", 4)
    kw.setdefault("rt", 6)
    kw.setdefault("adt", -1.0)       # no early exit: every trial evaluated
    kw.setdefault("chunk_size", 2)
    kw.setdefault("seed", 0)
    return bcd.BCDConfig(**kw)


# ------------------------------------------------------------ rng round-trip


def test_rng_state_roundtrip_through_json():
    rng = np.random.default_rng(123)
    rng.random(37)                                  # advance the stream
    blob = json.dumps(runner.rng_state_to_jsonable(rng))
    rng2 = runner.rng_from_state(json.loads(blob))
    np.testing.assert_array_equal(rng.random(100), rng2.random(100))
    np.testing.assert_array_equal(rng.integers(0, 1 << 62, 10),
                                  rng2.integers(0, 1 << 62, 10))


def test_rng_restore_rejects_foreign_bit_generator():
    state = runner.rng_state_to_jsonable(np.random.default_rng(0))
    state = dict(state, bit_generator="MT19937")
    with pytest.raises(runner.CheckpointError):
        runner.rng_from_state(state)


# ------------------------------------------------------- resume equivalence


def _toy_evaluator(backend):
    if backend == "sequential":
        return engine.SequentialEvaluator(_toy_eval_acc)
    if backend == "batched":
        return engine.BatchedEvaluator(_toy_eval_fn, pad_to=2)
    if backend == "pipelined":
        return engine.PipelinedEvaluator(_toy_eval_fn, pad_to=2, prefetch=2)
    raise AssertionError(backend)


@pytest.mark.parametrize("backend", ["sequential", "batched", "pipelined"])
def test_resume_matches_uninterrupted_across_backends(backend, tmp_path):
    masks = _toy_masks()
    cfg = _toy_cfg(masks, steps=5)

    ref = bcd.run_bcd(masks, cfg, _toy_eval_acc,
                      evaluator=_toy_evaluator(backend))

    d = str(tmp_path / backend)
    part = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d, max_steps=2),
                            _toy_eval_acc, evaluator=_toy_evaluator(backend))
    pres = part.run(masks)
    assert part.stopped_early and M.count(pres.masks) > cfg.b_target

    cont = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d),
                            _toy_eval_acc, evaluator=_toy_evaluator(backend))
    res = cont.run(masks)
    assert cont.resumed_from == 2 and not cont.stopped_early
    _assert_same_run(ref.masks, ref.history, res.masks, res.history)


def test_typed_move_state_roundtrips_through_resume(tmp_path):
    """Mixed-kind descent under the sensitivity proposal: the proposal
    reads ``move_stats``, so bit-identical resume requires the acceptance
    counters (and the per-step ``move_kind`` logs) to round-trip through
    the checkpoint exactly — not just masks and rng."""
    masks = _toy_masks()
    cfg = _toy_cfg(masks, steps=5, moves=M.MOVE_KINDS,
                   proposal="sensitivity")

    ref = bcd.run_bcd(masks, cfg, _toy_eval_acc)
    assert any(h.move_kind != "remove" for h in ref.history)

    d = str(tmp_path / "moves")
    part = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d, max_steps=2),
                            _toy_eval_acc)
    pres = part.run(masks)
    assert part.stopped_early
    # the partial run's counters are a strict prefix of the full run's
    assert sum(v["proposed"] for v in
               pres.move_stats["kinds"].values()) == 2 * cfg.rt

    cont = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d),
                            _toy_eval_acc)
    res = cont.run(masks)
    assert cont.resumed_from == 2 and not cont.stopped_early
    _assert_same_run(ref.masks, ref.history, res.masks, res.history)
    assert res.move_stats == ref.move_stats
    assert [h.move_kind for h in res.history] == \
        [h.move_kind for h in ref.history]


def test_resume_with_finetuned_params_roundtrip(tmp_path):
    """Params mutate between outer steps (finetune); they are part of the
    resume state and must round-trip bit-exactly through the checkpoint."""
    from repro.models.resnet import CNN, CNNConfig
    model = CNN(CNNConfig("tiny", 4, 8, ((4, 1, 1),), stem_channels=4))
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=8,
                                           n_train=64, n_test=32))
    params0 = model.init(jax.random.PRNGKey(0))
    _, loss_fn = train_lib.make_cnn_train_step(model, opt_lib.sgd(lr=1e-2))
    batches_np = data.batches("train", 16)
    batches = lambda i: {k: jnp.asarray(v)
                         for k, v in batches_np(i).items()}
    eval_b = data.train_eval_set(32)
    eval_fn_p = model.make_param_eval_fn(eval_b)
    acc_jit = jax.jit(eval_fn_p)
    masks0 = linearize.init_masks(model.mask_sites())
    cfg = _toy_cfg(masks0, steps=3, drc=16,
                   b_target=M.count(masks0) - 3 * 16, adt=0.5)

    def fresh_ctx():
        holder = {"params": params0}
        eval_acc = lambda m: float(acc_jit(M.as_device(m),
                                           holder["params"]))

        def ft(m):
            holder["params"] = snl_finetune(
                holder["params"], m,
                lambda p, mm, b, soft: loss_fn(p, mm, b, soft),
                batches, steps=4, lr=1e-2)
        return holder, eval_acc, ft

    holder, eval_acc, ft = fresh_ctx()
    ref = bcd.run_bcd(masks0, cfg, eval_acc, finetune=ft)
    ref_params = holder["params"]

    d = str(tmp_path / "ckpt")
    holder, eval_acc, ft = fresh_ctx()
    pio = (lambda: holder["params"],
           lambda p: holder.__setitem__("params", p))
    part = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d, max_steps=1),
                            eval_acc, ft, params_io=pio)
    part.run(masks0)
    assert part.stopped_early

    holder, eval_acc, ft = fresh_ctx()     # params reset to params0 —
    pio = (lambda: holder["params"],       # restore must overwrite them
           lambda p: holder.__setitem__("params", p))
    cont = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d),
                            eval_acc, ft, params_io=pio)
    res = cont.run(masks0)
    assert cont.resumed_from == 1
    _assert_same_run(ref.masks, ref.history, res.masks, res.history)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref_params, holder["params"])


def test_resume_refuses_changed_config(tmp_path):
    masks = _toy_masks()
    cfg = _toy_cfg(masks)
    d = str(tmp_path / "ckpt")
    part = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d, max_steps=1),
                            _toy_eval_acc)
    part.run(masks)
    changed = dataclasses.replace(cfg, seed=cfg.seed + 1)
    with pytest.raises(runner.CheckpointError, match="seed"):
        runner.BCDRunner(changed, runner.RunnerConfig(ckpt_dir=d),
                         _toy_eval_acc).run(masks)


# --------------------------------------------- corrupted checkpoint handling


def _run_two_checkpoints(tmp_path):
    masks = _toy_masks()
    cfg = _toy_cfg(masks, steps=4)
    d = str(tmp_path / "ckpt")
    part = runner.BCDRunner(
        cfg, runner.RunnerConfig(ckpt_dir=d, max_steps=2, keep=10),
        _toy_eval_acc)
    part.run(masks)
    assert checkpoint.latest_valid_step(d) == 2
    return masks, cfg, d


def test_corrupted_leaf_falls_back_to_previous_checkpoint(tmp_path):
    masks, cfg, d = _run_two_checkpoints(tmp_path)
    # bit-rot a leaf of the newest checkpoint: same size, flipped bytes
    step_dir = os.path.join(d, "step_00000002")
    leaf = os.path.join(step_dir, "leaf_00000.npy")
    blob = bytearray(open(leaf, "rb").read())
    blob[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(blob))
    assert checkpoint.validate(d, 2, deep=False)       # files all exist...
    assert not checkpoint.validate(d, 2, deep=True)    # ...but hash fails
    assert checkpoint.latest_valid_step(d) == 1
    with pytest.raises(checkpoint.CheckpointError, match="sha256"):
        checkpoint.restore({"masks": masks}, d, 2)
    # the runner resumes from step 1 and still reproduces the full run
    ref = bcd.run_bcd(masks, cfg, _toy_eval_acc)
    cont = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d),
                            _toy_eval_acc)
    res = cont.run(masks)
    assert cont.resumed_from == 1
    _assert_same_run(ref.masks, ref.history, res.masks, res.history)


def test_partial_checkpoint_missing_leaf_rejected(tmp_path):
    masks, cfg, d = _run_two_checkpoints(tmp_path)
    os.remove(os.path.join(d, "step_00000002", "leaf_00001.npy"))
    assert not checkpoint.validate(d, 2)
    assert checkpoint.latest_valid_step(d) == 1
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.restore({"masks": masks}, d, 2)


def test_garbage_manifest_rejected(tmp_path):
    masks, cfg, d = _run_two_checkpoints(tmp_path)
    mf = os.path.join(d, "step_00000002", "manifest.json")
    open(mf, "w").write("{not json")
    assert not checkpoint.validate(d, 2)
    assert checkpoint.latest_valid_step(d) == 1
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.read_manifest(d, 2)


def test_all_checkpoints_corrupt_is_fresh_start(tmp_path):
    masks, cfg, d = _run_two_checkpoints(tmp_path)
    for s in (1, 2):
        os.remove(os.path.join(d, f"step_{s:08d}", "manifest.json"))
    assert checkpoint.latest_valid_step(d) is None
    with pytest.raises(FileNotFoundError):
        runner.restore_run_state(d, cfg, masks)
    # BCDRunner treats it as a fresh run, not an error
    cont = runner.BCDRunner(cfg, runner.RunnerConfig(ckpt_dir=d),
                            _toy_eval_acc)
    res = cont.run(masks)
    assert cont.resumed_from is None
    ref = bcd.run_bcd(masks, cfg, _toy_eval_acc)
    _assert_same_run(ref.masks, ref.history, res.masks, res.history)


# ------------------------------------------------------------ stage init


def test_stage_init_roundtrip(tmp_path):
    masks = M.threshold({k: np.random.default_rng(0)
                         .random(v.shape).astype(np.float32)
                         for k, v in _toy_masks().items()}, 20)
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.zeros((3,), np.float32)}
    aux = {"alphas": {"a": np.full((24,), 0.25, np.float32)}}
    init = {"kind": "snl", "masks": masks, "params": params, "aux": aux}
    path = str(tmp_path / "init")
    runner.save_stage_init(path, init)
    assert runner.stage_init_exists(path)

    got = runner.load_stage_init(path, masks, params_template=params,
                                 aux_template=aux)
    assert got["kind"] == "snl"
    for k in masks:
        np.testing.assert_array_equal(got["masks"][k], masks[k])
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  params["w"])
    np.testing.assert_array_equal(
        np.asarray(got["aux"]["alphas"]["a"]), aux["alphas"]["a"])
    assert got["meta"]["budget"] == 20
    assert got["meta"]["mask_fingerprint"] == M.fingerprint(masks)

    # aux is optional on load — a sweep that only needs masks+params
    lean = runner.load_stage_init(path, masks, params_template=params)
    assert lean["aux"] is None
    with pytest.raises(runner.CheckpointError):
        runner.load_stage_init(str(tmp_path / "nope"), masks)


def test_snl_and_autorep_results_share_stage_init_shape():
    from repro.core.snl import SNLResult
    from repro.core.autorep import AutoRepResult
    masks = _toy_masks()
    s = SNLResult(params={"w": np.ones(2)}, masks=masks, alphas={},
                  snapshots=[], budget_per_epoch=[], lam_per_epoch=[])
    a = AutoRepResult(params={"w": np.ones(2)}, poly={"p": np.ones(3)},
                      masks=masks, alphas={}, budget_per_epoch=[])
    si, ai = s.stage_init(), a.stage_init()
    assert set(si) == set(ai) == {"kind", "masks", "params", "aux"}
    assert (si["kind"], ai["kind"]) == ("snl", "autorep")


# ------------------------------------------------------------------ sweep


def _sweep_ctx(tmp_path, name="toy"):
    masks = _toy_masks()
    params = {"w": np.arange(4, dtype=np.float32)}
    holder = {"params": params}
    pio = (lambda: holder["params"],
           lambda p: holder.__setitem__("params", p))
    cfg = sweep_lib.SweepConfig(
        budgets=[36, 28], out_dir=str(tmp_path / name), name=name)
    mk = lambda b: _toy_cfg(masks, b_target=b)
    init = {"kind": "snl", "masks": masks, "params": params}
    return masks, holder, pio, cfg, mk, init


def test_sweep_descends_warm_started_and_resumes(tmp_path):
    masks, holder, pio, cfg, mk, init = _sweep_ctx(tmp_path)
    res = sweep_lib.run_sweep(cfg, mk, _toy_eval_acc, init=init,
                              params_io=pio, eval_test=_toy_eval_acc)
    assert res["complete"] and [s["budget"] for s in res["stages"]] == [36, 28]
    assert M.count(res["final_masks"]) == 28
    # each stage's masks are a subset of the previous stage's (warm start)
    assert res["stages"][0]["mask_fingerprint"] != \
        res["stages"][1]["mask_fingerprint"]
    art = json.load(open(res["artifact"]))
    assert art["complete"] and len(art["stages"]) == 2
    assert all("wall_s" not in h for s in art["stages"]
               for h in s["history"])

    # notes merged out-of-band (e.g. the auto-prefetch report) must survive
    # a later artifact rewrite by a resumed sweep
    sweep_lib.update_notes(cfg, {"auto_prefetch": {"prefetch": 2}})

    # re-run: both stages skip, artifact identical, notes preserved
    res2 = sweep_lib.run_sweep(cfg, mk, _toy_eval_acc, init=init,
                               params_io=pio, eval_test=_toy_eval_acc)
    assert [s["mask_fingerprint"] for s in res2["stages"]] == \
        [s["mask_fingerprint"] for s in res["stages"]]
    assert res2["notes"]["auto_prefetch"] == {"prefetch": 2}


def test_sweep_interrupted_mid_stage_matches_uninterrupted(tmp_path):
    masks, holder, pio, cfg_a, mk, init = _sweep_ctx(tmp_path, "ref")
    ref = sweep_lib.run_sweep(cfg_a, mk, _toy_eval_acc, init=init,
                              params_io=pio)

    masks, holder, pio, cfg_b, mk, init = _sweep_ctx(tmp_path, "cut")
    cut = sweep_lib.SweepConfig(budgets=cfg_b.budgets,
                                out_dir=cfg_b.out_dir, name=cfg_b.name)
    # interrupt stage 0 mid-run: a runner with max_steps inside the stage
    part = runner.BCDRunner(
        mk(cut.budgets[0]),
        runner.RunnerConfig(
            ckpt_dir=os.path.join(sweep_lib._stage_dir(cut, 0), "ckpt"),
            max_steps=1),
        _toy_eval_acc, params_io=pio)
    runner.save_stage_init(os.path.join(cut.out_dir, "init"), init)
    part.run(masks)
    assert part.stopped_early
    # now run the sweep driver: it must resume the half-done stage
    res = sweep_lib.run_sweep(cut, mk, _toy_eval_acc, init=init,
                              params_io=pio)
    assert [s["mask_fingerprint"] for s in res["stages"]] == \
        [s["mask_fingerprint"] for s in ref["stages"]]
    assert [s["history"] for s in res["stages"]] == \
        [s["history"] for s in ref["stages"]]


def test_overlap_sweep_bit_identical_to_serial(tmp_path):
    """The overlap acceptance criterion: with the reporting tail
    (stage_finetune + stage_eval) running concurrently with the next
    stage's descent, the sweep emits masks, step histories, AND scores
    bit-identical to the serial sweep on the same schedule."""
    def run(name, overlap):
        masks, holder, pio, cfg, mk, init = _sweep_ctx(tmp_path, name)
        cfg = sweep_lib.SweepConfig(
            budgets=cfg.budgets, out_dir=cfg.out_dir, name=name,
            overlap=overlap)
        # a reporting finetune that really transforms params, and a score
        # that depends on both inputs — pure in (params, masks)
        sft = lambda p, m: {"w": p["w"] + np.float32(M.count(m))}
        sev = lambda m, p: _toy_eval_acc(m) + float(np.sum(p["w"]))
        return sweep_lib.run_sweep(cfg, mk, _toy_eval_acc, init=init,
                                   params_io=pio, stage_finetune=sft,
                                   stage_eval=sev)

    serial = run("serial", overlap=False)
    over = run("over", overlap=True)
    assert serial["complete"] and over["complete"]
    for a, b in zip(serial["stages"], over["stages"]):
        assert a["mask_fingerprint"] == b["mask_fingerprint"]
        assert a["history"] == b["history"]
        assert a["test_acc"] == b["test_acc"]
    # the overlapped artifact on disk converged to fully-scored too
    art = json.load(open(over["artifact"]))
    assert art["complete"]
    assert [s.get("test_acc") for s in art["stages"]] == \
        [s["test_acc"] for s in serial["stages"]]


def test_overlap_rejects_impure_eval_test(tmp_path):
    masks, holder, pio, cfg, mk, init = _sweep_ctx(tmp_path)
    cfg = sweep_lib.SweepConfig(budgets=cfg.budgets, out_dir=cfg.out_dir,
                                name=cfg.name, overlap=True)
    with pytest.raises(ValueError, match="stage_eval"):
        sweep_lib.run_sweep(cfg, mk, _toy_eval_acc, init=init,
                            params_io=pio, eval_test=_toy_eval_acc)


def test_resumed_sweep_scores_unscored_stages(tmp_path):
    """A crash after result.json but before the reporting tail leaves a
    completed-but-unscored stage; the resume path must finish scoring it
    rather than shipping an artifact with holes."""
    masks, holder, pio, cfg, mk, init = _sweep_ctx(tmp_path)
    res = sweep_lib.run_sweep(cfg, mk, _toy_eval_acc, init=init,
                              params_io=pio,
                              stage_eval=lambda m, p: _toy_eval_acc(m))
    # simulate the crash window: strip stage 0's score on disk
    rp = os.path.join(sweep_lib._stage_dir(cfg, 0), "result.json")
    stage = json.load(open(rp))
    want = stage.pop("test_acc")
    json.dump(stage, open(rp, "w"))
    res2 = sweep_lib.run_sweep(cfg, mk, _toy_eval_acc, init=init,
                               params_io=pio,
                               stage_eval=lambda m, p: _toy_eval_acc(m))
    assert res2["stages"][0]["test_acc"] == want
    assert json.load(open(rp))["test_acc"] == want
    assert [s["mask_fingerprint"] for s in res2["stages"]] == \
        [s["mask_fingerprint"] for s in res["stages"]]


def test_rescore_does_not_truncate_artifact(tmp_path):
    """The resume re-score path folds its score into the EXISTING artifact:
    when the on-disk artifact already describes more stages than the resume
    loop has revisited, the reporter must patch the stage in place, not
    clobber the artifact with a one-stage partial list."""
    cfg = sweep_lib.SweepConfig(budgets=[36, 28],
                                out_dir=str(tmp_path / "t"), name="t")
    s0 = {"stage": 0, "budget": 36, "mask_fingerprint": "aaa"}
    s1 = {"stage": 1, "budget": 28, "mask_fingerprint": "bbb",
          "test_acc": 9.0}
    os.makedirs(sweep_lib._stage_dir(cfg, 0), exist_ok=True)
    sweep_lib._write_artifact(cfg, [s0, s1], True)

    reporter = sweep_lib._StageReporter(cfg, [s0], None,
                                        lambda m, p: 5.0, None, None)
    reporter.submit(0, s0, _toy_masks(), None)
    reporter.join()
    art = json.load(open(sweep_lib.artifact_path(cfg)))
    assert len(art["stages"]) == 2 and art["complete"]   # not truncated
    assert art["stages"][0]["test_acc"] == 5.0           # score folded in
    assert art["stages"][1] == s1


def test_sweep_validates_schedule(tmp_path):
    masks, holder, pio, cfg, mk, init = _sweep_ctx(tmp_path)
    for bad in ([], [28, 36], [36, 36], [-1], [M.count(masks)]):
        c = sweep_lib.SweepConfig(budgets=bad, out_dir=str(tmp_path / "bad"))
        with pytest.raises(ValueError):
            c.validate(M.count(masks))
    with pytest.raises(ValueError, match="init"):
        sweep_lib.run_sweep(
            sweep_lib.SweepConfig(budgets=[8], out_dir=str(tmp_path / "x")),
            mk, _toy_eval_acc)


# ------------------------------------------------- SIGKILL (the real thing)


_KILL_SCRIPT = r"""
import json, os, sys
import numpy as np
import jax.numpy as jnp
from repro.core import bcd, masks as M
from repro.launch import sweep as sweep_lib

out_dir = sys.argv[1]
masks = {"a": np.ones((24,), np.float32), "b": np.ones((24,), np.float32)}
wa = jnp.arange(24, dtype=jnp.float32)
eval_fn = lambda m: 95.0 - 0.02 * (jnp.sum((1 - m["a"]) * wa) +
                                   jnp.sum((1 - m["b"]) * wa[::-1]))
eval_acc = lambda m: float(eval_fn(M.as_device(m)))
holder = {"params": {"w": np.arange(4, dtype=np.float32)}}
pio = (lambda: holder["params"], lambda p: holder.__setitem__("params", p))
cfg = sweep_lib.SweepConfig(budgets=[36, 28], out_dir=out_dir, name="kill")
mk = lambda b: bcd.BCDConfig(b_target=b, drc=4, rt=6, adt=-1.0,
                             chunk_size=2, seed=0)
init = {"kind": "snl", "masks": masks, "params": holder["params"]}
res = sweep_lib.run_sweep(cfg, mk, eval_acc, init=init, params_io=pio)
print("FPS=" + json.dumps([s["mask_fingerprint"] for s in res["stages"]]))
print("HIST=" + json.dumps([s["history"] for s in res["stages"]]))
"""


def _run_kill_script(out_dir, kill_after=None):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop(runner.KILL_ENV, None)
    if kill_after is not None:
        env[runner.KILL_ENV] = str(kill_after)
    return subprocess.run([sys.executable, "-c", _KILL_SCRIPT, out_dir],
                          env=env, capture_output=True, text=True,
                          timeout=600)


def test_sweep_survives_sigkill_mid_stage(tmp_path):
    """The acceptance criterion, literally: SIGKILL the sweep process
    mid-stage (stage 0 has 3 steps; kill after 4 accepted blocks = stage 1
    step 1), restart, and the final masks + step logs are bit-identical to
    a never-killed run."""
    ref = _run_kill_script(str(tmp_path / "ref"))
    assert ref.returncode == 0, ref.stderr[-2000:]

    killed = _run_kill_script(str(tmp_path / "res"), kill_after=4)
    assert killed.returncode == -9       # SIGKILL, not a clean exit

    resumed = _run_kill_script(str(tmp_path / "res"))
    assert resumed.returncode == 0, resumed.stderr[-2000:]

    def lines(out):
        return {ln.split("=", 1)[0]: json.loads(ln.split("=", 1)[1])
                for ln in out.stdout.splitlines()
                if ln.startswith(("FPS=", "HIST="))}
    a, b = lines(ref), lines(resumed)
    assert a["FPS"] == b["FPS"]
    assert a["HIST"] == b["HIST"]
