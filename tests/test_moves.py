"""Move-vocabulary conformance: backends × move kinds, bit-identical.

The contract under test (core.masks moves + core.bcd + core.engine): the
typed move vocabulary — remove / add_back / swap / stage_drop / share — is
invisible to the backend-equivalence guarantees.  For the same seed and
config, every backend must select bit-identical moves with identical trial
counts and early-exit flags, for every kind alone and for the mixed-kind
sensitivity-guided sampler, because (a) sampling happens entirely up front
on the host rng, (b) selection is a pure function of the drop vector, and
(c) multi-site candidates group by the *shallowest* touched site, so the
suffix backend's cached prefixes never read an edited mask.

Also here: the move algebra properties (swap ≡ add_back ∘ remove, exact
-drc billing, no out-of-layout resurrection), the PI-cost identity for
share-tied masks, and the two engine regression cases — two-segment moves
never straddling a SitedChunk, and the prefix trie invalidating down to the
shallower of two touched segments.
"""
import numpy as np
import jax
import pytest

from repro.core import bcd, engine, linearize, masks as M, pi_cost
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

BACKENDS = ("sequential", "batched", "sharded", "pipelined", "suffix")
MIXED = M.MOVE_KINDS                 # all five kinds in one config


# --------------------------------------------------------------- fixture


@pytest.fixture(scope="module")
def setup():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(128)
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def _make_ev(backend, model, params, batch, prefetch=1):
    if backend == "sequential":
        return engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    if backend == "batched":
        return engine.BatchedEvaluator(model.make_eval_fn(params, batch),
                                       pad_to=3)
    if backend == "sharded":
        return engine.ShardedEvaluator(model.make_eval_fn(params, batch),
                                       mesh_lib.make_candidate_mesh(),
                                       pad_to=3)
    if backend == "pipelined":
        return engine.PipelinedEvaluator(model.make_eval_fn(params, batch),
                                         pad_to=3, prefetch=prefetch)
    if backend == "suffix":
        ctx = {"params": params,
               "batch": {k: np.asarray(v) for k, v in batch.items()}}
        return engine.make_evaluator("suffix",
                                     split=model.make_suffix_eval_fns(),
                                     context=ctx, pad_to=3,
                                     prefetch=prefetch)
    raise AssertionError(backend)


def _run(model, params, batch, masks0, evaluator, moves,
         proposal="uniform"):
    total = M.count(masks0)
    cfg = bcd.BCDConfig(b_target=total - 3 * 16, drc=16, rt=6, adt=0.5,
                        finetune_every_step=False, seed=3, chunk_size=3,
                        moves=moves, proposal=proposal)
    eval_acc = model.make_eval_acc(params, batch)
    return bcd.run_bcd(masks0, cfg, eval_acc, evaluator=evaluator)


def _assert_same_result(a, b):
    for k in a.masks:
        np.testing.assert_array_equal(a.masks[k], b.masks[k])
    assert len(a.history) == len(b.history)
    for ha, hb in zip(a.history, b.history):
        assert (ha.trials, ha.found_early, ha.move_kind) == \
            (hb.trials, hb.found_early, hb.move_kind)
        assert ha.best_drop == pytest.approx(hb.best_drop, abs=1e-4)
        assert (ha.budget_before, ha.budget_after) == \
            (hb.budget_before, hb.budget_after)
    assert a.move_stats == b.move_stats


@pytest.fixture(scope="module")
def seq_ref(setup):
    """Memoized sequential reference per (moves, proposal) — every matrix
    cell compares against the same run."""
    model, params, batch, masks0 = setup
    cache = {}

    def ref(moves, proposal="uniform"):
        key = (tuple(moves), proposal)
        if key not in cache:
            cache[key] = _run(model, params, batch, masks0,
                              _make_ev("sequential", model, params, batch),
                              moves, proposal)
        return cache[key]
    return ref


# ----------------------------------------------- the conformance matrix


@pytest.mark.parametrize("backend", BACKENDS[1:])
@pytest.mark.parametrize("kind", M.MOVE_KINDS)
def test_backend_matches_sequential_per_kind(setup, seq_ref, backend, kind):
    """{batched, sharded, pipelined, suffix} × {remove, add_back, swap,
    stage_drop, share}: bit-identical masks, trial counts, early-exit flags
    and acceptance stats vs the sequential reference."""
    model, params, batch, masks0 = setup
    res = _run(model, params, batch, masks0,
               _make_ev(backend, model, params, batch), (kind,))
    _assert_same_result(seq_ref((kind,)), res)


@pytest.mark.parametrize("backend", BACKENDS[1:])
def test_backend_matches_sequential_mixed_sensitivity(setup, seq_ref,
                                                      backend):
    """All five kinds under the sensitivity-guided proposal: the kind draw
    and per-site weighting read only (rng, move_stats), so every backend
    replays the identical candidate stream."""
    model, params, batch, masks0 = setup
    res = _run(model, params, batch, masks0,
               _make_ev(backend, model, params, batch), MIXED,
               proposal="sensitivity")
    _assert_same_result(seq_ref(MIXED, "sensitivity"), res)


@pytest.mark.parametrize("prefetch", [0, 1, 2])
def test_suffix_mixed_moves_at_every_prefetch_depth(setup, seq_ref,
                                                    prefetch):
    """The suffix backend's site-major replay with typed multi-site moves,
    at prefetch 0 (strict), 1 (double-buffered) and 2."""
    model, params, batch, masks0 = setup
    res = _run(model, params, batch, masks0,
               _make_ev("suffix", model, params, batch, prefetch=prefetch),
               MIXED)
    _assert_same_result(seq_ref(MIXED), res)


# ------------------------------------ family matrix (SSM / RWKV / MoE)
#
# The same backend×move contract on recurrent and mixture-of-experts
# families: candidates cut the scanned stack mid-repeat (carry-checkpointed
# suffix prefixes) and, for MoE, flow through capacity-overflow token
# dropping — both must stay invisible to selection.

FAMILY_ARCHS = ("rwkv6_3b", "deepseek_moe_16b")
FAMILY_KINDS = ("remove", "swap", "stage_drop")


@pytest.fixture(scope="module")
def family_setup():
    from repro.configs.base import get_config
    from repro.models.lm import LM
    out = {}
    for arch in FAMILY_ARCHS:
        model = LM(get_config(arch).reduced())
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": np.random.default_rng(0).integers(
            0, model.cfg.vocab, (2, 17)).astype(np.int32)}
        masks0 = linearize.init_masks(model.mask_sites())
        out[arch] = (model, params, batch, masks0)
    return out


@pytest.fixture(scope="module")
def family_seq_ref(family_setup):
    cache = {}

    def ref(arch, moves):
        key = (arch, tuple(moves))
        if key not in cache:
            model, params, batch, masks0 = family_setup[arch]
            cache[key] = _run(model, params, batch, masks0,
                              _make_ev("sequential", model, params, batch),
                              moves)
        return cache[key]
    return ref


@pytest.mark.parametrize("backend", BACKENDS[1:])
@pytest.mark.parametrize("kind", FAMILY_KINDS)
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_backend_matches_sequential_per_kind(family_setup,
                                                    family_seq_ref, arch,
                                                    backend, kind):
    """{rwkv6, deepseek-moe} × {batched, sharded, pipelined, suffix} ×
    {remove, swap, stage_drop}: bit-identical masks, trial counts and
    early-exit flags vs the per-family sequential reference."""
    model, params, batch, masks0 = family_setup[arch]
    res = _run(model, params, batch, masks0,
               _make_ev(backend, model, params, batch), (kind,))
    _assert_same_result(family_seq_ref(arch, (kind,)), res)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_suffix_mixed_moves(family_setup, family_seq_ref, arch):
    """All three kinds in one descent on the suffix backend — mid-scan
    stack cuts and head/shared sites interleave in the candidate stream."""
    model, params, batch, masks0 = family_setup[arch]
    res = _run(model, params, batch, masks0,
               _make_ev("suffix", model, params, batch), FAMILY_KINDS)
    _assert_same_result(family_seq_ref(arch, FAMILY_KINDS), res)


_FAMILY_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.configs.base import get_config
from repro.core import bcd, engine, linearize, masks as M
from repro.launch import mesh as mesh_lib
from repro.models.lm import LM

for arch in ("rwkv6_3b", "deepseek_moe_16b"):
    model = LM(get_config(arch).reduced())
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": np.random.default_rng(0).integers(
        0, model.cfg.vocab, (2, 17)).astype(np.int32)}
    masks0 = linearize.init_masks(model.mask_sites())
    cfg = bcd.BCDConfig(b_target=M.count(masks0) - 2 * 16, drc=16, rt=6,
                        adt=0.5, finetune_every_step=False, seed=3,
                        chunk_size=3, moves=("remove", "swap", "stage_drop"))
    eval_acc = model.make_eval_acc(params, batch)
    seq = bcd.run_bcd(masks0, cfg, eval_acc,
                      evaluator=engine.SequentialEvaluator(eval_acc))
    mesh = mesh_lib.make_candidate_mesh()
    assert len(mesh.devices.reshape(-1)) == 4, mesh
    shd = bcd.run_bcd(masks0, cfg, eval_acc,
                      evaluator=engine.ShardedEvaluator(
                          model.make_eval_fn(params, batch), mesh, pad_to=3))
    for k in seq.masks:
        np.testing.assert_array_equal(seq.masks[k], shd.masks[k])
    assert [(h.trials, h.found_early, h.move_kind) for h in seq.history] \
        == [(h.trials, h.found_early, h.move_kind) for h in shd.history]
    assert seq.move_stats == shd.move_stats
    print(arch, "FAMILY_SHARDED_OK")
"""


def test_family_moves_on_forced_multi_device_mesh():
    """SSM + MoE mixed-kind descent on 4 forced host devices: candidate-
    axis sharding over scanned-stack masks selects the identical moves as
    the sequential reference."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _FAMILY_SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.count("FAMILY_SHARDED_OK") == 2


_MOVES_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import bcd, engine, linearize, masks as M
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import mesh as mesh_lib
from repro.models.resnet import CNN, CNNConfig

model = CNN(CNNConfig("tiny", 4, 8, ((4, 1, 1),), stem_channels=4))
data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=8,
                                       n_train=64, n_test=32))
params = model.init(jax.random.PRNGKey(0))
batch = data.train_eval_set(16)
masks0 = linearize.init_masks(model.mask_sites())
cfg = bcd.BCDConfig(b_target=M.count(masks0) - 2 * 8, drc=8, rt=6, adt=0.5,
                    finetune_every_step=False, seed=3, chunk_size=3,
                    moves=M.MOVE_KINDS, proposal="sensitivity")
eval_acc = model.make_eval_acc(params, batch)
seq = bcd.run_bcd(masks0, cfg, eval_acc,
                  evaluator=engine.SequentialEvaluator(eval_acc))
mesh = mesh_lib.make_candidate_mesh()
assert len(mesh.devices.reshape(-1)) == 4, mesh
shd = bcd.run_bcd(masks0, cfg, eval_acc,
                  evaluator=engine.ShardedEvaluator(
                      model.make_eval_fn(params, batch), mesh, pad_to=3))
for k in seq.masks:
    np.testing.assert_array_equal(seq.masks[k], shd.masks[k])
assert [h.move_kind for h in seq.history] == \
    [h.move_kind for h in shd.history]
assert seq.move_stats == shd.move_stats
print("MOVES_SHARDED_OK")
"""


def test_mixed_moves_on_forced_multi_device_mesh():
    """Real candidate-axis sharding: mixed-kind descent on 4 forced host
    devices selects the identical moves as the sequential reference."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MOVES_SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MOVES_SHARDED_OK" in out.stdout


# ------------------------------------------------------ the move algebra


def _grid_masks():
    # two ResNet-style stages + one non-stage site; 2D so share has a
    # last-axis driver structure to respect
    return {"g0b0.relu1": np.ones((3, 4), np.float32),
            "g0b1.relu2": np.ones((2, 4), np.float32),
            "g1b0.relu1": np.ones((2, 6), np.float32),
            "stem.relu": np.ones((4,), np.float32)}


def test_swap_equals_add_back_after_remove():
    masks = _grid_masks()
    flat, _ = M._flatten(masks)
    off, on = np.array([1, 7, 20]), np.array([5])
    flat0 = flat.copy()
    flat0[5] = 0.0                      # make `on` actually inactive
    via_swap = M.Move.swap(off, on).apply_flat(flat0)
    via_pair = M.Move.add_back(on).apply_flat(
        M.Move.remove(off).apply_flat(flat0))
    np.testing.assert_array_equal(via_swap, via_pair)


def test_moves_bill_exactly_minus_drc():
    """Every sampled move nets exactly -drc billable ReLUs (stage_drop up
    to max_remove), for every kind, across a whole descent's mask states."""
    masks = _grid_masks()
    rng = np.random.default_rng(0)
    flat, layout = M._flatten(masks)
    drc, max_remove = 3, 9
    for _ in range(12):
        for kind in M.MOVE_KINDS:
            moves = M.sample_moves(rng, M._unflatten(flat, layout), drc, 4,
                                   kinds=(kind,), max_remove=max_remove)
            for mv in moves:
                d = mv.billable_delta(flat)
                if kind == "stage_drop":
                    assert -max_remove <= d <= -drc, (kind, d)
                else:
                    assert d == -drc, (kind, d)
        # advance the state like a descent step would
        flat = M.sample_moves(rng, M._unflatten(flat, layout), drc, 1,
                              kinds=("share",))[0].apply_flat(flat)
        if int(np.sum(flat > 0.9)) <= max_remove + drc:
            break


def test_moves_never_touch_outside_layout_or_resurrect_active():
    masks = _grid_masks()
    rng = np.random.default_rng(1)
    flat, layout = M._flatten(masks)
    flat[::3] = 0.0                     # a third of the grid already off
    tree = M._unflatten(flat, layout)
    for kind in M.MOVE_KINDS:
        for mv in M.sample_moves(rng, tree, 2, 8, kinds=(kind,),
                                 max_remove=5):
            t = mv.touched()
            assert t.size and t.min() >= 0 and t.max() < flat.size
            assert np.all(flat[mv.off] > 0.9)       # offs were billable
            assert np.all(flat[mv.on] <= 0.5)       # ons were inactive
            assert np.all(flat[mv.tie] > 0.9)       # ties were billable


def test_share_ties_have_billable_driver_and_no_chains():
    masks = _grid_masks()
    rng = np.random.default_rng(2)
    flat, layout = M._flatten(masks)
    for _ in range(8):
        mv = M.sample_moves(rng, M._unflatten(flat, layout), 4, 1,
                            kinds=("share",))[0]
        out = mv.apply_flat(flat)
        for idx in mv.tie.tolist():
            assert out[idx - 1] > 0.9   # driver stays a full ReLU
        flat = out


def test_pi_cost_of_share_tied_mask_bills_driver_relus_only():
    masks = _grid_masks()
    rng = np.random.default_rng(3)
    mv = M.sample_moves(rng, masks, 5, 1, kinds=("share",))[0]
    tied = M.apply_move(masks, mv)
    drivers = M.relu_cost(tied)
    assert drivers == M.count(tied) - M.tied_count(tied)
    got = pi_cost.cost_of_masks(tied, n_nonlinear_layers=len(tied))
    want = pi_cost.cost(drivers, len(tied))
    assert got == want
    # ties are free, gates are kept: cheaper than count, costlier than none
    assert got.online_bytes < pi_cost.cost(M.count(tied), len(tied)).online_bytes


def test_share_forward_is_bitwise_inert_on_binary_masks():
    """_apply_share_ties with an all-binary mask must be the identity on
    the blended output — the pre-move-vocabulary forward, bit for bit."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8)))
    site = linearize.MaskSite(shape=(3, 8))
    mask = (np.arange(24).reshape(3, 8) % 2).astype(np.float32)
    out = linearize.apply_masked_act(x, mask, site)
    want = mask * np.maximum(x, 0.0) + (1.0 - mask) * x
    np.testing.assert_array_equal(np.asarray(out), want.astype(np.float32))


def test_share_forward_reuses_driver_sign():
    """A tied coordinate keeps its gate but gates on the *driver's* sign:
    out = x * H(x_prev) at tied coords, untouched elsewhere."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (4, 8)))
    site = linearize.MaskSite(shape=(8,))
    mask = np.ones((8,), np.float32)
    mask[3] = M.TIE
    out = np.asarray(linearize.apply_masked_act(x, mask, site))
    want = np.maximum(x, 0.0)
    want[:, 3] = x[:, 3] * (x[:, 2] > 0)
    np.testing.assert_allclose(out, want, atol=1e-6)


if HAS_HYPOTHESIS:
    @st.composite
    def _flat_and_move(draw):
        n = draw(st.integers(8, 40))
        bits = draw(st.lists(st.integers(0, 1), min_size=n, max_size=n))
        flat = np.asarray(bits, np.float32)
        coords = draw(st.lists(st.integers(0, n - 1), min_size=1,
                               max_size=6, unique=True))
        split = draw(st.integers(0, len(coords)))
        return flat, np.asarray(coords[:split] or coords[:1],
                                dtype=np.int64), \
            np.asarray(coords[split:] or coords[-1:], dtype=np.int64)

    @given(fm=_flat_and_move())
    @settings(max_examples=60, deadline=None)
    def test_swap_decomposition_property(fm):
        """swap(off, on) ≡ add_back(on) ∘ remove(off) on any flat state —
        the move algebra is purely set-valued."""
        flat, off, on = fm
        if set(off.tolist()) & set(on.tolist()):
            return
        via_swap = M.Move.swap(off, on).apply_flat(flat)
        via_pair = M.Move.add_back(on).apply_flat(
            M.Move.remove(off).apply_flat(flat))
        np.testing.assert_array_equal(via_swap, via_pair)

    @given(seed=st.integers(0, 2 ** 20), drc=st.integers(1, 5),
           kind=st.sampled_from(M.MOVE_KINDS))
    @settings(max_examples=60, deadline=None)
    def test_sampled_moves_stay_in_layout_property(seed, drc, kind):
        masks = _grid_masks()
        rng = np.random.default_rng(seed)
        flat, layout = M._flatten(masks)
        flat[rng.random(flat.size) < 0.3] = 0.0
        tree = M._unflatten(flat, layout)
        mv = M.sample_moves(rng, tree, drc, 1, kinds=(kind,),
                            max_remove=2 * drc)[0]
        t = mv.touched()
        assert t.min() >= 0 and t.max() < flat.size
        assert np.all(flat[mv.on] <= 0.5)
        assert -2 * drc <= mv.billable_delta(flat) <= -min(
            drc, int(np.sum(flat > 0.9)))

    @given(seed=st.integers(0, 2 ** 20))
    @settings(max_examples=40, deadline=None)
    def test_share_pi_cost_identity_property(seed):
        masks = _grid_masks()
        rng = np.random.default_rng(seed)
        mv = M.sample_moves(rng, masks, int(rng.integers(1, 6)), 1,
                            kinds=("share",))[0]
        tied = M.apply_move(masks, mv)
        assert pi_cost.cost_of_masks(tied, 4).relus == M.relu_cost(tied)


# ------------------------------------------ engine regression (satellite 3)


def _suffix_ev(model, params, batch, **kw):
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    return engine.make_evaluator("suffix",
                                 split=model.make_suffix_eval_fns(),
                                 context=ctx, **kw)


def test_two_segment_move_never_straddles_sited_chunks(setup):
    """A swap whose rider removals touch a shallower segment than its
    (off, on) exchange must be planned at the *shallower* segment — a
    sited chunk at the deep cut would read the candidate's edited shallow
    mask through the cached prefix."""
    model, params, batch, masks0 = setup
    split = model.make_suffix_eval_fns()
    order_sites = model.site_order()
    shallow, deep = order_sites[0], order_sites[-1]
    flat, layout = M._flatten(masks0)
    site_off = {k: (off, n) for k, off, n, _ in layout}
    so, sn = site_off[shallow]
    do, dn = site_off[deep]
    # candidate 0: pure deep removal; candidate 1: deep swap with a shallow
    # rider; candidate 2: deep removal again (same group as 0 if the
    # straddling candidate were misgrouped, it would split this group)
    moves = [
        M.Move.remove(np.arange(do, do + 4)),
        M.Move.swap(np.array([do + 8, so + 1]), np.array([])),
        M.Move.remove(np.arange(do + 4, do + 8)),
    ]
    ranks = M.move_site_ranks(moves, layout, split.site_segment)
    assert ranks[0] == ranks[2] == split.site_segment[deep]
    assert ranks[1] == split.site_segment[shallow]
    # force suffix mode for every sited chunk — the fallback path would
    # make the straddling check vacuous
    from repro.analysis.roofline import SuffixCostModel
    ev = _suffix_ev(model, params, batch, pad_to=3,
                    cost_model=SuffixCostModel(min_prefix_fraction=0.0,
                                               min_chunk=1))
    ev.begin_step(masks0)
    order, chunks = engine.plan_sited_chunks(ev, moves, layout,
                                             chunk_size=3)
    assert any(site is not None for site, _, _ in chunks)
    seen = set()
    for site, s, e in chunks:
        sel = order[s:e]
        seen.update(int(i) for i in sel)
        if site is None:
            continue
        seg = split.site_segment[site]
        for i in sel:
            assert ranks[int(i)] == seg, \
                f"candidate {int(i)} (cut {ranks[int(i)]}) landed in a " \
                f"chunk sited at segment {seg}"
    assert seen == {0, 1, 2}
    # and the materialized chunks agree with per-move application
    for chunk in engine.materialize_sited(flat, layout, moves, order,
                                          chunks):
        assert isinstance(chunk, engine.SitedChunk)


def test_begin_step_invalidates_to_shallower_touched_segment(setup):
    """After a two-segment accepted move, the prefix trie must drop every
    entry deeper than the *shallower* touched segment — a prefix cut
    between the two sites reads the shallower site's edited mask."""
    model, params, batch, masks0 = setup
    split = model.make_suffix_eval_fns()
    sites = model.site_order()
    mid, deep = sites[len(sites) // 2], sites[-1]
    segs = split.site_segment
    assert segs[mid] < segs[deep]
    ev = _suffix_ev(model, params, batch, pad_to=4)
    ev.begin_step(masks0)
    rng = np.random.default_rng(0)
    for site in (mid, deep):
        idx = M.sample_removal_indices_within(rng, masks0, 8, 4, [site])
        ev.evaluate(engine.SitedChunk(site, M.materialize_candidates(
            masks0, idx)))
    assert segs[mid] in ev.trie and segs[deep] in ev.trie
    # accept a swap touching BOTH segments: deep (off, on) + mid rider
    flat, layout = M._flatten(masks0)
    site_off = {k: (off, n) for k, off, n, _ in layout}
    mo, _ = site_off[mid]
    do, _ = site_off[deep]
    mv = M.Move.swap(np.array([do + 1, mo + 2]), np.array([]))
    ev.begin_step(M.apply_move(masks0, mv))
    assert segs[deep] not in ev.trie, \
        "deep prefix survived a shallower-site edit"
    assert segs[mid] in ev.trie, \
        "the mid-segment prefix reads only shallower masks and must survive"
