"""Property tests for the mask-tree algebra (hypothesis).

hypothesis is an optional dev dep (pip extra: test); the property tests are
guarded so a bare environment still collects and runs the deterministic
tests.  Deterministic coverage of the same utilities (threshold, IoU,
stacked-tree helpers) lives in tests/test_mask_utils.py.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import masks as M


def _tree(seed, n_sites=3, max_dim=40):
    rng = np.random.default_rng(seed)
    return {f"s{i}": (rng.random(rng.integers(1, max_dim, size=2))
                      > 0.3).astype(np.float32)
            for i in range(n_sites)}


if HAS_HYPOTHESIS:
    @given(seed=st.integers(0, 10**6), drc=st.integers(1, 64))
    @settings(max_examples=30, deadline=None)
    def test_sample_removal_block_invariants(seed, drc):
        masks = _tree(seed)
        before = M.count(masks)
        rng = np.random.default_rng(seed + 1)
        cand = M.sample_removal_block(rng, masks, drc)
        after = M.count(cand)
        assert after == before - min(drc, before)    # removes exactly drc
        assert M.is_subset(cand, masks)              # eliminate-only
        assert M.count(masks) == before              # input untouched

    @given(seed=st.integers(0, 10**6), budget=st.integers(0, 500))
    @settings(max_examples=30, deadline=None)
    def test_threshold_exact_budget(seed, budget):
        rng = np.random.default_rng(seed)
        soft = {f"s{i}": rng.random((7, 11)).astype(np.float32)
                for i in range(3)}
        hard = M.threshold(soft, budget)
        assert M.count(hard) == min(budget, M.total_size(soft))
        # keeps the largest coordinates
        flat_soft = np.concatenate([soft[k].reshape(-1)
                                    for k in sorted(soft)])
        flat_hard = np.concatenate([hard[k].reshape(-1)
                                    for k in sorted(hard)])
        if 0 < budget < flat_soft.size:
            kept_min = flat_soft[flat_hard > 0.5].min()
            dropped_max = flat_soft[flat_hard < 0.5].max()
            assert kept_min >= dropped_max - 1e-7

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_iou_subset_is_one(seed):
        masks = _tree(seed)
        rng = np.random.default_rng(seed)
        sub = M.sample_removal_block(rng, masks, 5)
        assert M.intersection_over_union(sub, masks) == 1.0
        assert M.is_subset(sub, masks)

    @given(seed=st.integers(0, 10**6), drc=st.integers(1, 32),
           n=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_stacked_sampling_matches_sequential(seed, drc, n):
        """sample_removal_blocks row i == the i-th sequential call (same
        generator state) — the engine's backend-equivalence contract."""
        masks = _tree(seed)
        stacked = M.sample_removal_blocks(
            np.random.default_rng(seed + 1), masks, drc, n)
        rng = np.random.default_rng(seed + 1)
        for i in range(n):
            want = M.sample_removal_block(rng, masks, drc)
            got = M.index_stacked(stacked, i)
            for k in masks:
                np.testing.assert_array_equal(got[k], want[k])
else:
    def test_mask_properties():
        pytest.skip("hypothesis not installed (pip extra: test)")


def test_flatten_roundtrip():
    masks = _tree(0)
    flat, layout = M._flatten(masks)
    back = M._unflatten(flat, layout)
    for k in masks:
        np.testing.assert_array_equal(masks[k], back[k])


def test_per_site_counts_and_distribution():
    masks = {"a": np.ones((4, 4), np.float32),
             "b": np.zeros((3,), np.float32)}
    assert M.per_site_counts(masks) == {"a": 16, "b": 0}
    from repro.core import analysis
    dist = analysis.layer_distribution(masks)
    assert dist == {"a": (16, 16), "b": (0, 3)}
