"""Serving primitives: MaskSetStore, hot-swap decode, ragged cache_len.

Contracts under test (training.serve):

- ``MaskSetStore`` stacks named mask sets device-resident, hands back
  per-set slices shaped exactly like a single tree, validates site layouts
  loudly, and fingerprint-checks checkpointed sets loaded from a sweep run
  directory;
- mask hot-swap is a pure argument substitution: one compiled decode step
  serves every budget, bitwise-identical to a dedicated per-budget trace;
- ``cache_len`` may be a ``(B,)`` vector (continuous batching): each slot
  decodes at its own position, matching per-request B=1 decodes;
- the sharded decode path (``jit_decode_step``) agrees with single-device
  decode on a forced-multi-device mesh (subprocess, like
  test_bcd_parallel).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import masks as M, pi_cost, runner as runner_lib
from repro.models.lm import LM
from repro.training import serve as serve_lib


# ------------------------------------------------------------ MaskSetStore


SHAPES = {"a": (6,), "b": (2, 4)}


def _sets():
    rng = np.random.default_rng(0)
    full = M.full_masks(SHAPES)
    soft = {k: rng.random(v.shape).astype(np.float32)
            for k, v in full.items()}
    total = M.count(full)
    return {"hi": M.threshold(soft, total), "lo": M.threshold(soft,
                                                              total // 2)}


def test_store_stacks_and_selects():
    sets = _sets()
    store = serve_lib.MaskSetStore(SHAPES, sets)
    assert store.names == ("hi", "lo")
    for name in store.names:
        sel = store.select(name)
        assert set(sel) == set(SHAPES)
        for k in sel:
            assert isinstance(sel[k], jnp.ndarray)
            assert sel[k].shape == SHAPES[k]
            np.testing.assert_array_equal(np.asarray(sel[k]), sets[name][k])
        info = store.info(name)
        assert info.relu_cost == M.relu_cost(sets[name])
        assert info.fingerprint == M.fingerprint(sets[name])
    assert store.info("hi").relu_cost > store.info("lo").relu_cost


def test_store_pi_cost_per_token_matches_cost_of_masks():
    store = serve_lib.MaskSetStore(SHAPES, _sets())
    got = store.pi_cost_per_token("lo")
    want = pi_cost.cost_of_masks(store.host("lo"), len(SHAPES))
    assert got == want


def test_store_rejects_layout_mismatch():
    good = _sets()["hi"]
    for bad, needle in [
            ({"a": good["a"]}, "missing site 'b'"),
            ({**good, "c": np.ones(3, np.float32)}, "unknown site 'c'"),
            ({**good, "a": np.ones(7, np.float32)}, "model wants (6,)")]:
        with pytest.raises(serve_lib.MaskSetError, match="site layout"):
            serve_lib.MaskSetStore(SHAPES, {"x": bad})
        problems = serve_lib.validate_site_layout(SHAPES, bad)
        assert any(needle in p for p in problems), (needle, problems)
    with pytest.raises(serve_lib.MaskSetError, match="at least one"):
        serve_lib.MaskSetStore(SHAPES, {})


def _save_stage(run_dir, name, masks):
    d = os.path.join(run_dir, name, "final")
    runner_lib.save_stage_init(d, {"kind": "bcd", "masks": masks})
    return d


def test_store_from_run_dir_loads_and_fingerprints(tmp_path):
    sets = _sets()
    _save_stage(str(tmp_path), "stage_00_b24", sets["hi"])
    _save_stage(str(tmp_path), "stage_01_b12", sets["lo"])
    store = serve_lib.MaskSetStore.from_run_dir(str(tmp_path), SHAPES)
    assert store.names == ("b24", "b12")
    for name, src in (("b24", "hi"), ("b12", "lo")):
        np.testing.assert_array_equal(store.host(name)["a"],
                                      sets[src]["a"])
        assert store.info(name).source.endswith("final")
    # restricting names works; asking for an absent set fails loudly
    only = serve_lib.MaskSetStore.from_run_dir(str(tmp_path), SHAPES,
                                               names=["b12"])
    assert only.names == ("b12",)
    with pytest.raises(serve_lib.MaskSetError, match="not found"):
        serve_lib.MaskSetStore.from_run_dir(str(tmp_path), SHAPES,
                                            names=["b999"])


def test_store_from_run_dir_rejects_tampered_masks(tmp_path):
    sets = _sets()
    final = _save_stage(str(tmp_path), "stage_00_b24", sets["hi"])
    # overwrite one mask leaf after the manifest was written: the content
    # hash no longer matches the recorded fingerprint
    step = os.path.join(final, "step_00000000")
    leaf = [f for f in os.listdir(step) if f.endswith(".npy")][0]
    arrs = np.load(os.path.join(step, leaf))
    np.save(os.path.join(step, leaf), np.zeros_like(arrs))
    with pytest.raises(runner_lib.CheckpointError):
        # deep validation catches the sha256 mismatch first
        runner_lib.load_stage_init(final, M.full_masks(SHAPES),
                                   masks_only=True)
    with pytest.raises(serve_lib.MaskSetError):
        serve_lib.MaskSetStore.from_run_dir(str(tmp_path), SHAPES)


def test_store_from_run_dir_rejects_wrong_model_layout(tmp_path):
    other = {"a": np.ones((9,), np.float32), "b": np.ones((2, 4),
                                                          np.float32)}
    _save_stage(str(tmp_path), "stage_00_b17", other)
    with pytest.raises(serve_lib.MaskSetError,
                       match="different site layout"):
        serve_lib.MaskSetStore.from_run_dir(str(tmp_path), SHAPES)


def test_store_from_run_dir_empty_is_clear(tmp_path):
    with pytest.raises(serve_lib.MaskSetError, match="no completed sweep"):
        serve_lib.MaskSetStore.from_run_dir(str(tmp_path), SHAPES)


# ----------------------------------------------------- decode-step contracts


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shapes = {k: s.shape for k, s in model.mask_sites().items()}
    full = M.full_masks(shapes)
    rng = np.random.default_rng(1)
    soft = {k: rng.random(v.shape).astype(np.float32)
            for k, v in full.items()}
    sets = {"full": full, "half": M.threshold(soft, M.count(full) // 2)}
    store = serve_lib.MaskSetStore(shapes, sets)
    return cfg, model, params, store


def _prefill_then_decode(model, params, masks, prompt, cache, steps,
                         decode, swap_to=None, swap_at=None):
    """Greedy continuation; optionally hot-swap the mask tree mid-stream."""
    prefill = jax.jit(serve_lib.make_prefill(model))
    last, cache = prefill(params, masks[0] if isinstance(masks, list)
                          else masks, prompt, cache)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    P = prompt.shape[1]
    out = [np.asarray(tok)]
    m = masks[0] if isinstance(masks, list) else masks
    for t in range(steps):
        if swap_at is not None and t == swap_at:
            m = swap_to
        tok, cache = decode(params, m, tok, cache,
                            jnp.asarray(P + t, jnp.int32))
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1), cache


def test_hot_swap_is_bitwise_and_does_not_recompile(lm):
    """One compiled decode step serves every budget: swapping the mask tree
    mid-stream gives exactly the tokens a dedicated per-budget trace gives,
    and the swap adds no cache entry (masks are arguments, not constants)."""
    cfg, model, params, store = lm
    B, P, G = 2, 8, 6
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, P), dtype=np.int32))
    shared = jax.jit(serve_lib.make_decode_step(model))

    full, half = store.select("full"), store.select("half")
    toks_full, _ = _prefill_then_decode(
        model, params, full, prompt, model.init_cache(B, P + G + 1), G,
        shared)
    has_cache_api = hasattr(shared, "_cache_size")
    n_compiles = shared._cache_size() if has_cache_api else None
    toks_swap, _ = _prefill_then_decode(
        model, params, full, prompt, model.init_cache(B, P + G + 1), G,
        shared, swap_to=half, swap_at=3)
    if has_cache_api:
        assert shared._cache_size() == n_compiles   # swap never re-jits

    # the swapped stream's prefix is bitwise the full-budget stream
    np.testing.assert_array_equal(toks_swap[:, :4], toks_full[:, :4])
    # and from the swap on it is bitwise what a dedicated half-budget
    # decode produces from the same cache state
    dedicated = jax.jit(serve_lib.make_decode_step(model))
    toks_half, _ = _prefill_then_decode(
        model, params, [full], prompt, model.init_cache(B, P + G + 1), G,
        dedicated, swap_to=half, swap_at=3)
    np.testing.assert_array_equal(toks_swap, toks_half)


def test_vector_cache_len_matches_scalar(lm):
    """A (B,) cache_len vector with equal entries computes the same decode
    forward as the scalar path (different HLO, so allclose — bf16)."""
    cfg, model, params, store = lm
    masks = store.select("full")
    B, P, G = 2, 6, 3
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, P), dtype=np.int32))
    max_len = P + G + 2
    prefill = jax.jit(serve_lib.make_prefill(model))
    _, cache = prefill(params, masks, prompt, model.init_cache(B, max_len))
    cache = jax.tree.map(np.asarray, cache)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1), dtype=np.int32))

    fwd = jax.jit(lambda p, m, t, c, cl: model.forward(p, m, t, cache=c,
                                                       cache_len=cl))
    ls, cs = fwd(params, masks, tok, jax.tree.map(jnp.asarray, cache),
                 jnp.asarray(P, jnp.int32))
    lv, cv = fwd(params, masks, tok, jax.tree.map(jnp.asarray, cache),
                 jnp.full((B,), P, jnp.int32))
    np.testing.assert_allclose(np.asarray(ls, np.float32),
                               np.asarray(lv, np.float32),
                               rtol=2e-2, atol=5e-2)
    for a, b in zip(jax.tree.leaves(cs), jax.tree.leaves(cv)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=5e-2)


def test_ragged_rows_are_independent_bitwise(lm):
    """Continuous batching's correctness core: at fixed B, a slot's decode
    stream is bitwise independent of what the other slots hold.  A request
    served next to a neighbor produces exactly the tokens it produces with
    that slot empty — same graph, same shapes, row-local values."""
    cfg, model, params, store = lm
    masks = store.select("full")
    B, G, max_len = 2, 3, 12
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, (1, p), dtype=np.int32)
               for p in (6, 4)]
    decode = jax.jit(serve_lib.make_decode_step(model))
    insert = jax.jit(serve_lib.make_insert_slot(model))
    prefill = jax.jit(serve_lib.make_prefill(model))

    def run(live):
        """Decode G steps with the requests in ``live`` occupying their
        slots (others left at the zero-init cache)."""
        big = model.init_cache(B, max_len)
        tok = np.zeros((B,), np.int32)
        cl = np.zeros((B,), np.int32)
        for i in live:
            p = prompts[i]
            small = model.init_cache(1, max_len)
            last, small = prefill(params, masks, jnp.asarray(p), small)
            big = insert(big, small, jnp.asarray(i, jnp.int32))
            tok[i] = int(jnp.argmax(last, -1)[0])
            cl[i] = p.shape[1]
        out = {i: [int(tok[i])] for i in live}
        for _ in range(G):
            nxt, big = decode(params, masks, jnp.asarray(tok[:, None]),
                              big, jnp.asarray(cl))
            tok = np.asarray(nxt).reshape(-1)
            cl += 1
            for i in live:
                out[i].append(int(tok[i]))
        return out

    both = run([0, 1])
    assert run([0])[0] == both[0]
    assert run([1])[1] == both[1]


_SHARDED_SERVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.core import linearize, masks as M
from repro.launch.mesh import make_host_mesh
from repro.models.lm import LM
from repro.training import serve as serve_lib

cfg = get_config("stablelm_1p6b").reduced()
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))
masks = M.as_device(linearize.init_masks(model.mask_sites()))
B, P, G = 4, 6, 4
max_len = P + G + 1
rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, P), dtype=np.int32))
prefill = jax.jit(serve_lib.make_prefill(model))
cache0 = model.init_cache(B, max_len)
last, cache0 = prefill(params, masks, prompt, cache0)
tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
cache0 = jax.tree.map(np.asarray, cache0)

def run(decode, vec):
    tok, cache = tok0, jax.tree.map(jnp.asarray, cache0)
    out = [np.asarray(tok)]
    for t in range(G):
        cl = np.full((B,), P + t, np.int32) if vec else P + t
        tok, cache = decode(params, masks, tok, cache,
                            jnp.asarray(cl, jnp.int32))
        out.append(np.asarray(tok))
    return np.concatenate(out, 1)

single = run(jax.jit(serve_lib.make_decode_step(model)), vec=True)
mesh = make_host_mesh(4, 1)
assert mesh.size == 4, mesh
scfg = serve_lib.ServeCfg(dp_axes=("data",), max_len=max_len, batch=B)
model.activation_spec = None
with mesh:
    sharded = run(serve_lib.jit_decode_step(model, mesh, scfg), vec=True)
np.testing.assert_array_equal(single, sharded)
print("SERVE_SHARDED_OK")
"""


def test_sharded_decode_matches_single_device_forced_multi_device():
    """jit_decode_step's production cache shardings, 4 forced host devices,
    vector cache_len: tokens identical to single-device decode."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SERVE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SERVE_SHARDED_OK" in out.stdout
