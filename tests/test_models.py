"""Per-architecture smoke tests (REQUIRED deliverable): every assigned arch at
a reduced config runs one forward + one train step on CPU — output shapes
checked, no NaNs — plus decode==prefill consistency per cache family."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import linearize, masks as M
from repro.models.lm import LM
from repro.training import optimizer as opt_lib, train as train_lib


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    text = S - cfg.prefix_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, text), dtype=np.int32)),
        "labels": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, text), dtype=np.int32))}
    if cfg.prefix_len:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)) * 0.02,
            jnp.float32)

    logits, _ = model.forward(params, masks, batch["tokens"],
                              prefix_embeds=batch.get("prefix_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    opt = opt_lib.adamw(lr=1e-3, grad_clip=1.0)
    step = train_lib.make_train_step(
        model, opt, train_lib.TrainStepCfg(remat=False, dp_axes=()))
    state = train_lib.make_state(model, opt, jax.random.PRNGKey(1))
    state, metrics = jax.jit(step)(state, batch, masks)
    assert bool(jnp.isfinite(metrics["loss"])), arch_id
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch_id
    assert int(state["step"]) == 1
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state["params"]),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch_id", ["stablelm_1p6b", "rwkv6_3b",
                                     "zamba2_2p7b", "deepseek_moe_16b"])
def test_decode_matches_full_forward(arch_id):
    cfg = get_config(arch_id).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))
    ref, _ = model.forward(params, masks, toks)
    cache = model.init_cache(B, S)
    lp, cache = model.forward(params, masks, toks[:, :8], cache=cache,
                              cache_len=0)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ref[:, :8], np.float32),
                               rtol=2e-3, atol=2e-3)
    outs = []
    for t in range(8, S):
        lt, cache = model.forward(params, masks, toks[:, t:t + 1],
                                  cache=cache, cache_len=t)
        outs.append(lt)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref[:, 8:], np.float32),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("dispatch", ["scatter", "gather"])
def test_moe_routing_stacked_vs_sequential_bitwise_under_overflow(dispatch):
    """Capacity-overflow token dropping must be deterministic under vmapped
    candidate stacking: evaluating N mask candidates as one stacked vmap
    must combine expert outputs bitwise-identically to N sequential calls.
    (Regression: the scatter-dispatch combine used a duplicate-index
    scatter-add whose accumulation order XLA leaves unspecified, so the
    stacked and per-candidate lowerings could sum a token's top-k expert
    outputs in different orders.)"""
    from repro.models import moe as moe_lib
    c = moe_lib.MoECfg(d_model=16, n_experts=4, top_k=2, d_ff_expert=8,
                       capacity_factor=0.5, dispatch=dispatch)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), c, dtype=jnp.float32)
    # skew the router so expert 0 oversubscribes its capacity and tokens
    # actually drop — the overflow path is the one under test
    p["router"] = p["router"].at[:, 0].add(3.0)
    B, S = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, c.d_model))
    assert S * c.top_k > c.n_experts * moe_lib._capacity(c, S) // 2
    site = linearize.MaskSite((c.n_experts, c.d_ff_expert), "relu")
    rng = np.random.default_rng(0)
    stacked = jnp.asarray(
        (rng.random((6, c.n_experts, c.d_ff_expert)) > 0.3)
        .astype(np.float32))

    def one(m):
        return moe_lib.moe_ffn(p, c, x, m, site)

    batched = jax.jit(jax.vmap(one))(stacked)
    seq = jnp.stack([jax.jit(one)(stacked[i])
                     for i in range(stacked.shape[0])])
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(seq))
    # the same routing actually dropped tokens (overflow was exercised)
    logits = x.astype(jnp.float32) @ p["router"]
    gates, slot_tk = moe_lib._route(
        logits[0], c, moe_lib._capacity(c, S))[0::2]
    assert bool((slot_tk == c.n_experts * moe_lib._capacity(c, S)).any()), \
        "test setup no longer overflows capacity"


def test_masks_change_output_but_zero_mask_keeps_linear_path():
    cfg = get_config("stablelm_1p6b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sites = model.mask_sites()
    ones = M.as_device(linearize.init_masks(sites))
    zeros = {k: jnp.zeros_like(v) for k, v in ones.items()}
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 16), dtype=np.int32))
    l1, _ = model.forward(params, ones, toks)
    l0, _ = model.forward(params, zeros, toks)
    assert bool(jnp.isfinite(l0).all())
    assert not np.allclose(np.asarray(l1, np.float32),
                           np.asarray(l0, np.float32))


def test_mask_budget_reduces_nonlinearity_count_consistently():
    cfg = get_config("rwkv6_3b").reduced()
    model = LM(cfg)
    masks = linearize.init_masks(model.mask_sites())
    total = M.count(masks)
    assert total == sum(int(np.prod(s.shape))
                        for s in model.mask_sites().values())
    hard = M.threshold({k: np.random.default_rng(0).random(v.shape)
                        .astype(np.float32) for k, v in masks.items()},
                       total // 2)
    assert M.count(hard) == total // 2
