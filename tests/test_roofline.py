"""Roofline machinery: collective parsing on known HLO snippets, and the
analytic FLOPs model validated against XLA cost_analysis on an UNROLLED
(scan-free) small model — the correction the scan-based dry-run relies on."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import roofline as rl
from repro.configs import get_config, ShapeCell
from repro.core import linearize, masks as M
from repro.models.lm import LM


def test_parse_collectives_counts_and_ring_bytes():
    hlo = """
ENTRY %main {
  %ar = f32[1024,256] all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[512,512] all-gather(%y), replica_groups=[16,16]<=[256]
}
"""
    st = rl.parse_collectives(hlo, 256)
    assert st.counts == {"all-reduce": 1, "all-gather": 1}
    ar = 2 * (1024 * 256 * 4) * (15 / 16) * 16
    ag = (512 * 512 * 2) * (15 / 16) * 16
    assert st.bytes_moved_global == pytest.approx(ar + ag)


def test_parse_collectives_loop_multiplier():
    hlo = """
%body.1 (p: (f32[8])) -> (f32[8]) {
  %ar = f32[64,64] all-reduce(%x), replica_groups=[4,4]<=[16]
}
ENTRY %main {
  %w = while(%init), condition=%cond.1, body=%body.1
  %ar2 = f32[64,64] all-reduce(%y), replica_groups=[4,4]<=[16]
}
"""
    st1 = rl.parse_collectives(hlo, 16, loop_trip_count=1)
    st10 = rl.parse_collectives(hlo, 16, loop_trip_count=10)
    assert st10.in_loop_count == 1
    one = (64 * 64 * 4) * 2 * (3 / 4) * 4
    assert st1.bytes_moved_global == pytest.approx(2 * one)
    assert st10.bytes_moved_global == pytest.approx(11 * one)


def test_analytic_flops_close_to_xla_on_unrolled_model():
    """Unroll the stack (pattern repeated, n_repeats==1 per tail trick is not
    enough — use a 2-layer config and compare against XLA's cost_analysis of
    the plain forward, which has no while loops at this size)."""
    cfg = dataclasses.replace(
        get_config("stablelm_1p6b"), n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, head_dim=64, d_ff=512, vocab=1024)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    B, S = 4, 128
    toks = jnp.zeros((B, S), jnp.int32)

    # forward-only, no remat, no scan benefit at R=2 — but scan still exists;
    # force unroll by comparing against per-layer analytic (mode='prefill')
    shape = ShapeCell("t", S, B, "prefill")
    flops_a, _ = rl.analytic_cell(cfg, shape, "prefill")

    def fwd(p, m, t):
        logits, _ = model.forward(p, m, t)
        return logits
    c = jax.jit(fwd).lower(params, masks, toks).compile()
    xla = float(rl.xla_cost(c).get("flops", 0.0))
    # XLA counts the scanned body once; correct by hand: body flops ≈
    # (xla_total - nonloop) ... instead compare against an R-scaled estimate:
    # with R=2 the undercount is bounded; assert analytic within [0.4x, 2.5x]
    assert 0.4 * xla <= flops_a <= 2.5 * xla, (flops_a, xla)


def test_analytic_flops_exact_on_unrolled_single_layer():
    """With n_layers == len(pattern) the stack has R=1 — no undercount —
    so analytic should match XLA closely (matmul-dominated regime)."""
    cfg = dataclasses.replace(
        get_config("stablelm_1p6b"), n_layers=1, d_model=512, n_heads=8,
        n_kv_heads=8, head_dim=64, d_ff=2048, vocab=8192)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    masks = M.as_device(linearize.init_masks(model.mask_sites()))
    B, S = 8, 512
    toks = jnp.zeros((B, S), jnp.int32)

    def fwd(p, m, t):
        logits, _ = model.forward(p, m, t)
        return logits
    c = jax.jit(fwd).lower(params, masks, toks).compile()
    xla = float(rl.xla_cost(c).get("flops", 0.0))
    shape = ShapeCell("t", S, B, "prefill")
    flops_a, _ = rl.analytic_cell(cfg, shape, "prefill")
    assert abs(flops_a - xla) / xla < 0.35, (flops_a, xla)


def test_model_flops_6nd():
    cfg = get_config("stablelm_1p6b")
    shape = ShapeCell("t", 4096, 256, "train")
    mf = rl.model_flops(cfg, shape, "train")
    n = rl.active_params(cfg)
    assert mf == pytest.approx(6 * n * 4096 * 256)
    # MoE counts only active experts
    moe = get_config("mixtral_8x22b")
    n_moe_active = rl.active_params(moe)
    # mixtral: top-2 of 8 -> active << total
    assert n_moe_active < 60e9


def test_roofline_bottleneck_and_fraction():
    r = rl.Roofline(arch="a", shape="s", mesh="m", chips=256,
                    flops_per_device=0, bytes_per_device=0,
                    collective_bytes_global=256 * 50e9,   # exactly 1s
                    model_flops_global=256 * rl.PEAK_FLOPS * 0.25,
                    analytic_flops_global=256 * rl.PEAK_FLOPS * 0.5,
                    analytic_bytes_global=1.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.t_compute == pytest.approx(0.5)
    assert r.bottleneck == "collective"
    assert r.roofline_fraction == pytest.approx(0.25)
    assert r.useful_flops_ratio == pytest.approx(0.5)


# --------------------------------------------- measured suffix cost model


def _hist_entry(chunk=8, site="deep.site", frac=0.75, sp=4.0,
                mode="suffix", **cfg):
    return {"config": {"chunk_size": chunk, **cfg},
            "per_site_depth": {"deep": {
                "site": site, "prefix_fraction": frac,
                "speedup_suffix_vs_batched": sp, "mode": mode}}}


def _write_hist(path, entries, *, junk=True):
    import json
    with open(path, "w") as fh:
        if junk:
            fh.write("not json at all\n\n[1, 2, 3]\n")
            # legacy PR-5-era line: summary keys only, no per_site_depth
            fh.write(json.dumps({"config": {"chunk_size": 8},
                                 "speedup_suffix_vs_batched": 4.0}) + "\n")
        for e in entries:
            fh.write(json.dumps(e) + "\n")


def test_cost_model_calibrated_missing_history_is_analytic(tmp_path):
    cm = rl.SuffixCostModel.calibrated(str(tmp_path / "nope.jsonl"))
    assert cm.measured is None
    assert cm.use_suffix(0.5, 8) and not cm.use_suffix(0.01, 8)


def test_cost_model_calibrated_ewma_and_fingerprint(tmp_path):
    p = str(tmp_path / "h.jsonl")
    _write_hist(p, [
        _hist_entry(sp=4.0, model="r18-mini"),
        _hist_entry(sp=2.0, model="r18-mini"),          # EWMA -> 3.0
        _hist_entry(sp=100.0, model="other"),           # filtered out
        _hist_entry(sp=100.0, mode="fallback"),         # not a measurement
    ])
    cm = rl.SuffixCostModel.calibrated(p, fingerprint={"model": "r18-mini"})
    assert cm.measured == ((0.75, 3.0, 8),)
    # fingerprint keys absent from an entry's config don't exclude it
    cm2 = rl.SuffixCostModel.calibrated(
        p, fingerprint={"model": "r18-mini", "n_devices": 1})
    assert cm2.measured == ((0.75, 3.0, 8),)
    # no fingerprint: the alien entry joins the EWMA
    cm3 = rl.SuffixCostModel.calibrated(p, fingerprint=None)
    assert cm3.measured is not None and cm3.measured[0][1] > 3.0


def test_cost_model_predicted_speedup_interpolates(tmp_path):
    cm = rl.SuffixCostModel(measured=((0.4, 2.0, 8), (0.8, 4.0, 8)))
    # exact measured point at its own chunk size
    assert cm.predicted_speedup(0.4, 8) == pytest.approx(2.0)
    assert cm.predicted_speedup(0.8, 8) == pytest.approx(4.0)
    # midpoint interpolates
    assert cm.predicted_speedup(0.6, 8) == pytest.approx(3.0, rel=0.2)
    # below the shallowest point: anchored at (0, 1)
    assert cm.predicted_speedup(0.0, 8) == pytest.approx(1.0)
    assert 1.0 < cm.predicted_speedup(0.2, 8) < 2.0
    # trie coverage only ever helps
    assert cm.predicted_speedup(0.8, 8, covered=0.8) > \
        cm.predicted_speedup(0.8, 8)
    # larger chunks amortize the prefix: analytic rescaling is monotone
    assert cm.predicted_speedup(0.8, 32) > cm.predicted_speedup(0.8, 8)


def test_cost_model_measured_decision_respects_margin():
    cm = rl.SuffixCostModel(measured=((0.75, 4.0, 8),), min_speedup=1.05)
    assert cm.use_suffix(0.75, 8)
    assert not cm.use_suffix(0.001, 8)     # interpolates to ~1.0 < margin
    assert not cm.use_suffix(0.75, 1)      # min_chunk still applies
    # a measured slowdown at depth turns suffix off where analytic says on
    slow = rl.SuffixCostModel(measured=((0.75, 0.9, 8),))
    assert not slow.use_suffix(0.75, 8)
    assert rl.SuffixCostModel().use_suffix(0.75, 8)   # analytic prior: on
