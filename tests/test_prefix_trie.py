"""PrefixTrie invariants + the suffix engine's trie lifetime.

The trie is the suffix backend's working set: device-resident prefix
activations keyed by cut-segment depth.  Under test here:

* **Lookup** returns the *deepest* cached ancestor at or above the
  requested depth (chain structure: depth d is an ancestor of every
  deeper entry).
* **Eviction** strictly respects the byte budget after every insert, is
  LRU-first with a shallow-first tie-break, and drops the just-inserted
  entry last.
* **Extension** — ``prefix_ext(a→b, prefix(a)) == prefix(b)`` bitwise at
  the model layer (both families), the contract that lets the engine
  fold only the segments between a cached ancestor and the cut.
* **Lifetime** — unchanged base masks keep entries across ``begin_step``;
  an edit at segment s drops exactly the depths > s; a byte budget small
  enough to thrash never changes selection (the trie is a pure cache).
"""
import numpy as np
import jax
import pytest

# hypothesis is an optional dev dep (pip extra: test) — bare environments
# must still collect/run the deterministic property sweep below, so only
# the @given tests are guarded.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.analysis.roofline import SuffixCostModel
from repro.configs.base import ArchConfig, Block
from repro.core import bcd, engine, linearize, masks as M
from repro.core.engine import PrefixTrie, tree_nbytes
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.lm import LM
from repro.models.resnet import CNN, CNNConfig


# ------------------------------------------------------------ unit level


def test_tree_nbytes_sums_leaves():
    t = {"a": np.zeros((4, 4), np.float32),
         "b": [np.zeros((2,), np.float16), np.zeros((3,), np.int32)]}
    assert tree_nbytes(t) == 4 * 4 * 4 + 2 * 2 + 3 * 4


def test_trie_lookup_returns_deepest_ancestor():
    t = PrefixTrie()
    t.insert(1, "p1", nbytes=1)
    t.insert(3, "p3", nbytes=1)
    assert t.lookup(0) is None
    assert t.lookup(1) == (1, "p1")
    assert t.lookup(2) == (1, "p1")
    assert t.lookup(3) == (3, "p3")
    assert t.lookup(9) == (3, "p3")
    assert t.depths() == (1, 3)
    assert 3 in t and 2 not in t and len(t) == 2


def test_trie_rejects_negative_budget():
    with pytest.raises(ValueError, match="budget_bytes"):
        PrefixTrie(budget_bytes=-1)


def test_trie_eviction_respects_budget_lru_then_shallow():
    t = PrefixTrie(budget_bytes=10)
    t.insert(1, "p1", nbytes=4)
    t.insert(2, "p2", nbytes=4)
    t.lookup(1)                      # touch depth 1 -> depth 2 becomes LRU
    t.insert(3, "p3", nbytes=4)      # over budget: evict LRU depth 2
    assert t.depths() == (1, 3) and t.total_bytes() == 8
    assert t.evictions == 1
    # just-inserted entry survives even when everything else must go
    t.insert(5, "p5", nbytes=9)
    assert t.depths() == (5,)
    # an entry that alone exceeds the budget is dropped too (caller keeps
    # the returned reference for in-flight dispatches)
    t.insert(6, "p6", nbytes=11)
    assert len(t) == 0
    assert t.total_bytes() == 0


def test_trie_eviction_tie_break_is_shallow_first():
    t = PrefixTrie(budget_bytes=8)
    t.insert(2, "p2", nbytes=4)
    t.insert(4, "p4", nbytes=4)
    # equal-tick ties are impossible (monotone clock); emulate "oldest
    # equally cold" by never touching either, then force one eviction:
    t.insert(6, "p6", nbytes=4)      # evicts depth 2 (oldest tick)
    assert t.depths() == (4, 6)


def test_trie_keep_where_and_clear():
    t = PrefixTrie()
    for d in (1, 2, 4):
        t.insert(d, f"p{d}", nbytes=1)
    t.keep_where(lambda d: d <= 2)
    assert t.depths() == (1, 2)
    t.clear()
    assert len(t) == 0 and t.total_bytes() == 0


def _check_invariants(trie, budget, mirror):
    """The two properties under test, against a dict mirror of inserts."""
    if budget is not None:
        assert trie.total_bytes() <= budget
    for probe in range(0, 12):
        got = trie.lookup(probe)
        live = [d for d in trie.depths() if d <= probe]
        if not live:
            assert got is None
        else:
            d = max(live)
            assert got == (d, mirror[d])


def _drive(ops, budget):
    trie = PrefixTrie(budget_bytes=budget)
    mirror = {}
    for op, depth, nbytes in ops:
        if op == "insert":
            mirror[depth] = f"v{depth}.{nbytes}"
            trie.insert(depth, mirror[depth], nbytes=nbytes)
        else:
            trie.lookup(depth)
        _check_invariants(trie, budget, mirror)


def test_trie_property_sweep_deterministic():
    """Seeded randomized op sequences: lookup always returns the deepest
    cached ancestor <= the probe, and total bytes never exceed the budget
    after any insert — runs even without hypothesis installed."""
    rng = np.random.default_rng(0)
    for case in range(50):
        budget = None if case % 5 == 0 else int(rng.integers(0, 40))
        ops = [("insert" if rng.random() < 0.6 else "lookup",
                int(rng.integers(0, 10)), int(rng.integers(1, 12)))
               for _ in range(rng.integers(1, 25))]
        _drive(ops, budget)


if HAS_HYPOTHESIS:
    @given(
        budget=st.one_of(st.none(), st.integers(0, 40)),
        ops=st.lists(st.tuples(st.sampled_from(["insert", "lookup"]),
                               st.integers(0, 10), st.integers(1, 12)),
                     min_size=1, max_size=30),
    )
    @settings(max_examples=60, deadline=None)
    def test_trie_property_lookup_and_budget(budget, ops):
        _drive(ops, budget)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_trie_property_lookup_and_budget():
        pass


# ---------------------------------------------- prefix-extension contract


def test_cnn_prefix_extension_bitwise():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    masks = M.sample_removal_block(np.random.default_rng(0), masks, 32)
    md = M.as_device(masks)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    order, segs = model.site_order(), model.site_segments()
    for a in order:
        pa = jax.jit(lambda p, m, x: model.forward_prefix(p, m, x, a))(
            params, md, x)
        for b in order:
            if segs[b] <= segs[a]:
                continue
            want = jax.jit(
                lambda p, m, x: model.forward_prefix(p, m, x, b))(
                    params, md, x)
            got = jax.jit(
                lambda p, m, c: model.forward_prefix(
                    p, m, None, b, from_site=a, cached=c))(params, md, pa)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"prefix_ext({a} -> {b}) != prefix({b})")


def test_lm_prefix_extension_bitwise():
    cfg = ArchConfig(
        name="tiny-ext", family="dense", n_layers=6, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=48, vocab=64, head_dim=16,
        pattern=(Block("dense"), Block("dense")),
        head_blocks=(Block("dense"),), dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    masks = linearize.init_masks(model.mask_sites())
    rng = np.random.default_rng(0)
    masks = M.sample_removal_block(rng, masks, 16)
    md = M.as_device(masks)
    tokens = np.asarray(rng.integers(0, cfg.vocab, (2, 9), dtype=np.int32))
    order, segs = model.site_order(), model.site_segments()
    for a in order:
        pa = jax.jit(lambda p, m, t: model.forward_prefix(p, m, t, a))(
            params, md, tokens)
        for b in order:
            if segs[b] <= segs[a]:
                continue
            want = jax.jit(
                lambda p, m, t: model.forward_prefix(p, m, t, b))(
                    params, md, tokens)
            got = jax.jit(
                lambda p, m, c: model.forward_prefix(
                    p, m, None, b, from_site=a, cached=c))(params, md, pa)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"prefix_ext({a} -> {b}) != prefix({b})")


# ----------------------------------------------- engine trie lifetime


@pytest.fixture(scope="module")
def setup():
    model = CNN(CNNConfig("tiny", 4, 16, ((8, 1, 1), (16, 1, 2)),
                          stem_channels=8))
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    batch = data.train_eval_set(128)
    masks0 = linearize.init_masks(model.mask_sites())
    return model, params, batch, masks0


def _suffix_ev(model, params, batch, **kw):
    ctx = {"params": params,
           "batch": {k: np.asarray(v) for k, v in batch.items()}}
    return engine.make_evaluator("suffix",
                                 split=model.make_suffix_eval_fns(),
                                 context=ctx, **kw)


def test_engine_extends_ancestor_instead_of_recomputing(setup):
    """Shallow-to-deep chunk order: the second sited chunk extends the
    first chunk's cached prefix (extension counter, not a second miss),
    and the accuracies still match the sequential reference."""
    model, params, batch, masks0 = setup
    order, segs = model.site_order(), model.site_segments()
    deep = order[-1]
    mid = max((s for s in order if segs[s] < segs[deep]),
              key=lambda s: segs[s])
    rng = np.random.default_rng(0)
    idx_mid = M.sample_removal_indices_within(rng, masks0, 16, 4, [mid])
    idx_deep = M.sample_removal_indices_within(rng, masks0, 16, 4, [deep])
    ev = _suffix_ev(model, params, batch, pad_to=4)
    seq = engine.SequentialEvaluator(model.make_eval_acc(params, batch))
    ev.begin_step(masks0)
    for site, idx in ((mid, idx_mid), (deep, idx_deep)):
        stacked = M.materialize_candidates(masks0, idx)
        np.testing.assert_allclose(
            ev.evaluate(engine.SitedChunk(site, stacked)),
            seq.evaluate(stacked), atol=1e-4)
    assert ev.trie.misses == 1 and ev.trie.extensions == 1
    assert ev.trie.depths() == (segs[mid], segs[deep])


def test_engine_covered_fraction_tracks_trie(setup):
    model, params, batch, masks0 = setup
    order, segs = model.site_order(), model.site_segments()
    deep = order[-1]
    fr = model.site_prefix_fractions()
    ev = _suffix_ev(model, params, batch, pad_to=4)
    ev.begin_step(masks0)
    assert ev.covered_fraction(deep) == 0.0
    idx = np.asarray(M.sample_removal_indices_within(
        np.random.default_rng(0), masks0, 16, 4, [deep]))
    ev.evaluate(engine.SitedChunk(
        deep, M.materialize_candidates(masks0, idx)))
    # the deep prefix is now resident: nothing left to compute for a cut
    # at the same depth, and a deeper cut would only pay the increment
    assert ev.covered_fraction(deep) == pytest.approx(fr[deep])
    shallow = order[0]
    assert ev.covered_fraction(shallow) == 0.0


def test_trie_budget_thrash_does_not_change_selection(setup):
    """trie_budget_bytes=0 evicts every entry right after insert — each
    chunk recomputes its prefix, but selection is bit-identical (the trie
    is a pure cache, never semantics)."""
    model, params, batch, masks0 = setup
    total = M.count(masks0)
    cfg = bcd.BCDConfig(b_target=total - 3 * 16, drc=16, rt=8, adt=0.5,
                        finetune_every_step=False, seed=3, chunk_size=4)
    eval_acc = model.make_eval_acc(params, batch)
    ref = bcd.run_bcd(masks0, cfg, eval_acc,
                      evaluator=engine.SequentialEvaluator(eval_acc))
    tight = bcd.run_bcd(masks0, cfg, eval_acc,
                        evaluator=_suffix_ev(model, params, batch,
                                             pad_to=4, prefetch=1,
                                             trie_budget_bytes=0))
    for k in ref.masks:
        np.testing.assert_array_equal(ref.masks[k], tight.masks[k])
    assert [h.trials for h in ref.history] == \
        [h.trials for h in tight.history]


def test_engine_multi_step_trie_reuse_matches_sequential(setup):
    """Full run_bcd with a warm trie carried across outer steps (plus the
    calibrated-capable cost model path) stays bit-identical to the
    sequential reference."""
    model, params, batch, masks0 = setup
    total = M.count(masks0)
    cfg = bcd.BCDConfig(b_target=total - 4 * 12, drc=12, rt=8, adt=0.5,
                        finetune_every_step=False, seed=5, chunk_size=3)
    eval_acc = model.make_eval_acc(params, batch)
    ref = bcd.run_bcd(masks0, cfg, eval_acc,
                      evaluator=engine.SequentialEvaluator(eval_acc))
    cm = SuffixCostModel(measured=((0.3, 2.0, 8), (0.75, 4.0, 8)))
    suf = bcd.run_bcd(masks0, cfg, eval_acc,
                      evaluator=_suffix_ev(model, params, batch,
                                           pad_to=3, prefetch=1,
                                           cost_model=cm))
    for k in ref.masks:
        np.testing.assert_array_equal(ref.masks[k], suf.masks[k])
    for ha, hb in zip(ref.history, suf.history):
        assert (ha.trials, ha.found_early) == (hb.trials, hb.found_early)
