"""GPipe microbatch pipeline: schedule output == sequential stages."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.training.pp import bubble_fraction, gpipe_forward


def _stage(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


@pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (3, 1)])
def test_gpipe_matches_sequential(S, M):
    rng = np.random.default_rng(0)
    D, mb = 16, 4
    params = {"w": jnp.asarray(rng.normal(size=(S, D, D)) * 0.3,
                               jnp.float32),
              "b": jnp.asarray(rng.normal(size=(S, D)) * 0.1, jnp.float32)}
    micro = jnp.asarray(rng.normal(size=(M, mb, D)), jnp.float32)
    got = gpipe_forward(_stage, params, micro)
    want = micro
    for s in range(S):
        want = jax.vmap(lambda x, s=s: _stage(
            jax.tree.map(lambda a: a[s], params), x))(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_gpipe_differentiable():
    rng = np.random.default_rng(1)
    S, M, D, mb = 3, 4, 8, 2
    params = {"w": jnp.asarray(rng.normal(size=(S, D, D)) * 0.3,
                               jnp.float32),
              "b": jnp.zeros((S, D), jnp.float32)}
    micro = jnp.asarray(rng.normal(size=(M, mb, D)), jnp.float32)
    g = jax.grad(lambda p: jnp.sum(gpipe_forward(_stage, p, micro) ** 2)
                 )(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    assert float(jnp.linalg.norm(g["w"])) > 0


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    # more microbatches -> smaller bubble
    assert bubble_fraction(4, 64) < bubble_fraction(4, 8)
